#!/usr/bin/env bash
# Repo verification: import-smoke every repro.* module, dry-run the
# benchmark harness + relational example, then the tier-1 suite
# (ROADMAP.md). The smokes catch collection-time breakage —
# ModuleNotFoundError / API drift in rarely-imported launch modules,
# rotted benchmark/example entry points — in seconds, before the
# multi-minute test run.
#
#   tools/verify.sh            # smoke + bench dry-run + example + tier-1
#   tools/verify.sh --smoke    # import smoke only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== import smoke: every repro.* module =="
python - <<'EOF'
import importlib, pkgutil, sys, traceback

import repro  # noqa: F401  (src on PYTHONPATH)

failed = []
mods = ["repro"]
for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
    mods.append(m.name)
for name in mods:
    if name == "repro.launch.dryrun":
        continue  # sets XLA_FLAGS for 512 host devices on import
    try:
        importlib.import_module(name)
    except Exception:
        failed.append(name)
        traceback.print_exc()
print(f"imported {len(mods) - len(failed)}/{len(mods)} modules")
# dryrun gets a subprocess so its XLA_FLAGS mutation can't leak here
import subprocess
r = subprocess.run(
    [sys.executable, "-c", "import repro.launch.dryrun"],
    env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
if r.returncode:
    failed.append("repro.launch.dryrun")
if failed:
    print("FAILED imports:", failed)
    sys.exit(1)
EOF

if [[ "${1:-}" == "--smoke" ]]; then
    exit 0
fi

echo "== benchmark dry-run smoke =="
python -m benchmarks.run --dry-run

echo "== examples smoke: relational query plan =="
python examples/table_queries.py

echo "== tier-1 tests =="
python -m pytest -x -q
