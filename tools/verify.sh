#!/usr/bin/env bash
# Repo verification: import-smoke every repro.* module, dry-run the
# benchmark harness + relational example, then the tier-1 suite
# (ROADMAP.md). The smokes catch collection-time breakage —
# ModuleNotFoundError / API drift in rarely-imported launch modules,
# rotted benchmark/example entry points — in seconds, before the
# multi-minute test run.
#
#   tools/verify.sh            # smoke + bench dry-run + example + tier-1
#   tools/verify.sh --smoke    # import smoke only
#   tools/verify.sh --fast     # everything, but tier-1 runs -m "not slow"
#                              # (skips the exhaustive grad sweeps)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== import smoke: every repro.* module =="
python - <<'EOF'
import importlib, pkgutil, sys, traceback

import repro  # noqa: F401  (src on PYTHONPATH)

failed = []
mods = ["repro"]
for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
    mods.append(m.name)
for name in mods:
    if name == "repro.launch.dryrun":
        continue  # sets XLA_FLAGS for 512 host devices on import
    try:
        importlib.import_module(name)
    except Exception:
        failed.append(name)
        traceback.print_exc()
print(f"imported {len(mods) - len(failed)}/{len(mods)} modules")
# dryrun gets a subprocess so its XLA_FLAGS mutation can't leak here
import subprocess
r = subprocess.run(
    [sys.executable, "-c", "import repro.launch.dryrun"],
    env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
if r.returncode:
    failed.append("repro.launch.dryrun")
if failed:
    print("FAILED imports:", failed)
    sys.exit(1)
EOF

if [[ "${1:-}" == "--smoke" ]]; then
    exit 0
fi

echo "== scan-engine smoke: schedule x monoid bit-parity =="
python - <<'EOF'
import numpy as np
import jax.numpy as jnp
from repro.kernels.compact import ops as kc
from repro.kernels.scan_blocked import ops as sb
from repro.kernels.segscan import ops as seg
from repro.kernels.ssm_scan import ops as ssm

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 1024)), jnp.float32)
f = jnp.asarray(rng.random((2, 1024)) < 0.02, jnp.int32)
a = jnp.asarray(rng.uniform(0.8, 1.0, (1, 256, 128)), jnp.float32)
m = jnp.asarray(rng.random((2, 1024)) < 0.5, jnp.int32)
cells = {
    "sum": lambda s: (sb.cumsum(x, interpret=True, schedule=s,
                                block_n=256),),
    "segmented": lambda s: (seg.segmented_cumsum(x, f, interpret=True,
                                                 schedule=s, block_n=256),),
    "affine": lambda s: (ssm.ssm_scan(a, x[:1, :256, None] * a, block_t=64,
                                      interpret=True, schedule=s),),
    "mask": lambda s: kc.mask_compact(m, interpret=True, schedule=s,
                                      block_n=256),
}
for name, fn in cells.items():
    outs = [fn(s) for s in ("carry", "decoupled", "fused")]
    ok = all(all(bool(jnp.all(p == q)) for p, q in zip(outs[0], o))
             for o in outs[1:])
    assert ok, f"{name}: schedules diverged"
    print(f"  {name}: carry == decoupled == fused (bitwise)")

# The tree schedule associates differently, so its bitwise bar is
# exact data: integers (and the mask monoid, which is integral).
xi = jnp.asarray(rng.integers(-9, 9, (2, 1024)), jnp.int32)
tree_cells = {
    "sum/int": lambda s: (sb.cumsum(xi, interpret=True, schedule=s,
                                    block_n=256),),
    "segmented/int": lambda s: (seg.segmented_cumsum(
        xi.astype(jnp.float32), f, interpret=True, schedule=s,
        block_n=256),),
    "mask": lambda s: kc.mask_compact(m, interpret=True, schedule=s,
                                      block_n=256),
}
for name, fn in tree_cells.items():
    outs = [fn(s) for s in ("carry", "tree")]
    ok = all(bool(jnp.all(p == q)) for p, q in zip(*outs))
    assert ok, f"{name}: tree diverged from carry on exact data"
    print(f"  {name}: tree == carry (bitwise on exact data)")
EOF

echo "== scan-backward smoke: grad(ssm_scan) as an engine fold =="
python - <<'EOF'
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.scan import reference
from repro.kernels.ssm_scan import ops as ssm

rng = np.random.default_rng(4)
a = jnp.asarray(rng.uniform(0.6, 1.0, (1, 256, 16)), jnp.float32)
b = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)

def loss_k(a, b):
    return jnp.sum(ssm.ssm_scan(a, b, interpret=True) ** 2)

def loss_r(a, b):
    return jnp.sum(reference.scan_ref((a, b), "affine", axis=1)[1] ** 2)

got = jax.grad(loss_k, argnums=(0, 1))(a, b)
want = jax.grad(loss_r, argnums=(0, 1))(a, b)
err = max(float(jnp.max(jnp.abs(p - q))) for p, q in zip(got, want))
assert err < 1e-4, f"ssm backward: {err} off reference autodiff"
print(f"  da/db: max|err| vs jax.grad(scan_ref) = {err:.2e}")
EOF

echo "== flash-attention smoke: engine fold schedules vs dense oracle =="
python - <<'EOF'
import numpy as np
import jax.numpy as jnp
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref

rng = np.random.default_rng(1)
B, Hkv, g, T, D = 1, 2, 2, 256, 32
q = jnp.asarray(rng.standard_normal((B, Hkv * g, T, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
ref = fa_ref.mha_ref(
    q.reshape(B * Hkv * g, T, D), k.reshape(B * Hkv, T, D),
    v.reshape(B * Hkv, T, D), group=g, scale=D ** -0.5,
).reshape(q.shape)
for s in ("carry", "decoupled"):
    got = fa_ops.flash_attention(q, k, v, scale=D ** -0.5, schedule=s,
                                 interpret=True)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 2e-3, f"flash {s}: {err} off the dense oracle"
    print(f"  softmax_pair/{s}: max|err| vs dense = {err:.2e}")
EOF

echo "== flash-backward smoke: engine grads vs autodiff blockwise =="
python - <<'EOF'
import jax
import jax.numpy as jnp
import numpy as np
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref

rng = np.random.default_rng(2)
B, Hkv, g, T, D = 1, 2, 2, 128, 16
q = jnp.asarray(rng.standard_normal((B, Hkv * g, T, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)

def ref_loss(q, k, v):
    o = fa_ref.blockwise_ref(
        q.reshape(B * Hkv * g, T, D), k.reshape(B * Hkv, T, D),
        v.reshape(B * Hkv, T, D), group=g, scale=D ** -0.5, block_k=64)
    return jnp.sum(o ** 2)

want = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
for s in ("carry", "decoupled"):
    def loss(q, k, v, s=s):
        return jnp.sum(fa_ops.flash_attention(
            q, k, v, scale=D ** -0.5, schedule=s, interpret=True) ** 2)
    got = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, want))
    assert err < 1e-4, f"flash bwd {s}: {err} off autodiff blockwise"
    print(f"  dq/dk/dv {s}: max|err| vs jax.grad(blockwise_ref) = {err:.2e}")
EOF

echo "== causal-bound smoke: bitwise identity + fewer cells =="
python - <<'EOF'
import jax.numpy as jnp
import numpy as np
from repro.kernels import scan_engine
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_kernel)

rng = np.random.default_rng(3)
T, D, b = 512, 16, 64
q = jnp.asarray(rng.standard_normal((2, T, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((2, T, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((2, T, D)), jnp.float32)
kw = dict(scale=D ** -0.5, causal=True, block_q=b, block_k=b,
          interpret=True)
on, counts = flash_attention_kernel(q, k, v, count_cells=True, **kw)
off = flash_attention_kernel(q, k, v, use_kv_bounds=False, **kw)
assert bool(jnp.all(on == off)), "KV bound changed bits"
n = T // b
lay = scan_engine.KVBlocks(bh=2, bh_kv=2, tq=T, tk=T, d=D, bq=b, bk=b,
                           kv_bounds=(True, None, T))
assert int(counts.sum()) == 2 * lay.active_cells() < 2 * n * n
print(f"  causal prefill: bitwise identical, "
      f"{int(counts.sum())}/{2 * n * n} cells executed")
EOF

# The full benchmark dry-run below also runs the attention suite via
# run.py; this standalone call additionally exercises fig_attention's
# own CLI entry point (__main__ + --dry-run flag parsing).
echo "== attention benchmark dry-run smoke =="
python -m benchmarks.fig_attention --dry-run

echo "== benchmark dry-run smoke + bench trajectory gate =="
python -m benchmarks.run --dry-run --json /tmp/bench.json
python -m tools.bench_gate --check-schema /tmp/bench.json BENCH_*.json
# Smoke-sized timings gate loosely (CI wall-clock noise); structure,
# parity strings, bytes/flops, and error-vs-oracle gate tight.
python -m tools.bench_gate --fresh /tmp/bench.json --baseline-dir . \
    --time-tol 3.0

echo "== trace-export smoke: serve run -> Chrome trace_event JSON =="
python -m repro.launch.serve --arch stablelm-12b --smoke --requests 3 \
    --max-new-tokens 4 --temperature 0 --attn-impl flash \
    --trace /tmp/serve_trace.json --stats-json > /tmp/serve_out.txt
python - <<'EOF'
import json
doc = json.load(open("/tmp/serve_trace.json"))
evs = doc["traceEvents"]
assert any(e["name"] == "serve.tick" and e["ph"] == "X" for e in evs)
assert any(e["name"] == "serve.request.finish" for e in evs)
assert any(e["name"].startswith("policy.") for e in evs)
line = [l for l in open("/tmp/serve_out.txt")
        if l.startswith("stats-json: ")][0]
parsed = json.loads(line[len("stats-json: "):])
assert parsed["stats"]["total_finished"] == 3
print(f"  {len(evs)} events ({sorted({e['name'] for e in evs})})")
EOF

echo "== examples smoke: relational query plan =="
python examples/table_queries.py

echo "== serve-chaos smoke: no request lost under seeded injection =="
python - <<'EOF'
import dataclasses, warnings
import jax, numpy as np
from repro import configs
from repro.serve import Engine, EngineConfig, FaultInjector, Request
from repro.train.step import init_params

cfg = dataclasses.replace(configs.get_smoke_config("stablelm-12b"),
                          dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
inj = FaultInjector.from_seed(3, ticks=40, p_error=0.15, p_nan=0.15,
                              p_stall=0.05, stall_s=0.002, poison_rids=[4])
eng = Engine(params, cfg, EngineConfig(
    max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
    temperature=0.0), injector=inj)
rng = np.random.default_rng(7)
n = 6
for rid in range(n):
    eng.submit(Request(rid=rid, prompt=rng.integers(
        2, 500, size=int(rng.integers(3, 9))).astype(np.int32)))
with warnings.catch_warnings():
    warnings.simplefilter("ignore")
    done = eng.run_to_completion()
eng.audit()  # raises on lost/duplicated rids or invalid finish reasons
assert sorted(r.rid for r in done) == list(range(n)), "request lost"
reasons = {r.rid: r.finish_reason for r in done}
assert reasons[4] == "error", f"poison not quarantined: {reasons}"
print(f"  {n} requests -> {reasons}")
print(f"  {eng.stats.summary()}")
EOF

echo "== paged-serve smoke: prefix-sum allocator end to end =="
python - <<'EOF'
import dataclasses, warnings
import jax, numpy as np
from repro import configs
from repro.serve import Engine, EngineConfig, Request
from repro.train.step import init_params

cfg = dataclasses.replace(configs.get_smoke_config("stablelm-12b"),
                          dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
eng = Engine(params, cfg, EngineConfig(
    max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
    temperature=0.0, cache_layout="paged", page_size=8))
rng = np.random.default_rng(7)
n = 3
for rid in range(n):
    eng.submit(Request(rid=rid, prompt=rng.integers(
        2, 500, size=int(rng.integers(3, 9))).astype(np.int32)))
with warnings.catch_warnings():
    warnings.simplefilter("ignore")
    done = eng.run_to_completion()
eng.audit()  # raises on lost/duplicated rids or invalid finish reasons
assert sorted(r.rid for r in done) == list(range(n)), "request lost"
assert all(r.output for r in done), "empty output"
assert eng.stats.page_allocs > 0, "allocator never exercised"
assert eng.allocator.in_use == 0, "pages leaked after drain"
print(f"  {n} requests on {eng.allocator.num_pages} pages "
      f"(page_size={eng.ecfg.page_size})")
print(f"  {eng.stats.summary()}")
EOF

echo "== shared-prefix smoke: COW page sharing saves allocations =="
python - <<'EOF'
import dataclasses, warnings
import jax, numpy as np
from repro import configs
from repro.serve import Engine, EngineConfig, Request
from repro.train.step import init_params

cfg = dataclasses.replace(configs.get_smoke_config("stablelm-12b"),
                          dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(7)
system = rng.integers(2, 500, size=16).astype(np.int32)  # 2 full pages
prompts = [np.concatenate([system, rng.integers(2, 500, size=3)
                           .astype(np.int32)]) for _ in range(2)]

def drive(share):
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
        temperature=0.0, cache_layout="paged", page_size=8,
        share_prefixes=share))
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        done = eng.run_to_completion()
    eng.audit()
    return {r.rid: list(r.output) for r in done}, eng.stats

out_u, st_u = drive(False)
out_s, st_s = drive(True)
assert out_s == out_u, "sharing changed token streams"
assert st_s.page_allocs < st_u.page_allocs, (
    f"sharing saved nothing: {st_s.page_allocs} vs {st_u.page_allocs}")
assert st_s.prefix_hits >= 1 and st_s.shared_page_maps >= 2
print(f"  2 requests, common 16-token system prompt: page_allocs "
      f"{st_u.page_allocs} -> {st_s.page_allocs}, "
      f"prefix_hits={st_s.prefix_hits}, "
      f"shared_page_maps={st_s.shared_page_maps}")
print(f"  {st_s.summary()}")
EOF

echo "== tier-1 tests =="
if [[ "${1:-}" == "--fast" ]]; then
    # Exhaustive sweeps (large-shape grad walls) are marked slow; the
    # canonical tier-1 run (ROADMAP.md) executes everything.
    python -m pytest -x -q -m "not slow"
else
    python -m pytest -x -q
fi
