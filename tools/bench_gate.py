"""Bench-trajectory gate: diff a fresh ``benchmarks.run --json`` document
against the committed ``BENCH_<suite>.json`` baselines.

    PYTHONPATH=src python -m tools.bench_gate --check-schema BENCH_*.json
    PYTHONPATH=src python -m tools.bench_gate \
        --fresh /tmp/bench.json --baseline-dir . [--time-tol 3.0]

Comparison rules (per table, rows matched by position):

  * structure — suite present, table count, title, columns, row count,
    row shape: any drift is a failure (the bench changed; re-baseline
    deliberately with ``benchmarks.run <suite> --dry-run --json``).
  * timing cells (the dicts ``TimingStats`` serializes, and plain
    floats in columns whose name mentions ``ms``/``sec``/``tick``):
    regression when ``fresh > base * time_tol``.  Getting FASTER never
    fails — speedups update the baseline, they don't gate.
  * throughput cells (column name contains ``/s``): inverted —
    regression when ``fresh < base / time_tol``.
  * other numeric cells: relative drift beyond ``--rel-tol`` fails in
    either direction (bytes/elem, flops/elem, error-vs-oracle, retry
    counters are deterministic structure, not noise).
  * string cells: exact.

Exit status 0 = gate passed, 1 = regression or structural drift,
2 = usage/schema error.  Only suites present in BOTH documents gate;
baselines with no fresh counterpart (and vice versa) are reported but
do not fail, so a partial run can still be checked.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

SCHEMA = "repro-bench/v1"

_TIMING_KEYS = {"p50", "min", "max", "iters"}
_TIMING_HINTS = ("ms", "sec", "tick", "time")


def _is_timing_dict(v) -> bool:
    return isinstance(v, dict) and set(v) == _TIMING_KEYS


def check_schema(doc, path="<doc>"):
    """Return a list of schema-violation strings (empty = valid)."""
    errs = []
    if not isinstance(doc, dict):
        return [f"{path}: not a JSON object"]
    if doc.get("schema") != SCHEMA:
        errs.append(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    suites = doc.get("suites")
    if not isinstance(suites, dict) or not suites:
        errs.append(f"{path}: missing/empty 'suites'")
        return errs
    for sname, tables in suites.items():
        if not isinstance(tables, list) or not tables:
            errs.append(f"{path}: suite {sname!r} is not a list of tables")
            continue
        for ti, t in enumerate(tables):
            where = f"{path}: {sname}[{ti}]"
            if not isinstance(t, dict):
                errs.append(f"{where}: not an object")
                continue
            for key in ("title", "columns", "rows"):
                if key not in t:
                    errs.append(f"{where}: missing {key!r}")
            cols = t.get("columns", [])
            for ri, row in enumerate(t.get("rows", [])):
                if not isinstance(row, list) or len(row) != len(cols):
                    errs.append(f"{where} row {ri}: shape != columns")
    return errs


def _compare_cell(col, base, fresh, time_tol, rel_tol, where):
    """One failure string, or None."""
    if _is_timing_dict(base) != _is_timing_dict(fresh):
        return f"{where}: cell kind changed ({base!r} -> {fresh!r})"
    if _is_timing_dict(base):
        b, f = base["p50"], fresh["p50"]
        if f > b * time_tol:
            return (f"{where} [{col}]: {f:.4g}s vs baseline {b:.4g}s "
                    f"(> {time_tol:.2f}x)")
        return None
    if isinstance(base, str) or isinstance(fresh, str):
        if base != fresh:
            return f"{where} [{col}]: {fresh!r} != baseline {base!r}"
        return None
    if isinstance(base, bool) or base is None:
        if base != fresh:
            return f"{where} [{col}]: {fresh!r} != baseline {base!r}"
        return None
    # numeric
    name = col.lower()
    if "/s" in name:  # throughput: lower is worse
        if fresh < base / time_tol:
            return (f"{where} [{col}]: {fresh:.4g} vs baseline {base:.4g} "
                    f"(< 1/{time_tol:.2f}x)")
        return None
    if any(h in name for h in _TIMING_HINTS):  # latency float: higher worse
        if fresh > base * time_tol:
            return (f"{where} [{col}]: {fresh:.4g} vs baseline {base:.4g} "
                    f"(> {time_tol:.2f}x)")
        return None
    denom = max(abs(base), abs(fresh), 1e-12)
    if abs(fresh - base) / denom > rel_tol:
        return (f"{where} [{col}]: {fresh!r} drifted from baseline "
                f"{base!r} (rel > {rel_tol:.2f})")
    return None


def compare_suite(name, base_tables, fresh_tables, time_tol, rel_tol):
    """Return a list of failure strings for one suite."""
    fails = []
    if len(base_tables) != len(fresh_tables):
        return [f"{name}: {len(fresh_tables)} tables vs baseline "
                f"{len(base_tables)}"]
    for ti, (bt, ft) in enumerate(zip(base_tables, fresh_tables)):
        where = f"{name}[{ti}]"
        if bt["title"] != ft["title"]:
            fails.append(f"{where}: title changed "
                         f"({bt['title']!r} -> {ft['title']!r})")
            continue
        if bt["columns"] != ft["columns"]:
            fails.append(f"{where}: columns changed "
                         f"({bt['columns']} -> {ft['columns']})")
            continue
        if len(bt["rows"]) != len(ft["rows"]):
            fails.append(f"{where}: {len(ft['rows'])} rows vs baseline "
                         f"{len(bt['rows'])}")
            continue
        for ri, (br, fr) in enumerate(zip(bt["rows"], ft["rows"])):
            for col, bc, fc in zip(bt["columns"], br, fr):
                err = _compare_cell(col, bc, fc, time_tol, rel_tol,
                                    f"{where} row {ri}")
                if err:
                    fails.append(err)
    return fails


def gate(fresh_doc, baselines, time_tol=1.75, rel_tol=0.05, out=print):
    """Diff ``fresh_doc`` against ``baselines`` ({suite: doc}); return
    the list of failures (empty = gate passed)."""
    fails = []
    common = sorted(set(baselines) & set(fresh_doc["suites"]))
    for name in sorted(set(baselines) - set(fresh_doc["suites"])):
        out(f"[bench-gate] note: baseline {name!r} has no fresh run")
    for name in sorted(set(fresh_doc["suites"]) - set(baselines)):
        out(f"[bench-gate] note: suite {name!r} has no baseline yet")
    for name in common:
        base = baselines[name]["suites"][name]
        suite_fails = compare_suite(name, base, fresh_doc["suites"][name],
                                    time_tol, rel_tol)
        out(f"[bench-gate] {name}: "
            + ("OK" if not suite_fails else f"{len(suite_fails)} failure(s)"))
        fails.extend(suite_fails)
    if not common:
        out("[bench-gate] warning: no suites in common — nothing gated")
    return fails


def load_baselines(baseline_dir):
    """{suite: doc} from every BENCH_<suite>.json in ``baseline_dir``
    that actually contains that suite."""
    found = {}
    for path in sorted(glob.glob(os.path.join(baseline_dir,
                                              "BENCH_*.json"))):
        suite = os.path.basename(path)[len("BENCH_"):-len(".json")]
        with open(path) as f:
            doc = json.load(f)
        errs = check_schema(doc, path)
        if errs:
            raise SystemExit("\n".join(errs))
        if suite not in doc.get("suites", {}):
            raise SystemExit(f"{path}: no suite {suite!r} inside")
        found[suite] = doc
    return found


def main(argv=None):
    ap = argparse.ArgumentParser(prog="tools.bench_gate",
                                 description=__doc__)
    ap.add_argument("--check-schema", nargs="+", metavar="FILE",
                    default=None,
                    help="validate documents and exit (no gating)")
    ap.add_argument("--fresh", metavar="FILE",
                    help="fresh benchmarks.run --json document")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding BENCH_<suite>.json")
    ap.add_argument("--time-tol", type=float, default=1.75,
                    help="timing ratio allowed before failing "
                         "(default %(default)s)")
    ap.add_argument("--rel-tol", type=float, default=0.05,
                    help="relative drift allowed on plain numeric cells "
                         "(default %(default)s)")
    args = ap.parse_args(argv)

    if args.check_schema is not None:
        bad = 0
        for path in args.check_schema:
            try:
                with open(path) as f:
                    doc = json.load(f)
                errs = check_schema(doc, path)
            except (OSError, ValueError) as e:
                errs = [f"{path}: {e}"]
            if errs:
                bad += 1
                print("\n".join(errs))
            else:
                print(f"{path}: schema ok "
                      f"({len(doc['suites'])} suite(s))")
        return 2 if bad else 0

    if not args.fresh:
        ap.error("--fresh is required unless --check-schema")
    with open(args.fresh) as f:
        fresh = json.load(f)
    errs = check_schema(fresh, args.fresh)
    if errs:
        print("\n".join(errs))
        return 2
    baselines = load_baselines(args.baseline_dir)
    fails = gate(fresh, baselines, args.time_tol, args.rel_tol)
    for msg in fails:
        print(f"[bench-gate] FAIL {msg}")
    if fails:
        print(f"[bench-gate] REGRESSION: {len(fails)} failure(s) vs "
              f"baselines in {args.baseline_dir}")
        return 1
    print("[bench-gate] all gated suites within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
