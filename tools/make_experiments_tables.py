"""Render EXPERIMENTS.md tables from dry-run JSON records.

    PYTHONPATH=src python tools/make_experiments_tables.py roofline
    PYTHONPATH=src python tools/make_experiments_tables.py dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load(d):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(p)
        recs.append(r)
    return recs


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b:.0f}"


def roofline_table(d="experiments/roofline"):
    print("| arch | shape | mode | compute s | memory s | collective s | "
          "dcn s | dominant | bound s | useful | MODEL_TFLOPs |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in load(d):
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | | FAIL: "
                  f"{r.get('error','')[:40]} | | | | | | | |")
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"],
                    r["dcn_s"])
        mode = ("unrolled" if r.get("knobs", {}).get("unroll", True)
                else "scanned†")
        print(f"| {r['arch']} | {r['shape']} | {mode} | "
              f"{r['compute_s']:.4f} | "
              f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['dcn_s']:.4f} | {r['dominant']} | {bound:.4f} | "
              f"{100*r['useful_ratio']:.0f}% | "
              f"{r['model_flops']/1e12:.0f} |")


def dryrun_table(d="experiments/dryrun"):
    print("| arch | shape | mesh | status | HLO flops/dev | HLO bytes/dev |"
          " coll bytes/dev | cross-pod | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in load(d):
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | "
                  f"| | | {r.get('compile_s',0):.0f} |")
            continue
        coll = sum(r["collective_bytes"].values())
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
              f"{r['hlo_flops']:.2e} | {fmt_bytes(r['hlo_bytes'])} | "
              f"{fmt_bytes(coll)} | {fmt_bytes(r['cross_pod_bytes'])} | "
              f"{r['compile_s']:.0f} |")


def perf_table(d="experiments/perf"):
    print("| cell | variant | compute s | memory s | collective s | "
          "bound s | Δbound |")
    print("|---|---|---|---|---|---|---|")
    rows = {}
    for r in load(d):
        if r.get("status") != "ok":
            continue
        key = (r["arch"], r["shape"])
        tag = r["_file"].rsplit("_", 1)[-1].replace(".json", "") \
            if "_" in r["_file"] else "base"
        if not r.get("knobs", {}).get("unroll", True):
            tag += "(scanned)"
        if r.get("knobs", {}).get("override_layers"):
            tag += f"@{r['knobs']['override_layers']}L"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"],
                    r["dcn_s"])
        rows.setdefault(key, []).append((tag, r, bound))
    for key, variants in rows.items():
        base = None
        for tag, r, bound in variants:
            delta = "" if base is None else f"{(bound/base-1)*100:+.0f}%"
            base = base or bound
            print(f"| {key[0]} × {key[1]} | {tag} | {r['compute_s']:.4f} |"
                  f" {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                  f"{bound:.4f} | {delta} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    {"roofline": roofline_table, "dryrun": dryrun_table,
     "perf": perf_table}[which](*sys.argv[2:])
