"""Insert generated tables at the EXPERIMENTS.md placeholders.

    PYTHONPATH=src python tools/inject_tables.py
"""

import io
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "tools")
from make_experiments_tables import perf_table, roofline_table  # noqa: E402


def capture(fn, *a):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a)
    return buf.getvalue().strip()


def main():
    path = "EXPERIMENTS.md"
    text = open(path).read()
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        capture(roofline_table, "experiments/roofline"))
    text = text.replace("<!-- PERF_TABLE -->",
                        capture(perf_table, "experiments/perf"))
    open(path, "w").write(text)
    print("tables injected")


if __name__ == "__main__":
    main()
