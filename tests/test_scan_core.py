"""Core scan algorithms vs the sequential oracle (paper Table 2 rows)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scan as scanlib

ALGOS = ("ref", "horizontal", "vertical", "tree", "blocked", "two_pass")


def _np_ref(x, exclusive=False):
    inc = np.cumsum(x, axis=-1, dtype=np.float64)
    if not exclusive:
        return inc
    exc = np.zeros_like(inc)
    exc[..., 1:] = inc[..., :-1]
    return exc


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("n", [1, 2, 7, 16, 100, 1024, 4100])
def test_cumsum_matches_numpy(algo, n):
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    got = scanlib.scan(jnp.asarray(x), "sum", algorithm=algo)
    np.testing.assert_allclose(np.asarray(got), _np_ref(x), rtol=2e-4,
                               atol=1e-4)


@pytest.mark.parametrize("algo", ALGOS)
def test_exclusive(algo):
    x = np.arange(1, 65, dtype=np.float32)
    got = scanlib.scan(jnp.asarray(x), "sum", algorithm=algo, exclusive=True)
    np.testing.assert_allclose(np.asarray(got), _np_ref(x, True), rtol=1e-5)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32, jnp.bfloat16])
def test_dtypes(algo, dtype):
    x = jnp.asarray(np.random.default_rng(0).integers(-5, 5, 257), dtype)
    got = scanlib.scan(x, "sum", algorithm=algo)
    ref = scanlib.scan_ref(x, "sum")
    tol = 0.1 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("axis", [0, 1, -1])
def test_axes_2d(algo, axis):
    x = np.random.default_rng(1).standard_normal((6, 33)).astype(np.float32)
    got = scanlib.scan(jnp.asarray(x), "sum", axis=axis, algorithm=algo)
    ref = np.cumsum(x, axis=axis, dtype=np.float64)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("op", ["max", "min", "prod"])
@pytest.mark.parametrize("algo", ["horizontal", "tree", "blocked"])
def test_other_monoids(op, algo):
    rng = np.random.default_rng(2)
    x = rng.uniform(0.5, 1.5, 100).astype(np.float32)
    got = scanlib.scan(jnp.asarray(x), op, algorithm=algo)
    ref = scanlib.scan_ref(jnp.asarray(x), op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_affine_monoid_blocked_vs_ref():
    rng = np.random.default_rng(3)
    a = rng.uniform(0.8, 1.0, 200).astype(np.float32)
    b = rng.standard_normal(200).astype(np.float32)
    got_a, got_b = scanlib.scan((jnp.asarray(a), jnp.asarray(b)), "affine",
                                algorithm="blocked", block_size=32)
    # sequential recurrence h_t = a_t h_{t-1} + b_t  (h_0 = 0)
    h = np.zeros(200)
    prev = 0.0
    for i in range(200):
        prev = a[i] * prev + b[i]
        h[i] = prev
    np.testing.assert_allclose(np.asarray(got_b), h, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# property-based invariants
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=300),
       st.sampled_from(["horizontal", "blocked", "tree", "vertical"]))
@settings(max_examples=30, deadline=None)
def test_property_recurrence(xs, algo):
    """y[i] - y[i-1] == x[i] (the defining recurrence)."""
    x = np.asarray(xs, np.float32)
    y = np.asarray(scanlib.scan(jnp.asarray(x), "sum", algorithm=algo),
                   np.float64)
    np.testing.assert_allclose(np.diff(y), x[1:], rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(y[0], x[0], rtol=1e-5)


@given(st.integers(1, 200), st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_property_concat(n1, n2):
    """scan(a ++ b) == scan(a) ++ (scan(b) + sum(a))."""
    rng = np.random.default_rng(n1 * 1000 + n2)
    a = rng.standard_normal(n1).astype(np.float32)
    b = rng.standard_normal(n2).astype(np.float32)
    whole = np.asarray(
        scanlib.cumsum(jnp.asarray(np.concatenate([a, b])),
                       algorithm="blocked"), np.float64)
    pa = np.asarray(scanlib.cumsum(jnp.asarray(a), algorithm="blocked"),
                    np.float64)
    pb = np.asarray(scanlib.cumsum(jnp.asarray(b), algorithm="blocked"),
                    np.float64)
    np.testing.assert_allclose(whole, np.concatenate([pa, pb + pa[-1]]),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(2, 512), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_property_block_size_invariance(n, block):
    """The blocked result must not depend on the block size."""
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    y1 = scanlib.scan(jnp.asarray(x), "sum", algorithm="blocked",
                      block_size=block)
    y2 = scanlib.scan(jnp.asarray(x), "sum", algorithm="blocked",
                      block_size=max(1, n))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


@given(st.integers(1, 8), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_property_dilation_partitions(parts, dilation):
    """partition_sizes: sums to n, first partition scaled by d."""
    n = 1000
    sizes = scanlib.partition_sizes(n, parts, dilation)
    assert sum(sizes) == n
    assert all(s > 0 for s in sizes)


@pytest.mark.parametrize("variant", [1, 2])
@pytest.mark.parametrize("dilation", [0.0, 0.3, 1.0])
def test_two_pass_variants_dilation(variant, dilation):
    x = np.random.default_rng(9).standard_normal(515).astype(np.float32)
    got = scanlib.scan_two_pass(jnp.asarray(x), "sum", num_partitions=5,
                                variant=variant, dilation=dilation)
    np.testing.assert_allclose(np.asarray(got), _np_ref(x), rtol=2e-4,
                               atol=1e-4)


def test_policy_choices():
    from repro.core.scan.policy import choose
    small = choose(1024)
    assert small.algorithm == "horizontal"  # fits fast memory (Obs 2)
    big = choose(1 << 26)
    assert big.algorithm in ("kernel", "blocked")  # partitioned (Obs 3)
    assert big.variant == 2                        # reduce-first (SIMD2-P)
    hbm = choose(1 << 26, bandwidth_abundant=True)
    assert big.algorithm != hbm.algorithm or hbm.algorithm == "two_pass"


def test_segmented_scan_restarts():
    vals = jnp.asarray(np.ones(10, np.float32))
    flags = jnp.asarray([1, 0, 0, 1, 0, 0, 0, 1, 0, 0], jnp.int32)
    out = scanlib.segmented_scan(vals, flags)
    np.testing.assert_allclose(
        np.asarray(out), [1, 2, 3, 1, 2, 3, 4, 1, 2, 3])


# ---------------------------------------------------------------------------
# Degenerate scan axes: every algorithm must agree with the oracle on
# n == 0 (nothing to combine — historically several algorithms crashed:
# horizontal's exclusive shift sliced [0, 1) from a length-0 identity,
# blocked indexed block [0, 0] of zero blocks, vertical folded an empty
# chunk) and on n == 1 (no combine steps at all).
# ---------------------------------------------------------------------------


ALGOS_ALL = ALGOS + ("kernel",)


@pytest.mark.parametrize("algo", ALGOS_ALL)
@pytest.mark.parametrize("exclusive", [False, True])
@pytest.mark.parametrize("n", [0, 1])
def test_degenerate_lengths_match_ref(algo, exclusive, n):
    x = jnp.asarray(
        np.random.default_rng(7).standard_normal((3, n)).astype(np.float32))
    got = scanlib.scan(x, "sum", axis=-1, algorithm=algo,
                       exclusive=exclusive)
    ref = scanlib.scan_ref(x, "sum", axis=-1, exclusive=exclusive)
    assert got.shape == x.shape
    assert got.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("exclusive", [False, True])
def test_degenerate_lengths_multi_leaf(exclusive):
    # The affine (two-leaf) monoid through the library algorithms.
    for algo in ("ref", "horizontal", "tree", "blocked"):
        a = jnp.zeros((2, 0), jnp.float32)
        b = jnp.zeros((2, 0), jnp.float32)
        out_a, out_b = scanlib.scan((a, b), "affine", axis=-1,
                                    algorithm=algo, exclusive=exclusive)
        assert out_a.shape == (2, 0) and out_b.shape == (2, 0)


def test_degenerate_lengths_kernel_families():
    from repro.kernels.scan_blocked import ops as cops
    from repro.kernels.segscan import ops as sops
    from repro.kernels.ssm_scan import ops as ssops

    e = jnp.zeros((2, 0), jnp.float32)
    assert cops.cumsum(e).shape == (2, 0)
    assert cops.cumsum(e, exclusive=True).shape == (2, 0)
    assert sops.segmented_cumsum(e, e).shape == (2, 0)
    e3 = jnp.zeros((2, 0, 4), jnp.float32)
    assert ssops.ssm_scan(e3, e3).shape == (2, 0, 4)


# ---------------------------------------------------------------------------
# Tree-oracle non-commutative wall (the down-sweep order trap): Blelloch's
# down-sweep hands the right child combine(parent, old_left) — with the
# PARENT prefix as the LEFT argument. A commutative monoid (sum) hides a
# swapped implementation; the affine and segmented monoids do not. Pin
# the order against the sequential oracle on awkward (non-power-of-two)
# lengths, where the identity padding also has to be on the correct side.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [3, 5, 37, 100, 130])
@pytest.mark.parametrize("exclusive", [False, True])
def test_tree_oracle_affine_non_commutative(n, exclusive):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = scanlib.scan((a, b), "affine", algorithm="tree",
                       exclusive=exclusive)
    ref = scanlib.scan_ref((a, b), "affine", exclusive=exclusive)
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("n", [3, 37, 130])
def test_tree_oracle_segmented_non_commutative(n):
    from repro.core.scan import assoc

    rng = np.random.default_rng(n)
    vals = jnp.asarray(rng.integers(-4, 5, n).astype(np.float32))
    flags = jnp.asarray((rng.random(n) < 0.3).astype(np.float32))
    monoid = assoc.segmented(assoc.get("sum"))
    got = scanlib.scan((flags, vals), monoid, algorithm="tree")
    ref = scanlib.scan_ref((flags, vals), monoid)
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-6)
