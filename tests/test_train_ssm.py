"""End-to-end SSM training on the engine: chunked | kernel peers.

``TrainStepConfig.ssm_impl="kernel"`` routes the tiny Mamba2 LM's
inter-chunk recurrence through the engine-backed affine kernel whose
custom VJP runs the backward as one more engine scan — the SSM twin of
``attn_impl="flash"``. The wall: loss, per-leaf gradients, and one full
AdamW step must agree with the chunked-reference autodiff peer within
float tolerance, and the kernel route must actually launch the engine
in BOTH directions (trace evidence).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm as lm_mod
from repro.models.config import ModelConfig
from repro.obs import trace
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.step import TrainStepConfig, make_train_step

IMPLS = ("chunked", "kernel")


def _tiny_cfg(**over):
    base = dict(name="tiny-ssm", family="ssm", num_layers=2,
                d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                d_ff=128, vocab_size=128, layer_pattern=("mamba",),
                ssm_state=16, ssm_heads=2, ssm_head_dim=16, ssm_chunk=16,
                dtype="float32")
    base.update(over)
    return ModelConfig(**base)


def _batch(rng, B=2, S=64, V=128):
    return {
        "tokens": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }


def _loss_and_grads(cfg, params, batch, impl, remat=True):
    return jax.value_and_grad(
        lambda p: lm_mod.lm_loss(p, batch, cfg, ssm_impl=impl,
                                 remat=remat),
        has_aux=True)(params)


def _max_leaf_err(a, b):
    errs = jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree.leaves(errs))


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(np.random.default_rng(0))
    return cfg, params, batch


def test_loss_and_grad_parity_kernel_vs_chunked(setup):
    cfg, params, batch = setup
    results = {impl: _loss_and_grads(cfg, params, batch, impl)
               for impl in IMPLS}
    losses = {impl: float(r[0][0]) for impl, r in results.items()}
    assert abs(losses["kernel"] - losses["chunked"]) < 1e-5, losses
    err = _max_leaf_err(results["kernel"][1], results["chunked"][1])
    assert err < 1e-4, err


def test_kernel_route_launches_engine_both_directions(setup):
    """The kernel-impl grad must emit affine ``kernel.launch`` instants
    for forward AND backward compilations; the chunked route none.

    Launch instants fire once per compilation, so this test uses a
    sequence length no other test compiles (the grad of the fixture
    batch is already warm by the time this runs)."""
    cfg, params, _ = setup
    batch = _batch(np.random.default_rng(7), S=48)
    tracer = trace.enable()
    try:
        tracer.clear()
        _loss_and_grads(cfg, params, batch, "chunked")
        chunked = [e for e in tracer.events()
                   if e["name"] == "kernel.launch"
                   and e["args"]["monoid"] == "affine"]
        assert chunked == []

        tracer.clear()
        _loss_and_grads(cfg, params, batch, "kernel")
        affine = [e for e in tracer.events()
                  if e["name"] == "kernel.launch"
                  and e["args"]["monoid"] == "affine"]
        assert len(affine) >= 2, \
            "expected forward and backward engine compilations"
    finally:
        trace.disable()


def test_optimizer_step_parity(setup):
    cfg, params, batch = setup
    acfg = AdamWConfig(lr=1e-3, grad_clip=1.0, weight_decay=0.1)
    stepped = {}
    for impl in IMPLS:
        (_, _), grads = _loss_and_grads(cfg, params, batch, impl)
        opt = adamw_init(params)
        new_params, _, _ = adamw_update(grads, opt, params, acfg, lr=1e-3)
        stepped[impl] = new_params
    assert _max_leaf_err(stepped["kernel"], stepped["chunked"]) < 1e-4
    # and the step actually moved the parameters
    assert _max_leaf_err(stepped["kernel"], params) > 1e-6


def test_make_train_step_runs_kernel_ssm(setup):
    """The full jitted train step (remat + lax.scan over periods +
    chunked CE) accepts ssm_impl='kernel' and matches the chunked
    route's loss and updated params."""
    cfg, params, batch = setup
    outs = {}
    for impl in IMPLS:
        step = jax.jit(make_train_step(
            cfg, TrainStepConfig(remat=True, ssm_impl=impl,
                                 total_steps=10)))
        opt = adamw_init(params)
        new_p, _, metrics = step(params, opt, batch,
                                 jnp.zeros((), jnp.int32))
        outs[impl] = (new_p, float(metrics["loss"]))
    assert abs(outs["kernel"][1] - outs["chunked"][1]) < 1e-5
    assert _max_leaf_err(outs["kernel"][0], outs["chunked"][0]) < 1e-4


def test_hybrid_pattern_kernel_grads(setup):
    """A hybrid attention+mamba pattern trains through the kernel route
    too — the impl knob only touches the SSM layers."""
    cfg = _tiny_cfg(num_layers=2, layer_pattern=("global", "mamba"))
    params = lm_mod.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch(np.random.default_rng(1))
    (_, _), g_ref = _loss_and_grads(cfg, params, batch, "chunked")
    (_, _), g_ker = _loss_and_grads(cfg, params, batch, "kernel")
    assert _max_leaf_err(g_ker, g_ref) < 1e-4
