"""Trainer loop, fault tolerance, checkpoint/restart, optimizer."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.manager import (CheckpointManager, latest_step,
                                      restore_checkpoint, save_checkpoint)
from repro.data import DataConfig, SyntheticDataset
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.train.step import TrainStepConfig, init_params, make_train_step
from repro.train.trainer import Trainer, TrainerConfig, _StragglerTracker


@pytest.fixture
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def _tiny_setup(arch="xlstm-125m", steps=6, **tkw):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, TrainStepConfig(remat=False, total_steps=steps)))
    ds = SyntheticDataset(DataConfig(
        seq_len=32, global_batch=2, vocab_size=cfg.vocab_size))
    return cfg, params, opt, step, ds


def test_loss_decreases(tmp_ckpt):
    cfg, params, opt, step, ds = _tiny_setup(steps=30)
    tr = Trainer(step, ds, TrainerConfig(
        total_steps=30, checkpoint_every=100, checkpoint_dir=tmp_ckpt,
        log_every=100))
    tr.run(params, opt)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first, (first, last)


def test_checkpoint_restart_resumes_exactly(tmp_ckpt):
    cfg, params, opt, step, ds = _tiny_setup(steps=8)
    tr = Trainer(step, ds, TrainerConfig(
        total_steps=8, checkpoint_every=4, checkpoint_dir=tmp_ckpt,
        log_every=100))
    p_final, o_final = tr.run(params, opt)

    # new process analogue: fresh params, restore, run the remaining steps
    params2 = init_params(jax.random.PRNGKey(0), cfg)
    opt2 = adamw_init(params2)
    tr2 = Trainer(step, ds, TrainerConfig(
        total_steps=8, checkpoint_every=4, checkpoint_dir=tmp_ckpt))
    start, p_r, o_r = tr2.maybe_restore(params2, opt2)
    assert start == 8  # final commit
    for a, b in zip(jax.tree.leaves(p_r), jax.tree.leaves(p_final)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_commit_marker_protects_partial(tmp_ckpt):
    cfg, params, opt, step, ds = _tiny_setup()
    save_checkpoint(tmp_ckpt, 5, {"params": params})
    # simulate a crash mid-write: a step dir without COMMIT
    os.makedirs(os.path.join(tmp_ckpt, "step_9"), exist_ok=True)
    assert latest_step(tmp_ckpt) == 5


def test_checkpoint_retention(tmp_ckpt):
    mgr = CheckpointManager(tmp_ckpt, keep=2)
    x = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.save(s, x, block=True)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_ckpt)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_bf16_roundtrip(tmp_ckpt):
    x = {"w": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)}
    save_checkpoint(tmp_ckpt, 1, x)
    back = restore_checkpoint(tmp_ckpt, 1, x)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(x["w"], np.float32))


def test_nan_guard_skips_update(tmp_ckpt):
    cfg, params, opt, step, ds = _tiny_setup()
    calls = {"n": 0}

    def poisoned(p, o, b, i):
        calls["n"] += 1
        p2, o2, m = step(p, o, b, i)
        if calls["n"] == 2:
            m = dict(m, loss=jnp.float32(float("nan")))
        return p2, o2, m

    tr = Trainer(poisoned, ds, TrainerConfig(
        total_steps=4, checkpoint_every=100, checkpoint_dir=tmp_ckpt,
        max_nan_skips=2))
    tr.run(params, opt)
    steps_logged = [h["step"] for h in tr.history]
    assert 1 not in steps_logged          # the poisoned step was skipped
    assert len(tr.history) == 3


def test_step_retry_on_failure(tmp_ckpt):
    cfg, params, opt, step, ds = _tiny_setup()
    boom = {"armed": True}

    def flaky(p, o, b, i):
        if boom["armed"] and int(i) == 2:
            boom["armed"] = False
            raise RuntimeError("simulated preemption")
        return step(p, o, b, i)

    tr = Trainer(flaky, ds, TrainerConfig(
        total_steps=4, checkpoint_every=2, checkpoint_dir=tmp_ckpt,
        max_step_retries=1))
    tr.run(params, opt)
    assert [h["step"] for h in tr.history][-1] == 3  # completed despite fail


def test_straggler_tracker_flags_outlier():
    t = _StragglerTracker(zscore=3.0, min_samples=10)
    for i in range(30):
        assert not t.observe(i, 1.0 + 0.01 * (i % 3))
    assert t.observe(31, 10.0)   # 10s step vs ~1s mean


def test_adamw_converges_quadratic():
    w = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, w)   # d/dw w^2
        w, st, _ = adamw_update(g, st, w, cfg)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.1


def test_grad_clip_and_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    assert abs(float(global_norm(g)) - 5.0) < 1e-6
    w = {"a": jnp.zeros(2)}
    st = adamw_init(w)
    _, _, m = adamw_update(g, st, w, AdamWConfig(grad_clip=1.0))
    assert abs(float(m["grad_norm"]) - 5.0) < 1e-5


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lrw = float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lre = float(cosine_schedule(100, peak_lr=1.0, warmup_steps=10,
                                total_steps=100, min_ratio=0.1))
    assert lr0 == 0.0 and abs(lrw - 1.0) < 1e-6 and abs(lre - 0.1) < 1e-6


def test_microbatch_equals_full_batch():
    """Gradient accumulation must match the single-batch gradient."""
    cfg = configs.get_smoke_config("phi3-medium-14b")
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((4, 16), jnp.float32)}
    s1 = make_train_step(cfg, TrainStepConfig(microbatches=1, remat=False))
    s2 = make_train_step(cfg, TrainStepConfig(microbatches=2, remat=False))
    p1, _, m1 = jax.jit(s1)(params, opt, batch, jnp.asarray(0))
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params), batch,
                            jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_data_pipeline_deterministic():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=100, seed=3)
    a = SyntheticDataset(cfg).batch_at(7)
    b = SyntheticDataset(cfg).batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = SyntheticDataset(cfg).batch_at(8)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
