"""Engine-backed flash attention: schedule parity across the config grid.

Mirrors the 3-schedule × 4-monoid sweep in ``test_scan_engine.py`` for
the SOFTMAX_PAIR carried-payload registration: the acceptance bar for
folding flash attention onto the scan engine (interpret mode on CPU) is

  * both fold schedules (carry / decoupled split-KV) match the dense
    oracle ``ref.py:mha_ref`` across {causal, sliding window, softcap,
    GQA group sizes, kv_len not a multiple of block_k, all-masked rows};
  * the schedules agree with each OTHER to tight tolerance (folds
    re-associate the payload rescaling at chunk boundaries, so parity is
    atol-tight rather than bitwise — unlike the element-monoid sweep);
  * the registration surface: registry entry, spec shape, the engine's
    transform/finalize dispatch, and the two-way attention policy rule.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scan import assoc, policy
from repro.kernels import scan_engine
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_kernel, pick_kv_splits)

SCHEDULES = ("carry", "decoupled")


def _rand_qkv(rng, B, Hq, Hkv, Tq, Tk, D, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, Hq, Tq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    return q, k, v


def _dense(q, k, v, **kw):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    return fa_ref.mha_ref(
        q.reshape(B * Hq, Tq, D), k.reshape(B * Hkv, Tk, D),
        v.reshape(B * Hkv, Tk, D), group=Hq // Hkv, **kw,
    ).reshape(B, Hq, Tq, D)


# ---------------------------------------------------------------------------
# schedule-parity sweep: 2 fold schedules x config grid, vs the dense oracle
# ---------------------------------------------------------------------------


CONFIGS = [
    # (name, B, Hkv, group, Tq, Tk, D, causal, window, softcap, bq, bk)
    ("causal", 2, 2, 1, 256, 256, 32, True, None, None, 128, 128),
    ("noncausal", 1, 2, 1, 256, 256, 32, False, None, None, 128, 128),
    ("window", 1, 2, 1, 256, 256, 32, True, 64, None, 64, 128),
    ("softcap", 1, 1, 1, 256, 256, 32, True, None, 30.0, 128, 128),
    ("gqa2", 2, 2, 2, 256, 256, 32, True, None, None, 128, 128),
    ("gqa4_window_cap", 1, 2, 4, 256, 256, 16, True, 96, 20.0, 128, 64),
    ("ragged_kv", 1, 2, 1, 300, 300, 32, True, None, None, 128, 128),
    ("ragged_kv_noncausal", 1, 1, 1, 200, 300, 16, False, None, None,
     128, 128),
]


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize(
    "cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_flash_engine_matches_dense(cfg, schedule):
    name, B, Hkv, g, Tq, Tk, D, causal, window, softcap, bq, bk = cfg
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    q, k, v = _rand_qkv(rng, B, Hkv * g, Hkv, Tq, Tk, D)
    got = fa_ops.flash_attention(
        q, k, v, scale=D ** -0.5, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, schedule=schedule,
        interpret=True)
    ref = _dense(q, k, v, scale=D ** -0.5, causal=causal, window=window,
                 softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize(
    "cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_flash_engine_cross_schedule_parity(cfg):
    """carry vs decoupled: the same fold re-associated at chunk
    boundaries only — atol-tight across the whole config grid."""
    name, B, Hkv, g, Tq, Tk, D, causal, window, softcap, bq, bk = cfg
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    q, k, v = _rand_qkv(rng, B, Hkv * g, Hkv, Tq, Tk, D)
    outs = [
        fa_ops.flash_attention(
            q, k, v, scale=D ** -0.5, causal=causal, window=window,
            softcap=softcap, block_q=bq, block_k=bk, schedule=s,
            interpret=True)
        for s in SCHEDULES
    ]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_flash_engine_all_masked_rows(schedule):
    """Rows whose whole KV band is masked (q positions beyond
    kv_len + window) must degrade to the uniform softmax — the finite
    NEG_INF mask keeps the max-carry NaN-free and matches the dense
    reference's exp(0) arithmetic exactly."""
    rng = np.random.default_rng(17)
    Tq = Tk = 256
    D, kv_len, window = 16, 64, 32
    q = jnp.asarray(rng.standard_normal((2, Tq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, Tk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, Tk, D)), jnp.float32)
    # rows >= kv_len + window see NO live key: fully masked
    got = flash_attention_kernel(
        q, k, v, scale=D ** -0.5, causal=True, window=window,
        kv_len=kv_len, block_q=64, block_k=64, schedule=schedule,
        interpret=True)
    ref = fa_ref.mha_ref(q, k, v, scale=D ** -0.5, causal=True,
                         window=window, kv_len=kv_len)
    assert not bool(jnp.any(jnp.isnan(got)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_flash_engine_bf16(schedule):
    rng = np.random.default_rng(13)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 128, 128, 32, jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, scale=32 ** -0.5,
                                 schedule=schedule, interpret=True)
    ref = _dense(q, k, v, scale=32 ** -0.5)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("splits", [1, 2, 4, 8])
def test_flash_engine_split_invariance(splits):
    """The decoupled fold must not depend on the chunk count."""
    rng = np.random.default_rng(7)
    q, k, v = _rand_qkv(rng, 1, 2, 1, 128, 1024, 16)
    ref = _dense(q, k, v, scale=0.25, causal=True)
    got = fa_ops.flash_attention(
        q, k, v, scale=0.25, causal=True, schedule="decoupled",
        kv_splits=splits, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_blockwise_ref_still_matches_engine():
    """The autodiff-able training-path oracle and the engine kernel are
    two statements of the same fold."""
    rng = np.random.default_rng(5)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 192, 192, 16)
    eng = fa_ops.flash_attention(q, k, v, scale=0.25, causal=True,
                                 schedule="carry", interpret=True)
    blk = fa_ref.blockwise_ref(
        q.reshape(2, 192, 16), k.reshape(2, 192, 16),
        v.reshape(2, 192, 16), scale=0.25, causal=True,
        block_k=64).reshape(1, 2, 192, 16)
    np.testing.assert_allclose(np.asarray(eng), np.asarray(blk),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# registration surface
# ---------------------------------------------------------------------------


def test_softmax_pair_registered_with_engine():
    assert "softmax_pair" in scan_engine.monoids.REGISTRY
    spec = scan_engine.monoids.REGISTRY["softmax_pair"]()
    assert isinstance(spec, assoc.KernelSpec)
    assert spec.n_leaves == 3              # (m, l, acc) payload triple
    assert spec.transform is not None and spec.finalize is not None
    assert not spec.supports_exclusive


def test_engine_rejects_bad_fold_requests():
    spec = assoc.softmax_pair_kernel_spec(scale=1.0)
    lay = scan_engine.KVBlocks(bh=2, bh_kv=2, tq=128, tk=128, d=16,
                               bq=128, bk=128)
    x = jnp.ones((2, 128, 16), jnp.float32)
    with pytest.raises(ValueError):
        scan_engine.scan((x, x, x), spec, lay, schedule="carry",
                         exclusive=True)
    with pytest.raises(ValueError):
        scan_engine.scan((x, x, x), spec, lay, schedule="carry",
                         return_totals=True)
    with pytest.raises(ValueError):
        scan_engine.KVBlocks(bh=3, bh_kv=2, tq=128, tk=128, d=16,
                             bq=128, bk=128)  # bh != bh_kv * group
    with pytest.raises(ValueError):
        scan_engine.KVBlocks(bh=2, bh_kv=2, tq=128, tk=512, d=16,
                             bq=128, bk=128, splits=3)  # 3 !| 4 blocks


def test_pick_kv_splits_divides():
    assert pick_kv_splits(8, 16) == 8
    assert pick_kv_splits(12, 8) == 6      # largest divisor <= target
    assert pick_kv_splits(7, 4) == 1       # prime block count
    assert pick_kv_splits(1) == 1


# ---------------------------------------------------------------------------
# policy: the two-way attention rule
# ---------------------------------------------------------------------------


def test_attention_policy_decode_vs_prefill():
    cores = policy.NUM_CORES
    # decode with few heads: rows < cores -> split-KV
    assert policy.choose_attention_schedule(cores // 2, 1 << 15) \
        == "decoupled"
    # long-KV scoring (32k at bk=128) with decode-class rows -> split-KV
    assert policy.choose_attention_schedule(4 * cores, 1 << 15) \
        == "decoupled"
    # same KV but fully saturated prefill rows -> carry
    assert policy.choose_attention_schedule(
        cores * policy.SPLIT_KV_ROW_CAP, 1 << 15) == "carry"
    # short KV, saturated rows -> carry
    assert policy.choose_attention_schedule(cores * 4, 2048) == "carry"


def test_attention_schedule_resolution_through_ops():
    # decode-class shape: B=1, 8 heads, one q position, 64k-token cache
    assert fa_ops.resolved_attention_schedule((1, 8, 1, 64), 1 << 16) \
        == "decoupled"
    # training/prefill-class shape: plenty of (head, q-block) rows
    assert fa_ops.resolved_attention_schedule((8, 16, 4096, 64), 4096) \
        == "carry"
    with pytest.raises(ValueError):
        fa_ops.resolved_attention_schedule((1, 8, 1, 64), 64,
                                           schedule="fused")


def test_decoupled_pads_prime_kv_block_counts():
    """The ops wrapper must achieve a real split count even when the raw
    KV block count is prime (the 500k-context class pads to 3907 blocks)
    — the KV axis is padded to a multiple of the target chunk count, and
    results still match the dense oracle on the unpadded kv_len."""
    from repro.kernels.flash_attention.ops import _decoupled_padding
    pad_k, splits = _decoupled_padding(7 * 128, 128, None)  # 7 blocks
    assert splits == 7 and pad_k == 0
    pad_k, splits = _decoupled_padding(17 * 128, 128, 16)   # prime 17
    assert splits == 16 and (17 * 128 + pad_k) // 128 % 16 == 0
    rng = np.random.default_rng(23)
    q, k, v = _rand_qkv(rng, 1, 2, 1, 128, 17 * 128, 16)
    got = fa_ops.flash_attention(q, k, v, scale=0.25, causal=False,
                                 schedule="decoupled", kv_splits=16,
                                 block_k=128, interpret=True)
    ref = _dense(q, k, v, scale=0.25, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
