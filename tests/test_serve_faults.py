"""Chaos wall + request-lifecycle tests for the hardened serve engine.

The contract under test (ISSUE 6): under seeded injection of step
errors, NaN logits, and stalls —

  * no request is lost or duplicated,
  * every submitted request terminates with exactly ONE finish reason,
  * undisturbed requests' outputs are BITWISE identical to a fault-free
    run (greedy decoding; per-row cache_len isolation makes a row's
    output independent of its co-residents).

Plus the lifecycle machinery on its own: admission control, deadlines,
cancel, prompt bucketing/compile bounds, NaN-guard trainer parity, and
the degradation ladder.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm as lm_mod
from repro.serve import (AdmissionError, Engine, EngineConfig,
                         EngineDeadlineError, FaultInjector, FaultSpec,
                         InjectedFault, Request)
from repro.train.step import init_params


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("stablelm-12b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(n, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 500, size=int(rng.integers(3, 9)))
            .astype(np.int32) for _ in range(n)]


def _run(cfg, params, prompts, ecfg, injector=None, max_ticks=200):
    eng = Engine(params, cfg, ecfg, injector=injector)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=max_ticks)
    eng.audit()
    return eng


def _chaos_ecfg(**kw):
    base = dict(max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
                temperature=0.0)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# the chaos wall: >= 3 injection schedules x both attn schedules
# ---------------------------------------------------------------------------

_BASELINES: dict = {}


def _baseline(cfg, params, prompts, attn_schedule):
    key = attn_schedule
    if key not in _BASELINES:
        eng = _run(cfg, params, prompts, _chaos_ecfg(
            attn_impl="flash", attn_schedule=attn_schedule))
        assert all(r.finish_reason in ("eos", "length_budget")
                   for r in eng.finished)
        _BASELINES[key] = {r.rid: list(r.output) for r in eng.finished}
    return _BASELINES[key]


@pytest.mark.parametrize("attn_schedule", ["carry", "decoupled"])
@pytest.mark.parametrize("cache_layout", ["contiguous", "paged"])
@pytest.mark.parametrize("fault_seed", [3, 11, 42])
def test_chaos_wall(small_model, attn_schedule, cache_layout, fault_seed):
    cfg, params = small_model
    prompts = _prompts(6)
    # The baseline is always the CONTIGUOUS fault-free run: paged decode
    # is bitwise identical at equal configs (ISSUE 8), so the paged axis
    # asserts cross-layout identity under injection for free.
    base = _baseline(cfg, params, prompts, attn_schedule)

    poison = [fault_seed % len(prompts)]
    inj = FaultInjector.from_seed(
        fault_seed, ticks=40, p_error=0.15, p_nan=0.15, p_stall=0.05,
        stall_s=0.002, poison_rids=poison)
    eng = _run(cfg, params, prompts, _chaos_ecfg(
        attn_impl="flash", attn_schedule=attn_schedule,
        cache_layout=cache_layout), injector=inj)

    # no request lost or duplicated; exactly one terminal state each
    rids = sorted(r.rid for r in eng.finished)
    assert rids == list(range(len(prompts)))
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert all(v is not None for v in reasons.values())

    # the poison request was quarantined, not the pool
    assert reasons[poison[0]] == "error"
    assert eng.stats.quarantined >= 1

    # undisturbed requests are bitwise identical to the fault-free run
    for r in eng.finished:
        if r.rid in poison or r.degraded or r.finish_reason == "error":
            continue
        assert r.output == base[r.rid], (
            f"rid {r.rid} diverged under injection: "
            f"{r.output} != {base[r.rid]}")

    # the injector actually exercised the machinery
    assert inj.fired_count() > 0


def test_chaos_wall_windowed_hybrid():
    """The chaos wall's windowed-paged axis (ISSUE 9): a gemma3-style
    local/global hybrid decodes entirely on pages — local rings riding
    the first window//page_size table entries, wrapping past the window
    — under fault injection. Undisturbed streams stay bitwise identical
    to the contiguous fault-free baseline."""
    cfg = configs.get_smoke_config("gemma3-12b")   # 5:1 local:global, w=32
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(1), cfg)
    prompts = _prompts(4, seed=5)
    ecfg = dict(max_slots=2, max_len=48, max_new_tokens=30, eos_id=-1,
                temperature=0.0)                   # lengths pass window 32
    base_eng = _run(cfg, params, prompts, EngineConfig(**ecfg))
    assert all(r.finish_reason in ("eos", "length_budget")
               for r in base_eng.finished)
    base = {r.rid: list(r.output) for r in base_eng.finished}

    poison = [1]
    inj = FaultInjector.from_seed(11, ticks=60, p_error=0.1, p_nan=0.1,
                                  p_stall=0.05, stall_s=0.002,
                                  poison_rids=poison)
    eng = _run(cfg, params, prompts,
               EngineConfig(cache_layout="paged", page_size=8, **ecfg),
               injector=inj, max_ticks=400)

    rids = sorted(r.rid for r in eng.finished)
    assert rids == list(range(len(prompts)))
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons[poison[0]] == "error"
    assert eng.stats.quarantined >= 1
    for r in eng.finished:
        if r.rid in poison or r.degraded or r.finish_reason == "error":
            continue
        assert r.output == base[r.rid], (
            f"rid {r.rid} diverged under injection on the hybrid: "
            f"{r.output} != {base[r.rid]}")
    assert inj.fired_count() > 0


def test_chaos_all_transient_recovers_everything(small_model):
    """With only transient (count=1) faults every request completes
    normally and every output matches the fault-free baseline."""
    cfg, params = small_model
    prompts = _prompts(6)
    base = _baseline(cfg, params, prompts, "carry")
    inj = FaultInjector([
        FaultSpec("error", op="any", tick=1, count=1),
        FaultSpec("nan", op="step", tick=3, count=1),
        FaultSpec("error", op="step", tick=5, count=1),
        FaultSpec("stall", op="any", tick=6, count=1, stall_s=0.002),
    ])
    eng = _run(cfg, params, prompts, _chaos_ecfg(
        attn_impl="flash", attn_schedule="carry"), injector=inj)
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert set(reasons.values()) <= {"eos", "length_budget"}
    for r in eng.finished:
        if not r.degraded:
            assert r.output == base[r.rid]
    assert eng.stats.step_retries + eng.stats.prefill_retries >= 1
    assert eng.stats.degradations >= 1          # the NaN tick degraded


# ---------------------------------------------------------------------------
# step-failure recovery: retry + bisection quarantine
# ---------------------------------------------------------------------------


def test_transient_step_error_is_retried(small_model):
    cfg, params = small_model
    prompts = _prompts(2)
    base_eng = _run(cfg, params, prompts, _chaos_ecfg())
    base = {r.rid: list(r.output) for r in base_eng.finished}
    inj = FaultInjector([FaultSpec("error", op="step", tick=2, count=1)])
    eng = _run(cfg, params, prompts, _chaos_ecfg(), injector=inj)
    assert eng.stats.step_retries == 1
    assert eng.stats.quarantined == 0
    assert {r.rid: list(r.output) for r in eng.finished} == base


def test_poison_request_is_bisected_out(small_model):
    cfg, params = small_model
    prompts = _prompts(4)
    base_eng = _run(cfg, params, prompts, _chaos_ecfg())
    base = {r.rid: list(r.output) for r in base_eng.finished}
    inj = FaultInjector([FaultSpec("error", op="step", rid=1, count=None)])
    eng = _run(cfg, params, prompts, _chaos_ecfg(), injector=inj)
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons[1] == "error"
    assert eng.stats.quarantined == 1
    assert eng.stats.probes >= 2
    for r in eng.finished:
        if r.rid != 1:
            assert r.output == base[r.rid]


def test_ambient_persistent_failure_raises():
    """A failure that reproduces with NO requests implicated must raise
    EngineStepError, not spin or silently drop the pool."""
    cfg = configs.get_smoke_config("stablelm-12b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    inj = FaultInjector([FaultSpec("error", op="step", count=None)])
    eng = Engine(params, cfg, _chaos_ecfg(), injector=inj)
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32)))
    from repro.serve import EngineStepError
    with pytest.raises(EngineStepError):
        eng.run_to_completion()


# ---------------------------------------------------------------------------
# numeric degradation ladder + trainer NaN-guard parity
# ---------------------------------------------------------------------------


def test_nan_tick_does_not_advance_lengths_or_budgets(small_model):
    """Trainer/serve parity: like trainer.py's non-finite-loss skip, an
    all-NaN tick must not advance lengths/budgets for ANY slot."""
    cfg, params = small_model
    inj = FaultInjector([FaultSpec("nan", op="step", tick=2, count=1)])
    eng = Engine(params, cfg, _chaos_ecfg(degrade_on_nonfinite=False),
                 injector=inj)
    for i, p in enumerate(_prompts(2)):
        eng.submit(Request(rid=i, prompt=p))
    eng.step()                                   # tick 1: admit + decode
    lengths = eng.lengths.copy()
    budgets = eng.budgets.copy()
    outs = [len(r.output) for r in eng.slot_req if r is not None]
    eng.step()                                   # tick 2: injected NaN
    assert eng.stats.nonfinite_ticks == 1
    assert eng.stats.skipped_ticks == 1
    np.testing.assert_array_equal(eng.lengths, lengths)
    np.testing.assert_array_equal(eng.budgets, budgets)
    assert [len(r.output) for r in eng.slot_req if r is not None] == outs
    eng.step()                                   # tick 3: clean again
    assert eng.lengths.sum() == lengths.sum() + 2


def test_nan_tick_degrades_and_recovers_bitwise(small_model):
    """With the ladder on, a NaN tick re-runs on the safe route; for a
    pure-attention model the math is identical, so outputs match the
    fault-free run bitwise and nothing is marked degraded."""
    cfg, params = small_model
    prompts = _prompts(3)
    base_eng = _run(cfg, params, prompts, _chaos_ecfg())
    base = {r.rid: list(r.output) for r in base_eng.finished}
    inj = FaultInjector([FaultSpec("nan", op="step", tick=2, count=1)])
    eng = _run(cfg, params, prompts, _chaos_ecfg(), injector=inj)
    assert eng.stats.nonfinite_ticks == 1
    assert eng.stats.degradations == 1
    assert eng.stats.skipped_ticks == 0
    assert {r.rid: list(r.output) for r in eng.finished} == base
    assert not any(r.degraded for r in eng.finished)


def test_persistent_nan_quarantines_after_streak(small_model):
    cfg, params = small_model
    inj = FaultInjector([FaultSpec("nan", op="step", count=None)])
    eng = _run(cfg, params, _prompts(2), _chaos_ecfg(
        degrade_on_nonfinite=False, max_consecutive_nan_ticks=2),
        injector=inj)
    assert all(r.finish_reason in ("error", "eos", "length_budget")
               for r in eng.finished)
    assert any(r.finish_reason == "error" for r in eng.finished)
    assert eng.stats.skipped_ticks >= 2


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_oversized_prompt_rejected_fast(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, _chaos_ecfg(max_len=12, max_new_tokens=20))
    req = Request(rid=0, prompt=np.arange(6, dtype=np.int32))
    assert eng.submit(req) is False
    assert req.finish_reason == "rejected"
    assert "cannot complete" in req.error
    with pytest.raises(AdmissionError):
        eng.submit(Request(rid=1, prompt=np.arange(6, dtype=np.int32)),
                   strict=True)
    eng.audit()


def test_bounded_queue_reject_policy(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, _chaos_ecfg(max_waiting=2))
    reqs = [Request(rid=i, prompt=np.arange(3, dtype=np.int32))
            for i in range(4)]
    results = [eng.submit(r) for r in reqs]
    assert results == [True, True, False, False]
    assert reqs[2].finish_reason == "rejected"
    assert "queue full" in reqs[2].error
    eng.run_to_completion()
    assert sorted(r.rid for r in eng.finished) == [0, 1, 2, 3]


def test_bounded_queue_block_policy(small_model):
    """policy="block" drives the engine until the queue drains instead
    of rejecting — every request completes."""
    cfg, params = small_model
    eng = Engine(params, cfg, _chaos_ecfg(
        max_waiting=1, admission_policy="block"))
    for i in range(4):
        assert eng.submit(Request(
            rid=i, prompt=np.arange(3, dtype=np.int32))) is True
    eng.run_to_completion()
    assert sorted(r.rid for r in eng.finished) == [0, 1, 2, 3]
    assert all(r.finish_reason == "length_budget" for r in eng.finished)


# ---------------------------------------------------------------------------
# deadlines + cancel
# ---------------------------------------------------------------------------


def test_per_request_ttl_expires_waiting_and_active(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, _chaos_ecfg(
        max_slots=1, max_new_tokens=20))
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32)))
    # stuck behind rid 0 on the single slot; expires while waiting
    eng.submit(Request(rid=1, prompt=np.arange(3, dtype=np.int32),
                       deadline_ticks=2))
    eng.run_to_completion()
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons[1] == "deadline"
    assert reasons[0] == "length_budget"
    # active-slot TTL: engine-wide deadline cuts generation short
    eng2 = Engine(params, cfg, _chaos_ecfg(
        max_slots=1, max_new_tokens=30, deadline_ticks=3))
    eng2.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32)))
    eng2.run_to_completion()
    assert eng2.finished[0].finish_reason == "deadline"
    assert 0 < len(eng2.finished[0].output) < 30


def test_cancel_waiting_and_active(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, _chaos_ecfg(max_slots=1, max_new_tokens=10))
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32)))
    eng.submit(Request(rid=1, prompt=np.arange(3, dtype=np.int32)))
    eng.step()                       # rid 0 active, rid 1 waiting
    assert eng.cancel(1) is True     # cancel from the waiting queue
    assert eng.cancel(0) is True     # cancel the active slot
    assert eng.cancel(99) is False
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons == {0: "cancelled", 1: "cancelled"}
    assert eng.step() == 0           # pool is empty again
    eng.audit()


# ---------------------------------------------------------------------------
# prompt bucketing + prefill-variant bounds
# ---------------------------------------------------------------------------


def test_bucketing_bounds_prefill_compiles(small_model):
    """Prompts of length 3/5/6/7 share ONE pow2 bucket (8): a single
    prefill variant is jitted, and outputs match the unbucketed engine
    bitwise."""
    cfg, params = small_model
    prompts = [np.arange(2, 2 + n, dtype=np.int32) for n in (3, 5, 6, 7)]
    eng_b = _run(cfg, params, prompts, _chaos_ecfg(bucket_prompts=True))
    assert eng_b.stats.prefill_compiles == 1
    eng_u = _run(cfg, params, prompts, _chaos_ecfg(bucket_prompts=False))
    assert eng_u.stats.prefill_compiles == 4     # one per distinct length
    assert ({r.rid: list(r.output) for r in eng_b.finished}
            == {r.rid: list(r.output) for r in eng_u.finished})


def test_prefill_variant_cache_is_capped(small_model):
    cfg, params = small_model
    prompts = [np.arange(2, 2 + n, dtype=np.int32) for n in (3, 4, 5)]
    eng = _run(cfg, params, prompts, _chaos_ecfg(
        bucket_prompts=False, max_prefill_variants=2))
    assert eng.stats.prefill_compiles == 3
    assert eng.stats.prefill_cache_evictions == 1
    assert len(eng._prefill_cache) <= 2


def test_bucketing_gated_off_for_recurrent_models():
    """Pad tokens would corrupt SSM recurrent state: bucketable() must
    refuse hybrid patterns and the engine must fall back to exact-length
    prefill."""
    from repro.serve import bucketable
    cfg = configs.get_smoke_config("zamba2-7b")
    assert not bucketable(cfg)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, _chaos_ecfg(
        max_slots=1, bucket_prompts=True))
    assert eng._bucketed is False
    eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32)))
    eng.run_to_completion()
    assert len(eng.finished[0].output) == 5


# ---------------------------------------------------------------------------
# per-row isolation (what underwrites the bitwise-identity invariant)
# ---------------------------------------------------------------------------


def test_heterogeneous_lengths_isolated_per_row(small_model):
    """Rows with different prompt lengths sharing the pool decode exactly
    as they would alone — per-row cache_len gives each its own positions
    and masking extent."""
    cfg, params = small_model
    prompts = [np.asarray([3, 5, 7], np.int32),
               np.asarray([11, 13, 17, 19, 23, 29], np.int32)]
    solo = {}
    for i, p in enumerate(prompts):
        eng = _run(cfg, params, [p], _chaos_ecfg(max_slots=1))
        solo[i] = list(eng.finished[0].output)
    joint = _run(cfg, params, prompts, _chaos_ecfg(max_slots=2))
    for r in joint.finished:
        assert list(r.output) == solo[r.rid], (
            f"rid {r.rid}: co-resident changed my tokens")


# ---------------------------------------------------------------------------
# injector unit behavior
# ---------------------------------------------------------------------------


def test_injector_from_seed_is_deterministic():
    a = FaultInjector.from_seed(9, ticks=32, p_error=0.2, p_nan=0.2)
    b = FaultInjector.from_seed(9, ticks=32, p_error=0.2, p_nan=0.2)
    assert a.specs == b.specs
    c = FaultInjector.from_seed(10, ticks=32, p_error=0.2, p_nan=0.2)
    assert a.specs != c.specs


def test_injector_count_budget_and_rid_gating():
    from repro.serve import StepContext
    inj = FaultInjector([
        FaultSpec("error", op="step", rid=7, count=2),
    ])

    def fn(params, tokens, cache, cache_len):
        return jnp.zeros((1, 4)), cache

    wrapped = inj.wrap_step(fn)
    args = (None, None, None, None)
    inj.begin(StepContext(tick=0, rids=(1, 2), op="step"))
    wrapped(*args)                               # rid 7 absent: no fire
    for _ in range(2):
        inj.begin(StepContext(tick=1, rids=(1, 7), op="step"))
        with pytest.raises(InjectedFault):
            wrapped(*args)
    inj.begin(StepContext(tick=2, rids=(1, 7), op="step"))
    wrapped(*args)                               # budget exhausted
    assert inj.fired_count("error") == 2


def test_injector_nan_poisons_targeted_row():
    from repro.serve import StepContext
    inj = FaultInjector([FaultSpec("nan", op="step", rid=5, count=1)])

    def fn(params, tokens, cache, cache_len):
        return jnp.zeros((3, 4)), cache

    wrapped = inj.wrap_step(fn)
    inj.begin(StepContext(tick=0, rids=(4, 5), op="step",
                          rows={4: 0, 5: 2}))
    logits, _ = wrapped(None, None, None, None)
    assert bool(jnp.isnan(logits[2]).all())
    assert bool(jnp.isfinite(logits[0]).all())


def test_sampling_maps_nan_to_neg_inf():
    from repro.serve import sample_logits
    logits = jnp.asarray([[1.0, jnp.nan, 0.5]])
    tok = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.0)
    assert int(tok[0]) == 0                      # NaN cannot win argmax
    tok = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.7,
                        top_p=0.9)
    assert int(tok[0]) != 1                      # nor enter the nucleus
