"""Pallas kernels vs their pure-jnp oracles (interpret mode on CPU).

Per assignment: sweep shapes/dtypes per kernel and assert_allclose
against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.scan_blocked import ops as sb_ops
from repro.kernels.ssm_scan import ops as ssm_ops


# ---------------------------------------------------------------------------
# scan_blocked: VMEM-partitioned cumsum (paper §2.2 on TPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 128), (4, 1024), (8, 4096), (3, 517),
                                   (16, 2048), (2, 8192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_scan_blocked_shapes_dtypes(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(-9, 9, shape), dtype)
    else:
        x = jnp.asarray(rng.standard_normal(shape), dtype)
    got = sb_ops.cumsum(x, axis=-1, interpret=True)
    ref = jnp.cumsum(x.astype(jnp.float32), axis=-1)
    tol = 0.15 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("block_n", [128, 256, 2048])
def test_scan_blocked_block_invariance(block_n):
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((4, 4096)), jnp.float32)
    got = sb_ops.cumsum(x, axis=-1, block_n=block_n, interpret=True)
    ref = jnp.cumsum(x, axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_scan_blocked_exclusive_and_axis():
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((5, 300)), jnp.float32)
    got = sb_ops.cumsum(x, axis=0, exclusive=True, interpret=True)
    inc = jnp.cumsum(x, axis=0)
    ref = jnp.concatenate([jnp.zeros_like(x[:1]), inc[:-1]], axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_scan_blocked_3d():
    x = jnp.asarray(
        np.random.default_rng(2).standard_normal((2, 3, 640)), jnp.float32)
    got = sb_ops.cumsum(x, axis=-1, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.cumsum(np.asarray(x), -1), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssm_scan: chunked affine scan (Mamba2/xLSTM recurrence)
# ---------------------------------------------------------------------------


def _ssm_ref(a, b):
    def step(h, ab):
        h = ab[0] * h + ab[1]
        return h, h
    _, hs = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                         (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


@pytest.mark.parametrize("shape", [(1, 64, 128), (2, 256, 512), (3, 100, 64),
                                   (1, 1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_shapes_dtypes(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = jnp.asarray(rng.uniform(0.7, 1.0, shape), dtype)
    b = jnp.asarray(rng.standard_normal(shape) * 0.1, dtype)
    got = ssm_ops.ssm_scan(a, b, interpret=True)
    ref = _ssm_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    tol = 0.1 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("block_t", [32, 128, 512])
def test_ssm_scan_block_invariance(block_t):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (2, 512, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 512, 128)), jnp.float32)
    got = ssm_ops.ssm_scan(a, b, block_t=block_t, interpret=True)
    ref = _ssm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_ssm_scan_vs_core_affine():
    """Kernel and core-library AFFINE scans agree (two implementations of
    the same monoid)."""
    from repro.core import scan as scanlib
    rng = np.random.default_rng(8)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (1, 200, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 200, 32)), jnp.float32)
    got = ssm_ops.ssm_scan(a, b, interpret=True)
    _, hb = scanlib.scan((a, b), "affine", axis=1, algorithm="blocked",
                         block_size=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(hb), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# flash_attention: online-softmax scan kernel
# ---------------------------------------------------------------------------


def _rand_qkv(rng, B, Hq, Hkv, T, D, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("T", [128, 256, 300])
@pytest.mark.parametrize("gqa", [1, 4])
def test_flash_matches_dense(T, gqa):
    rng = np.random.default_rng(T * gqa)
    B, Hkv, D = 2, 2, 32
    q, k, v = _rand_qkv(rng, B, Hkv * gqa, Hkv, T, D)
    got = fa_ops.flash_attention(q, k, v, scale=D ** -0.5, interpret=True)
    ref = fa_ref.mha_ref(
        q.reshape(B * Hkv * gqa, T, D), k.reshape(B * Hkv, T, D),
        v.reshape(B * Hkv, T, D), group=gqa, scale=D ** -0.5,
    ).reshape(B, Hkv * gqa, T, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_window_softcap(window, softcap):
    rng = np.random.default_rng(11)
    B, H, T, D = 1, 2, 256, 32
    q, k, v = _rand_qkv(rng, B, H, H, T, D)
    got = fa_ops.flash_attention(
        q, k, v, scale=D ** -0.5, window=window, softcap=softcap,
        interpret=True)
    ref = fa_ref.mha_ref(
        q.reshape(B * H, T, D), k.reshape(B * H, T, D),
        v.reshape(B * H, T, D), group=1, scale=D ** -0.5, window=window,
        softcap=softcap).reshape(B, H, T, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_blockwise_ref_matches_dense_and_grads():
    """The training-path blockwise scan: values AND gradients match."""
    rng = np.random.default_rng(12)
    BH, T, D = 4, 192, 16
    q = jnp.asarray(rng.standard_normal((BH, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, T, D)), jnp.float32)

    f_block = lambda q, k, v: jnp.sum(
        fa_ref.blockwise_ref(q, k, v, scale=0.25, block_k=64) ** 2)
    f_dense = lambda q, k, v: jnp.sum(
        fa_ref.mha_ref(q, k, v, scale=0.25) ** 2)
    np.testing.assert_allclose(f_block(q, k, v), f_dense(q, k, v), rtol=1e-4)
    g_block = jax.grad(f_block, argnums=(0, 1, 2))(q, k, v)
    g_dense = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    for gb, gd in zip(g_block, g_dense):
        np.testing.assert_allclose(np.asarray(gb), np.asarray(gd),
                                   rtol=1e-3, atol=1e-3)


def test_flash_bf16():
    rng = np.random.default_rng(13)
    B, H, T, D = 1, 1, 128, 32
    q, k, v = _rand_qkv(rng, B, H, H, T, D, jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, scale=D ** -0.5, interpret=True)
    ref = fa_ref.mha_ref(
        q.reshape(H, T, D), k.reshape(H, T, D), v.reshape(H, T, D),
        group=1, scale=D ** -0.5).reshape(B, H, T, D)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# segscan: segmented prefix sum (paper §1 partitioning primitive on-chip)
# ---------------------------------------------------------------------------


from repro.kernels.segscan import ops as seg_ops
from repro.kernels.segscan import ref as seg_ref


@pytest.mark.parametrize("shape", [(1, 128), (4, 1024), (3, 517),
                                   (2, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_segscan_shapes_dtypes(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    if dtype == jnp.int32:
        v = jnp.asarray(rng.integers(-9, 9, shape), dtype)
    else:
        v = jnp.asarray(rng.standard_normal(shape), dtype)
    f = jnp.asarray(rng.random(shape) < 0.05, jnp.int32)
    got = seg_ops.segmented_cumsum(v, f, interpret=True)
    ref = seg_ref.segmented_cumsum_ref(v, f)
    tol = 0.15 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("block_n", [128, 512])
def test_segscan_block_invariance_and_cross_block_segments(block_n):
    """Segments spanning block boundaries must carry correctly, and a
    flag INSIDE a later block must kill the incoming carry."""
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.standard_normal((2, 1024)), jnp.float32)
    f = jnp.zeros((2, 1024), jnp.int32)
    # one segment start mid-block-2, none in block 1 => carry must cross
    f = f.at[:, 0].set(1).at[0, 700].set(1).at[1, 130].set(1)
    got = seg_ops.segmented_cumsum(v, f, block_n=block_n, interpret=True)
    ref = seg_ref.segmented_cumsum_ref(v, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_segscan_matches_core_segmented():
    """Kernel and core-library segmented scans agree."""
    from repro.core import scan as scanlib
    rng = np.random.default_rng(6)
    v = jnp.asarray(rng.standard_normal(512), jnp.float32)
    f = jnp.asarray(rng.random(512) < 0.1, jnp.int32)
    got = seg_ops.segmented_cumsum(v, f, interpret=True)
    want = scanlib.segmented_scan(v, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_segscan_no_flags_equals_cumsum():
    v = jnp.asarray(np.random.default_rng(7).standard_normal((2, 300)),
                    jnp.float32)
    f = jnp.zeros((2, 300), jnp.int32)
    got = seg_ops.segmented_cumsum(v, f, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.cumsum(np.asarray(v), -1),
                               rtol=1e-4, atol=1e-4)
