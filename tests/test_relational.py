"""Relational operator subsystem: reference-semantics property tests.

Every operator is checked against its ground truth: ``filter_compact``
vs boolean-mask indexing, ``radix_sort`` vs ``jnp.sort``/stable
``np.argsort``, ``group_by`` vs ``jax.ops.segment_sum``/numpy folds,
``hash_join`` vs the nested-loop join — across dtypes and the
empty / all-true / all-false predicate edges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import relational as rel

KEY_DTYPES = ("int32", "int16", "uint8", "uint32", "float32", "float16",
              "bool")


def _draw_keys(rng, dtype, n):
    if dtype == "bool":
        return rng.integers(0, 2, n).astype(bool)
    dt = np.dtype(dtype)
    if dt.kind == "f":
        vals = rng.standard_normal(n) * 100
        return vals.astype(dt)
    info = np.iinfo(dt)
    return rng.integers(info.min, int(info.max) + 1, n).astype(dt)


# ---------------------------------------------------------------------------
# filter / stream compaction
# ---------------------------------------------------------------------------


@given(st.lists(st.booleans(), min_size=0, max_size=300))
@settings(max_examples=30, deadline=None)
def test_filter_compact_matches_boolean_mask(mask):
    mask = np.asarray(mask, bool)
    T = len(mask)
    values = np.arange(10, 10 + T, dtype=np.int32)
    out, count = rel.filter_compact(jnp.asarray(values), jnp.asarray(mask))
    want = values[mask]
    assert int(count) == len(want)
    assert out.shape == (T,)
    np.testing.assert_array_equal(np.asarray(out)[: len(want)], want)
    np.testing.assert_array_equal(np.asarray(out)[len(want):], 0)


@pytest.mark.parametrize("predicate", ["empty", "all_true", "all_false"])
def test_filter_compact_predicate_edges(predicate):
    T = 0 if predicate == "empty" else 64
    mask = jnp.full((T,), predicate == "all_true", bool)
    values = jnp.arange(T, dtype=jnp.int32)
    for algorithm in ("ref", "kernel"):
        out, count = rel.filter_compact(values, mask, algorithm=algorithm,
                                        interpret=True)
        want = np.asarray(values)[np.asarray(mask)]
        assert int(count) == len(want), (predicate, algorithm)
        np.testing.assert_array_equal(np.asarray(out)[: len(want)], want)


@given(st.integers(1, 400), st.floats(0.0, 1.0))
@settings(max_examples=10, deadline=None)
def test_compact_kernel_matches_ref(n, sel):
    """Fused Pallas kernel (decoupled mask scan) == library scan path."""
    rng = np.random.default_rng(n)
    mask = jnp.asarray(rng.random(n) < sel)
    dest_r, count_r = rel.compact_indices(mask, algorithm="ref")
    dest_k, count_k = rel.compact_indices(mask, algorithm="kernel",
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(dest_k), np.asarray(dest_r))
    assert int(count_k) == int(count_r)


def test_filter_compact_capacity_and_fill():
    values = jnp.arange(8, dtype=jnp.int32)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], bool)
    out, count = rel.filter_compact(values, mask, size=3, fill_value=-7)
    assert int(count) == 6  # true survivor count, beyond the cap
    np.testing.assert_array_equal(np.asarray(out), [0, 2, 3])
    out2, _ = rel.filter_compact(values, mask, size=8, fill_value=-7)
    np.testing.assert_array_equal(np.asarray(out2)[6:], -7)


def test_filter_compact_size_exceeds_input():
    """size > T must not leak dropped values through the T sentinel."""
    values = jnp.asarray([1, 2, 3], jnp.int32)
    mask = jnp.asarray([True, False, False])
    out, count = rel.filter_compact(values, mask, size=5)
    assert int(count) == 1
    np.testing.assert_array_equal(np.asarray(out), [1, 0, 0, 0, 0])


def test_mask_compact_kernel_zero_sized_batch():
    from repro.kernels.compact import mask_compact
    dest, counts = mask_compact(jnp.zeros((0, 5), bool))
    assert dest.shape == (0, 5) and counts.shape == (0,)


def test_filter_compact_2d_rows():
    rng = np.random.default_rng(3)
    values = jnp.asarray(rng.standard_normal((20, 5)), jnp.float32)
    mask = jnp.asarray(rng.random(20) < 0.5)
    out, count = rel.filter_compact(values, mask)
    want = np.asarray(values)[np.asarray(mask)]
    np.testing.assert_array_equal(np.asarray(out)[: int(count)], want)


# ---------------------------------------------------------------------------
# partition
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 6), min_size=0, max_size=200))
@settings(max_examples=20, deadline=None)
def test_radix_partition_stable(ids):
    ids = np.asarray(ids, np.int32)
    payload = np.arange(len(ids), dtype=np.int32)
    plan, part_ids, part_payload = rel.radix_partition(
        jnp.asarray(ids), 7, jnp.asarray(payload))
    if len(ids) == 0:
        assert np.asarray(part_ids).shape == (0,)
        return
    # bucket-contiguous and stable == numpy stable argsort by bucket
    order = np.argsort(ids, kind="stable")
    np.testing.assert_array_equal(np.asarray(part_ids), ids[order])
    np.testing.assert_array_equal(np.asarray(part_payload), payload[order])
    np.testing.assert_array_equal(
        np.asarray(plan.counts), np.bincount(ids, minlength=7))


# ---------------------------------------------------------------------------
# sort
# ---------------------------------------------------------------------------


@given(st.sampled_from(KEY_DTYPES), st.integers(0, 300))
@settings(max_examples=24, deadline=None)
def test_radix_sort_matches_jnp_sort(dtype, n):
    rng = np.random.default_rng(n + 1)
    keys = _draw_keys(rng, dtype, n)
    got = rel.radix_sort(jnp.asarray(keys))
    assert got.dtype == jnp.asarray(keys).dtype
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.sort(jnp.asarray(keys))))


@given(st.integers(0, 200))
@settings(max_examples=20, deadline=None)
def test_argsort_stable(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 8, n).astype(np.int32)  # heavy ties
    perm = rel.argsort(jnp.asarray(keys))
    np.testing.assert_array_equal(
        np.asarray(perm), np.argsort(keys, kind="stable"))


def test_radix_sort_payload_reordered():
    keys = jnp.asarray([5, 1, 4, 1, 3], jnp.int32)
    payload = jnp.asarray([[0, 0], [1, 1], [2, 2], [3, 3], [4, 4]],
                          jnp.float32)
    sk, sp = rel.radix_sort(keys, payload)
    np.testing.assert_array_equal(np.asarray(sk), [1, 1, 3, 4, 5])
    np.testing.assert_array_equal(np.asarray(sp)[:, 0], [1, 3, 4, 2, 0])


def test_radix_sort_duplicates_and_extremes():
    keys = jnp.asarray([0, -(2 ** 31), 2 ** 31 - 1, 0, -1, 1, -(2 ** 31)],
                       jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(rel.radix_sort(keys)), np.sort(np.asarray(keys)))
    fkeys = jnp.asarray([0.0, -0.0, jnp.inf, -jnp.inf, 1e-38, -1e38],
                        jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(rel.radix_sort(fkeys)), np.sort(np.asarray(fkeys)))


# ---------------------------------------------------------------------------
# group-by
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 5), min_size=0, max_size=200))
@settings(max_examples=20, deadline=None)
def test_group_by_sum_matches_segment_sum(ids):
    G = 6
    ids = np.asarray(ids, np.int32)
    rng = np.random.default_rng(len(ids))
    values = rng.integers(-50, 50, len(ids)).astype(np.int32)
    got = rel.group_by(jnp.asarray(ids), jnp.asarray(values), G, "sum")
    want = jax.ops.segment_sum(jnp.asarray(values), jnp.asarray(ids),
                               num_segments=G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_group_by_float_sum_close():
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 4, 100), jnp.int32)
    values = jnp.asarray(rng.standard_normal(100), jnp.float32)
    got = rel.group_by(ids, values, 4, "sum")
    want = jax.ops.segment_sum(values, ids, num_segments=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("agg", ["max", "min", "count", "mean"])
def test_group_by_aggs_vs_numpy(agg):
    rng = np.random.default_rng(1)
    G = 5
    ids = rng.integers(0, G, 80)
    values = rng.integers(-100, 100, 80).astype(np.int32)
    got = np.asarray(rel.group_by(jnp.asarray(ids, jnp.int32),
                                  jnp.asarray(values), G, agg))
    for g in range(G):
        vals = values[ids == g]
        if agg == "count":
            assert got[g] == len(vals)
        elif len(vals) == 0:
            ident = {"max": np.iinfo(np.int32).min,
                     "min": np.iinfo(np.int32).max, "mean": 0.0}[agg]
            assert got[g] == ident
        elif agg == "mean":
            np.testing.assert_allclose(got[g], vals.mean(), rtol=1e-6)
        else:
            assert got[g] == {"max": vals.max, "min": vals.min}[agg]()


def test_group_by_vector_values():
    ids = jnp.asarray([0, 1, 0, 2], jnp.int32)
    values = jnp.asarray([[1, 2], [3, 4], [5, 6], [7, 8]], jnp.int32)
    got = rel.group_by(ids, values, 3, "sum")
    np.testing.assert_array_equal(np.asarray(got),
                                  [[6, 8], [3, 4], [7, 8]])


def test_group_by_kernel_path_matches_segment_sum():
    """The segscan-kernel route (long runs on TPU; forced here) must agree
    with ``jax.ops.segment_sum`` — bit-exactly for integer values."""
    rng = np.random.default_rng(7)
    G, T = 9, 4096
    ids = jnp.asarray(rng.integers(0, G, T), jnp.int32)
    vals_i = jnp.asarray(rng.integers(-50, 50, T), jnp.int32)
    got = rel.group_by(ids, vals_i, G, "sum", algorithm="kernel")
    want = jax.ops.segment_sum(vals_i, ids, num_segments=G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    vals_f = jnp.asarray(rng.standard_normal(T), jnp.float32)
    got_f = rel.group_by(ids, vals_f, G, "mean", algorithm="kernel")
    want_f = jax.ops.segment_sum(vals_f, ids, num_segments=G) / \
        jnp.maximum(jax.ops.segment_sum(jnp.ones_like(vals_f), ids,
                                        num_segments=G), 1)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=1e-4, atol=1e-4)
    # vector values ride the kernel's row layout
    vals_v = jnp.asarray(rng.integers(-9, 9, (T, 3)), jnp.int32)
    got_v = rel.group_by(ids, vals_v, G, "sum", algorithm="kernel")
    want_v = jax.ops.segment_sum(vals_v, ids, num_segments=G)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_group_by_auto_gate_is_policy_thresholded():
    """Off-TPU auto stays on the library scan; the gate itself follows
    ``policy.choose`` (kernel only past the VMEM block budget)."""
    from repro.core.scan import policy
    from repro.relational.groupby import _seg_algorithm
    small = policy.VMEM_BLOCK_BUDGET // 4 // 2  # f32 elems, half budget
    big = policy.VMEM_BLOCK_BUDGET // 4 * 2
    assert _seg_algorithm("ref", "sum", big, 4) == "ref"
    assert _seg_algorithm("kernel", "sum", small, 4) == "kernel"
    if jax.default_backend() == "tpu":
        assert _seg_algorithm("auto", "sum", big, 4) == "kernel"
        assert _seg_algorithm("auto", "sum", small, 4) == "ref"
    else:
        assert _seg_algorithm("auto", "sum", big, 4) == "ref"
    assert _seg_algorithm("auto", "max", big, 4) == "ref"  # non-sum monoid
    with pytest.raises(ValueError):
        _seg_algorithm("bogus", "sum", big, 4)


@given(st.lists(st.integers(-20, 20), min_size=0, max_size=150))
@settings(max_examples=20, deadline=None)
def test_group_by_sorted_runs(raw):
    keys = np.sort(np.asarray(raw, np.int32))
    rng = np.random.default_rng(len(keys))
    values = rng.integers(0, 10, len(keys)).astype(np.int32)
    uniq, aggs, count = rel.group_by_sorted(
        jnp.asarray(keys), jnp.asarray(values), "sum")
    n = int(count)
    if len(keys) == 0:
        assert n == 0
        return
    uref, inv = np.unique(keys, return_inverse=True)
    aref = np.zeros(len(uref), np.int64)
    np.add.at(aref, inv, values)
    assert n == len(uref)
    np.testing.assert_array_equal(np.asarray(uniq)[:n], uref)
    np.testing.assert_array_equal(np.asarray(aggs)[:n].astype(np.int64),
                                  aref)


# ---------------------------------------------------------------------------
# join
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 8), min_size=0, max_size=60),
       st.lists(st.integers(0, 8), min_size=0, max_size=60))
@settings(max_examples=15, deadline=None)
def test_hash_join_matches_nested_loop(lk, rk):
    res = rel.hash_join(jnp.asarray(lk, jnp.int32),
                        jnp.asarray(rk, jnp.int32))
    c = int(res.count)
    got = sorted(zip(np.asarray(res.left_index)[:c].tolist(),
                     np.asarray(res.right_index)[:c].tolist()))
    want = sorted((i, j) for i, a in enumerate(lk)
                  for j, b in enumerate(rk) if a == b)
    assert got == want
    # padding past count is -1
    assert (np.asarray(res.left_index)[c:] == -1).all()


def test_hash_join_capped_and_jittable():
    lk = jnp.asarray([1, 2, 3, 2], jnp.int32)
    rk = jnp.asarray([2, 2, 9], jnp.int32)
    jit_join = jax.jit(lambda a, b: rel.hash_join(a, b, max_matches=16))
    res = jit_join(lk, rk)
    assert int(res.count) == 4
    c = int(res.count)
    got = sorted(zip(np.asarray(res.left_index)[:c].tolist(),
                     np.asarray(res.right_index)[:c].tolist()))
    assert got == [(1, 0), (1, 1), (3, 0), (3, 1)]
    # cap smaller than the match count still reports the true total
    res2 = rel.hash_join(lk, rk, max_matches=2)
    assert int(res2.count) == 4
    assert res2.left_index.shape == (2,)


def test_hash_join_overflow_guard():
    """An eager join whose pair count wraps int32 must raise, not
    silently return garbage (x64 mode accumulates in int64 instead) —
    both under the default histogram bound and the exact-count path."""
    if jax.config.jax_enable_x64:
        pytest.skip("int64 accumulation active; no wrap to guard")
    n = 66_000  # n*n ≈ 4.36e9: wraps mod 2^32 back to a POSITIVE int32
    keys = jnp.zeros((n,), jnp.int32)
    with pytest.raises(OverflowError):
        rel.hash_join(keys, keys)  # default "auto" bound
    with pytest.raises(OverflowError):
        rel.hash_join(keys, keys, max_matches=None)  # exact path


def test_hash_join_auto_capacity_is_spill_safe():
    """The default histogram-product capacity must dominate the true
    match count for a SKEWED key distribution — no pair ever dropped —
    unlike an undersized manual cap."""
    rng = np.random.default_rng(11)
    # heavy skew: most keys collide on a handful of values
    lk = jnp.asarray(rng.integers(0, 4, 300), jnp.int32)
    rk = jnp.asarray(rng.integers(0, 6, 200), jnp.int32)
    bound = rel.estimate_max_matches(lk, rk)
    res = rel.hash_join(lk, rk)  # default: auto bound
    c = int(res.count)
    assert res.left_index.shape[0] == bound >= c
    lkn, rkn = np.asarray(lk), np.asarray(rk)
    want = sorted((i, j) for i, a in enumerate(lkn)
                  for j, b in enumerate(rkn) if a == b)
    got = sorted(zip(np.asarray(res.left_index)[:c].tolist(),
                     np.asarray(res.right_index)[:c].tolist()))
    assert got == want                      # nothing spilled
    assert (np.asarray(res.left_index)[c:] == -1).all()
    # regression: an undersized manual cap DOES drop pairs (count still
    # reports the true total) — the failure mode "auto" exists to remove
    res_small = rel.hash_join(lk, rk, max_matches=5)
    assert int(res_small.count) == len(want)
    assert res_small.left_index.shape == (5,)


def test_estimate_max_matches_float_and_empty():
    assert rel.estimate_max_matches(
        jnp.zeros((0,), jnp.int32), jnp.zeros((3,), jnp.int32)) == 0
    lk = jnp.asarray([0.5, -1.25, 3.0, 0.5], jnp.float32)
    rk = jnp.asarray([3.0, 0.5, 0.5], jnp.float32)
    bound = rel.estimate_max_matches(lk, rk)
    res = rel.hash_join(lk, rk)
    assert bound >= int(res.count) == 5


def test_hash_join_auto_under_jit_raises():
    lk = jnp.asarray([1, 2], jnp.int32)
    with pytest.raises(ValueError):
        jax.jit(lambda a, b: rel.hash_join(a, b))(lk, lk)


def test_group_by_count_shape_with_vector_values():
    """agg="count" is (G,) for empty and non-empty batches alike."""
    full = rel.group_by(jnp.asarray([0, 2], jnp.int32),
                        jnp.ones((2, 3), jnp.float32), 4, "count")
    empty = rel.group_by(jnp.zeros((0,), jnp.int32),
                         jnp.ones((0, 3), jnp.float32), 4, "count")
    assert full.shape == empty.shape == (4,)
    np.testing.assert_array_equal(np.asarray(full), [1, 0, 1, 0])


def test_hash_join_float_keys():
    lk = jnp.asarray([0.5, -1.25, 3.0], jnp.float32)
    rk = jnp.asarray([3.0, 0.5, 0.5], jnp.float32)
    res = rel.hash_join(lk, rk)
    c = int(res.count)
    got = sorted(zip(np.asarray(res.left_index)[:c].tolist(),
                     np.asarray(res.right_index)[:c].tolist()))
    assert got == [(0, 1), (0, 2), (2, 0)]


def test_hash_join_rejects_mixed_key_dtypes():
    with pytest.raises(TypeError):
        rel.hash_join(jnp.asarray([1.0, 2.0], jnp.float32),
                      jnp.asarray([1, 2], jnp.int32))


def test_hash_join_float_nan_and_signed_zero():
    """NaN keys match nothing (even a build NaN that radix-orders before
    -inf must not corrupt the search for real keys); -0.0 matches +0.0."""
    neg_nan = np.frombuffer(np.uint32(0xFFC00000).tobytes(),
                            np.float32)[0]
    lk = jnp.asarray([-1.0, 0.5, 2.0, np.nan, 0.0], jnp.float32)
    rk = jnp.asarray([neg_nan, -1.0, 0.5, 2.0, -0.0], jnp.float32)
    res = rel.hash_join(lk, rk)
    c = int(res.count)
    got = sorted(zip(np.asarray(res.left_index)[:c].tolist(),
                     np.asarray(res.right_index)[:c].tolist()))
    assert got == [(0, 1), (1, 2), (2, 3), (4, 4)]


# ---------------------------------------------------------------------------
# consumers stay routed through the subsystem
# ---------------------------------------------------------------------------


def test_moe_and_engine_route_through_relational():
    import inspect

    from repro.models.layers import moe
    from repro.serve import engine
    assert "partition_plan" in inspect.getsource(moe)
    assert "rel_compact" in inspect.getsource(engine)
