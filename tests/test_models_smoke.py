"""Per-arch smoke tests: reduced same-family config, one forward/train
step on CPU, asserting output shapes + no NaNs (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec, lm
from repro.optim import adamw_init
from repro.train.step import TrainStepConfig, init_params, make_train_step


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend_tokens:
        batch["embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, 1024))

    # forward: shapes + finiteness
    if cfg.is_encdec:
        hidden, _ = encdec.decode_forward(
            params, toks, encdec.encode(params, batch["embeds"], cfg), cfg)
    else:
        hidden, _, _ = lm.forward(params, toks, cfg,
                                  embeds=batch.get("embeds"))
        if cfg.frontend_tokens:
            assert hidden.shape == (B, cfg.frontend_tokens + S, cfg.d_model)
            hidden = hidden[:, cfg.frontend_tokens:]
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())

    # one real optimizer step: loss finite, params move
    step = jax.jit(make_train_step(
        cfg, TrainStepConfig(remat=False, total_steps=10,
                             warmup_steps=1)))
    opt = adamw_init(params)
    p1, o1, metrics = step(params, opt, batch, jnp.asarray(1))
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, p1)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ["gemma2-9b", "zamba2-7b", "xlstm-125m",
                                  "granite-moe-1b-a400m"])
def test_arch_decode_matches_forward(arch):
    """Prefill+decode must equal the full forward pass (cache exactness),
    covering KV ring buffers (gemma SWA), SSM states (zamba2), xLSTM
    states, and MoE decode."""
    cfg = configs.get_smoke_config(arch)
    # f32 for exactness; capacity high enough that the full forward drops
    # no token (dropped tokens legitimately differ between a 50-token
    # forward and a 2-token decode — that is capacity routing, not a bug).
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    from repro.models.layers.embedding import lm_logits
    hidden, _, _ = lm.forward(params, toks, cfg)
    want = lm_logits(params, hidden[:, -1:], cfg)[:, 0]

    logits, cache = lm.prefill(params, toks[:, :S], cfg, max_len=S + 8)
    got, _ = lm.decode_step(params, toks[:, S:], cache,
                            jnp.asarray(S, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_gemma2_softcaps_active():
    cfg = configs.get_smoke_config("gemma2-9b")
    assert cfg.attn_softcap == 50.0 and cfg.final_softcap == 30.0
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    hidden, _, _ = lm.forward(params, toks, cfg)
    from repro.models.layers.embedding import lm_logits
    logits = lm_logits(params, hidden, cfg)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0 + 1e-3


def test_full_configs_match_assignment():
    """Exact values from the assignment table."""
    want = {
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for arch, (L, d, h, kv, ff, v) in want.items():
        cfg = configs.get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    moe = configs.get_config("granite-moe-1b-a400m")
    assert (moe.num_experts, moe.top_k) == (32, 8)
    qwen = configs.get_config("qwen3-moe-235b-a22b")
    assert (qwen.num_experts, qwen.top_k) == (128, 8)
    zamba = configs.get_config("zamba2-7b")
    assert zamba.ssm_state == 64
    seam = configs.get_config("seamless-m4t-large-v2")
    assert seam.encoder_layers == 24


def test_layer_patterns_tile():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        if not cfg.is_encdec:
            periods, rem = cfg.pattern_periods
            assert rem == 0, f"{arch}: pattern must tile num_layers"
        assert configs.get_smoke_config(arch).family == cfg.family


def test_long_context_skips_documented():
    from repro.configs.shapes import LONG_CONTEXT_ARCHS, cells
    assert "gemma3-12b" in LONG_CONTEXT_ARCHS       # SWA-bounded
    assert "xlstm-125m" in LONG_CONTEXT_ARCHS       # recurrent
    assert "zamba2-7b" in LONG_CONTEXT_ARCHS        # hybrid
    assert "phi3-medium-14b" not in LONG_CONTEXT_ARCHS  # pure full attn
    assert len(cells("phi3-medium-14b")) == 3
    assert len(cells("gemma3-12b")) == 4
    total = sum(len(cells(a)) for a in configs.ARCHS)
    assert total == 34  # 30 base + 4 long-context rows
