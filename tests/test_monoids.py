"""Monoid-law property tests (hypothesis): the algebra every algorithm
in the package relies on. If these fail, nothing else is trustworthy."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scan import assoc

_f = st.floats(-10, 10, width=32)
_pos = st.floats(0.125, 2.0, width=32)


def _close(a, b, tol=1e-3):
    np.testing.assert_allclose(
        np.asarray(jnp.asarray(a), np.float64),
        np.asarray(jnp.asarray(b), np.float64), rtol=tol, atol=tol)


def _tclose(ta, tb, tol=1e-3):
    import jax
    for a, b in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
        _close(a, b, tol)


@pytest.mark.parametrize("name", ["sum", "max", "min", "prod"])
@given(x=_f, y=_f, z=_f)
@settings(max_examples=40, deadline=None)
def test_scalar_monoid_associativity(name, x, y, z):
    m = assoc.get(name)
    a, b, c = (jnp.float32(v) for v in (x, y, z))
    _tclose(m.combine(m.combine(a, b), c), m.combine(a, m.combine(b, c)))


@pytest.mark.parametrize("name", ["sum", "max", "min", "prod"])
@given(x=_f)
@settings(max_examples=20, deadline=None)
def test_scalar_monoid_identity(name, x):
    m = assoc.get(name)
    a = jnp.float32(x)
    e = m.identity_like(a)
    _tclose(m.combine(e, a), a)
    _tclose(m.combine(a, e), a)


@given(a1=_pos, b1=_f, a2=_pos, b2=_f, a3=_pos, b3=_f)
@settings(max_examples=40, deadline=None)
def test_affine_associativity(a1, b1, a2, b2, a3, b3):
    m = assoc.AFFINE
    e1 = (jnp.float32(a1), jnp.float32(b1))
    e2 = (jnp.float32(a2), jnp.float32(b2))
    e3 = (jnp.float32(a3), jnp.float32(b3))
    _tclose(m.combine(m.combine(e1, e2), e3),
            m.combine(e1, m.combine(e2, e3)), tol=1e-2)


@given(a=_pos, b=_f)
@settings(max_examples=20, deadline=None)
def test_affine_identity(a, b):
    m = assoc.AFFINE
    e = (jnp.float32(a), jnp.float32(b))
    ident = m.identity_like(e)
    _tclose(m.combine(ident, e), e)
    _tclose(m.combine(e, ident), e)


@given(m1=_f, s1=_pos, m2=_f, s2=_pos, m3=_f, s3=_pos)
@settings(max_examples=40, deadline=None)
def test_softmax_pair_associativity(m1, s1, m2, s2, m3, s3):
    m = assoc.SOFTMAX_PAIR
    e1 = (jnp.float32(m1), jnp.float32(s1))
    e2 = (jnp.float32(m2), jnp.float32(s2))
    e3 = (jnp.float32(m3), jnp.float32(s3))
    _tclose(m.combine(m.combine(e1, e2), e3),
            m.combine(e1, m.combine(e2, e3)), tol=1e-2)


def test_softmax_pair_equals_logsumexp():
    """Scanning the softmax-pair monoid = running (max, sumexp)."""
    import jax
    from repro.core.scan import reference
    xs = jnp.asarray(np.random.default_rng(0).standard_normal(64),
                     jnp.float32)
    elems = (xs, jnp.ones_like(xs))
    m_run, s_run = reference.scan_ref(elems, assoc.SOFTMAX_PAIR, axis=0)
    lse = np.asarray(m_run) + np.log(np.asarray(s_run))
    want = [float(jax.nn.logsumexp(xs[: i + 1])) for i in range(64)]
    np.testing.assert_allclose(lse, want, rtol=1e-5, atol=1e-5)


def test_fold_order_preserved_noncommutative():
    """Monoid.fold must respect operand order (affine is non-commutative)."""
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.uniform(0.5, 1.5, 13), jnp.float32)
    b = jnp.asarray(rng.standard_normal(13), jnp.float32)
    fa, fb = assoc.AFFINE.fold((a, b), axis=0)
    # sequential left fold
    sa, sb = jnp.float32(1.0), jnp.float32(0.0)
    for i in range(13):
        sa, sb = assoc.AFFINE.combine((sa, sb), (a[i], b[i]))
    _close(fa, sa, 1e-4)
    _close(fb, sb, 1e-4)


@given(st.lists(st.tuples(st.booleans(), _f), min_size=1, max_size=60))
@settings(max_examples=25, deadline=None)
def test_segmented_lift_matches_python(pairs):
    """Segmented-sum scan == python loop with resets."""
    from repro.core.scan import reference
    flags = jnp.asarray([int(f) for f, _ in pairs], jnp.int32)
    vals = jnp.asarray([v for _, v in pairs], jnp.float32)
    seg = assoc.segmented(assoc.SUM)
    _, out = reference.scan_ref((flags, vals), seg, axis=0)
    acc, want = 0.0, []
    for f, v in pairs:
        acc = v if f else acc + v
        want.append(acc)
    np.testing.assert_allclose(np.asarray(out, np.float64), want,
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# registry-wide law sweep: every entry in assoc.REGISTRY, including
# SOFTMAX_PAIR and MATRIX_AFFINE (runs under tests/_hypothesis_fallback.py
# when the real hypothesis is absent)
# ---------------------------------------------------------------------------


def _element_for(name, rng_vals):
    """Build one monoid element for ``name`` from 4 drawn floats."""
    x, y, z, w = (jnp.float32(v) for v in rng_vals)
    if name in ("sum", "max", "min", "prod"):
        return x
    if name == "affine":
        return (jnp.abs(x) + jnp.float32(0.125), y)
    if name == "matrix_affine":
        # scalar decay broadcasting over a (2, 2) matrix update
        a = jnp.abs(x) + jnp.float32(0.125)
        B = jnp.stack([jnp.stack([y, z]), jnp.stack([z, w])])
        return (jnp.broadcast_to(a, (2, 2)), B)
    if name == "softmax_pair":
        return (x, jnp.abs(y) + jnp.float32(0.125))
    raise AssertionError(f"unhandled registry monoid {name!r}")


_quad = st.tuples(_f, _f, _f, _f)


@pytest.mark.parametrize("name", sorted(assoc.REGISTRY))
@given(e1=_quad, e2=_quad, e3=_quad)
@settings(max_examples=25, deadline=None)
def test_registry_monoid_associativity(name, e1, e2, e3):
    m = assoc.REGISTRY[name]
    a, b, c = (_element_for(name, e) for e in (e1, e2, e3))
    _tclose(m.combine(m.combine(a, b), c), m.combine(a, m.combine(b, c)),
            tol=1e-2)


@pytest.mark.parametrize("name", sorted(assoc.REGISTRY))
@given(e=_quad)
@settings(max_examples=15, deadline=None)
def test_registry_monoid_identity(name, e):
    m = assoc.REGISTRY[name]
    a = _element_for(name, e)
    ident = m.identity_like(a)
    _tclose(m.combine(ident, a), a)
    _tclose(m.combine(a, ident), a)


# ---------------------------------------------------------------------------
# the NEG_INF finite-mask invariant (softmax max-carry edge elements)
# ---------------------------------------------------------------------------


_maybe_masked = st.sampled_from(["live", "masked"])


@given(k1=_maybe_masked, k2=_maybe_masked, k3=_maybe_masked,
       e1=_quad, e2=_quad, e3=_quad)
@settings(max_examples=25, deadline=None)
def test_softmax_pair_neg_inf_edges_stay_finite(k1, k2, k3, e1, e2, e3):
    """Fully-masked blocks enter the fold as (NEG_INF, bk) elements; any
    mix of masked/live operands must combine NaN-free and associatively
    — this is what the kernels' finite NEG_INF (vs a true -inf) buys."""
    m = assoc.SOFTMAX_PAIR

    def elem(kind, vals):
        mm, ss = _element_for("softmax_pair", vals)
        if kind == "masked":
            mm = jnp.float32(assoc.NEG_INF)
        return (mm, ss)

    a, b, c = elem(k1, e1), elem(k2, e2), elem(k3, e3)
    left = m.combine(m.combine(a, b), c)
    right = m.combine(a, m.combine(b, c))
    for leaf in (*left, *right):
        assert not bool(jnp.isnan(leaf)), (k1, k2, k3)
    _tclose(left, right, tol=1e-2)


def test_neg_inf_finite_sentinel_vs_true_inf():
    """Why NEG_INF is finite: a true -inf max-carry NaNs the rescale
    (``-inf - -inf``); the -1e30 sentinel keeps exp(0)=1 arithmetic."""
    m = assoc.SOFTMAX_PAIR
    masked = (jnp.float32(assoc.NEG_INF), jnp.float32(4.0))
    out = m.combine(masked, masked)
    assert not any(bool(jnp.isnan(leaf)) for leaf in out)
    np.testing.assert_allclose(float(out[1]), 8.0)  # exp(0) = 1 arithmetic
    inf_masked = (jnp.float32(-jnp.inf), jnp.float32(4.0))
    out_inf = m.combine(inf_masked, inf_masked)
    assert bool(jnp.isnan(out_inf[1]))  # the failure the sentinel avoids


# ---------------------------------------------------------------------------
# kernel-side carried payload: the (m, l, acc) triple of the flash spec
# ---------------------------------------------------------------------------


def _payload_elem(vals, masked=False):
    x, y, z, w = (jnp.float32(v) for v in vals)
    mm = jnp.float32(assoc.NEG_INF) if masked else x
    ll = jnp.abs(y) + jnp.float32(0.125)
    acc = jnp.stack([z, w])
    return (mm[None], ll[None], acc)


@given(k1=_maybe_masked, k2=_maybe_masked, k3=_maybe_masked,
       e1=_quad, e2=_quad, e3=_quad)
@settings(max_examples=25, deadline=None)
def test_softmax_payload_triple_associativity(k1, k2, k3, e1, e2, e3):
    """The kernel spec's combine carries the weighted-value accumulator
    alongside the (m, l) pair; the lifted triple must stay associative
    (including NEG_INF masked operands) or the split-KV decoupled fold
    would diverge from the carry chain."""
    spec = assoc.softmax_pair_kernel_spec(scale=1.0)
    a = _payload_elem(e1, k1 == "masked")
    b = _payload_elem(e2, k2 == "masked")
    c = _payload_elem(e3, k3 == "masked")
    left = spec.combine(spec.combine(a, b), c)
    right = spec.combine(a, spec.combine(b, c))
    for leaf in (*left, *right):
        assert not bool(jnp.any(jnp.isnan(leaf)))
    _tclose(left, right, tol=1e-2)


@given(e=_quad)
@settings(max_examples=15, deadline=None)
def test_softmax_payload_identity_fills(e):
    """The spec's fills (NEG_INF, 0, 0) are a two-sided identity — the
    fold seeds and the chunk chain rely on it."""
    spec = assoc.softmax_pair_kernel_spec(scale=1.0)
    a = _payload_elem(e)
    ident = tuple(jnp.full_like(leaf, f)
                  for leaf, f in zip(a, spec.fills))
    _tclose(spec.combine(ident, a), a)
    _tclose(spec.combine(a, ident), a)
