"""Backward as a scan: the kernel family's custom VJPs vs reference autodiff.

``cumsum`` / ``segmented_cumsum`` / ``ssm_scan`` each carry a
``jax.custom_vjp`` whose backward is ONE MORE engine scan — the flipped
scan of the incoming cotangent with transposed/rolled gates — instead of
autodiff through the Pallas kernel. The wall here:

  * ``jax.grad`` through each wrapper matches differentiating the jnp
    reference to float tolerance, across shapes, dtypes, both exclusive
    modes, and every differentiable monoid;
  * the backward really executes on the engine: with tracing enabled, a
    grad computation emits ``kernel.launch`` instants for the backward
    compilation too, not just the forward.

Degenerate (empty) inputs keep gradients well-defined via the wrappers'
early-return guards.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scan import reference
from repro.kernels.scan_blocked import ops as sb_ops
from repro.kernels.segscan import ops as seg_ops
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.obs import trace

SHAPES = [(1, 256), (3, 1024), (2, 4096)]


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 1e-4


def _assert_grads_close(g, g_ref, dtype):
    ref = np.asarray(g_ref, np.float64)
    # bf16 grads of long sums cross zero with large RELATIVE error even
    # when absolutely tiny — scale the absolute floor by the grad range.
    atol = _tol(dtype) * max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(
        np.asarray(g, np.float64), ref, rtol=_tol(dtype), atol=atol)


# ---------------------------------------------------------------------------
# cumsum: dx = flip(cumsum(flip(g)))  (same exclusive flag)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("exclusive", [False, True])
@pytest.mark.parametrize("shape", SHAPES)
def test_cumsum_grad_matches_reference(shape, exclusive, dtype):
    rng = np.random.default_rng(30)
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    w = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def loss_kernel(x):
        out = sb_ops.cumsum(x, exclusive=exclusive, interpret=True)
        return jnp.sum(out.astype(jnp.float32) * w)

    def loss_ref(x):
        out = reference.cumsum_ref(x.astype(jnp.float32),
                                   exclusive=exclusive)
        return jnp.sum(out * w)

    g = jax.grad(loss_kernel)(x)
    g_ref = jax.grad(loss_ref)(x)
    assert g.dtype == x.dtype
    _assert_grads_close(g, g_ref, dtype)


# ---------------------------------------------------------------------------
# segmented: dvalues = flip(segscan(flip(g), flip(shift_left(flags))))
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_segmented_grad_matches_reference(shape, dtype):
    rng = np.random.default_rng(31)
    v = jnp.asarray(rng.standard_normal(shape), dtype)
    f = jnp.asarray(rng.random(shape) < 0.05, jnp.int32)
    w = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def loss_kernel(v):
        out = seg_ops.segmented_cumsum(v, f, interpret=True)
        return jnp.sum(out.astype(jnp.float32) * w)

    def loss_ref(v):
        out = reference.segmented_scan_ref(v.astype(jnp.float32), f)
        return jnp.sum(out * w)

    g = jax.grad(loss_kernel)(v)
    g_ref = jax.grad(loss_ref)(v)
    assert g.dtype == v.dtype
    _assert_grads_close(g, g_ref, dtype)


def test_segmented_grad_flag_boundaries():
    """Gradients must not leak across segment boundaries: an element's
    cotangent reaches exactly its own segment's prefix positions."""
    v = jnp.zeros((8,), jnp.float32)
    f = jnp.asarray([0, 0, 0, 1, 0, 0, 1, 0], jnp.int32)

    def pick(v, i):
        return seg_ops.segmented_cumsum(v, f, interpret=True)[i]

    # d out[5] / d v: positions 3..5 (its segment so far), nothing else
    g = jax.grad(pick)(v, 5)
    np.testing.assert_array_equal(
        np.asarray(g), [0, 0, 0, 1, 1, 1, 0, 0])
    # d out[2] / d v: head segment only
    g = jax.grad(pick)(v, 2)
    np.testing.assert_array_equal(
        np.asarray(g), [1, 1, 1, 0, 0, 0, 0, 0])


# ---------------------------------------------------------------------------
# ssm (affine): lambda_t = g_t + a_{t+1} lambda_{t+1}; da = lambda * h_{t-1}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 8), (2, 512, 16), (3, 1024, 4)])
def test_ssm_grad_matches_reference(shape, dtype):
    rng = np.random.default_rng(32)
    a = jnp.asarray(rng.uniform(0.6, 1.0, shape), dtype)
    b = jnp.asarray(rng.standard_normal(shape) * 0.1, dtype)
    w = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def loss_kernel(a, b):
        h = ssm_ops.ssm_scan(a, b, interpret=True)
        return jnp.sum(h.astype(jnp.float32) * w)

    def loss_ref(a, b):
        _, h = reference.scan_ref(
            (a.astype(jnp.float32), b.astype(jnp.float32)), "affine",
            axis=1)
        return jnp.sum(h * w)

    ga, gb = jax.grad(loss_kernel, argnums=(0, 1))(a, b)
    ga_ref, gb_ref = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    assert ga.dtype == a.dtype and gb.dtype == b.dtype
    _assert_grads_close(gb, gb_ref, dtype)
    _assert_grads_close(ga, ga_ref, dtype)


def test_ssm_grad_per_schedule():
    """The backward engine scan honors the caller's schedule choice —
    grads agree across all four organizations."""
    rng = np.random.default_rng(33)
    a = jnp.asarray(rng.uniform(0.6, 1.0, (2, 512, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 512, 8)), jnp.float32)

    def loss(a, b, schedule):
        h = ssm_ops.ssm_scan(a, b, interpret=True, schedule=schedule)
        return jnp.sum(h * h)

    grads = [jax.grad(loss, argnums=(0, 1))(a, b, s)
             for s in ("carry", "decoupled", "fused", "tree")]
    for ga, gb in grads[1:]:
        np.testing.assert_allclose(np.asarray(ga), np.asarray(grads[0][0]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(grads[0][1]),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the backward really runs on the engine (trace evidence), empties
# ---------------------------------------------------------------------------


def test_backward_launches_engine_kernels():
    """kernel.launch instants fire for the BACKWARD compilation: a grad
    through ssm_scan must add affine launches beyond the forward's, and a
    grad through cumsum adds sum launches."""
    tracer = trace.enable()
    try:
        rng = np.random.default_rng(34)
        # Launch instants fire once per COMPILATION, and the backward
        # scan deliberately reuses the forward's jitted impl (same
        # shapes, same statics). So: never warm any shape used here —
        # a forward-only call on a fresh shape compiles once, and a
        # fresh grad compiles the forward-under-AD AND the backward.
        a = jnp.asarray(rng.uniform(0.6, 1.0, (1, 320, 8)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((1, 320, 8)), jnp.float32)

        tracer.clear()
        ssm_ops.ssm_scan(a, b, interpret=True)
        fwd = [e for e in tracer.events() if e["name"] == "kernel.launch"
               and e["args"]["monoid"] == "affine"]
        assert len(fwd) == 1

        tracer.clear()
        a2, b2 = a[:, :192], b[:, :192]        # fresh shape for the grad
        jax.grad(lambda a, b: jnp.sum(
            ssm_ops.ssm_scan(a, b, interpret=True) ** 2),
            argnums=(0, 1))(a2, b2)
        both = [e for e in tracer.events() if e["name"] == "kernel.launch"
                and e["args"]["monoid"] == "affine"]
        assert len(both) >= 2, \
            "grad must launch the engine for the backward scan too"

        tracer.clear()
        x = jnp.asarray(rng.standard_normal((1, 320)), jnp.float32)
        jax.grad(lambda x: jnp.sum(
            sb_ops.cumsum(x, interpret=True) ** 2))(x)
        sums = [e for e in tracer.events() if e["name"] == "kernel.launch"
                and e["args"]["monoid"] == "sum"]
        assert len(sums) >= 2, "forward AND backward cumsum launches"
    finally:
        trace.disable()


def test_empty_inputs_have_grads():
    x = jnp.zeros((2, 0), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(
        sb_ops.cumsum(x, interpret=True)))(x)
    assert g.shape == (2, 0)
    a = jnp.zeros((2, 0, 4), jnp.float32)
    ga, gb = jax.grad(lambda a, b: jnp.sum(
        ssm_ops.ssm_scan(a, b, interpret=True)), argnums=(0, 1))(a, a)
    assert ga.shape == (2, 0, 4) and gb.shape == (2, 0, 4)
