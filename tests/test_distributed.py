"""Multi-device tests — run in subprocesses so the main pytest process
keeps its single CPU device (the dry-run flag must not leak, per spec)."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 420):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        if not hasattr(jax, "shard_map"):  # jax <= 0.4.37 compat
            from repro.dist.sharding import shard_map as _sm
            jax.shard_map = _sm
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"))
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


@pytest.mark.parametrize("variant", [1, 2])
@pytest.mark.parametrize("exchange", ["all_gather", "hillis_permute",
                                      "ring"])
def test_scan_sharded_matches_ref(variant, exchange):
    """The paper's multithreaded two-pass scan with devices as threads."""
    out = _run(f"""
        from repro.core import scan as scanlib
        mesh = jax.make_mesh((8,), ("d",))
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal(4096), jnp.float32)
        spec = P("d")
        xs = jax.device_put(x, NamedSharding(mesh, spec))
        y = scanlib.scan_sharded(
            xs, "sum", mesh=mesh, axis_name="d", spec=spec,
            variant={variant}, carry_exchange="{exchange}",
            local_algorithm="blocked", block_size=256)
        ref = np.cumsum(np.asarray(x), dtype=np.float64)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_scan_sharded_affine_monoid():
    """Distributed SSM-style affine scan (sequence parallelism carry)."""
    out = _run("""
        from repro.core import scan as scanlib
        mesh = jax.make_mesh((4,), ("d",))
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.uniform(0.8, 1.0, (512,)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((512,)), jnp.float32)
        spec = P("d")
        sh = NamedSharding(mesh, spec)
        y_a, y_b = scanlib.scan_sharded(
            (jax.device_put(a, sh), jax.device_put(b, sh)), "affine",
            mesh=mesh, axis_name="d", spec=spec,
            carry_exchange="hillis_permute", local_algorithm="ref")
        h, want = 0.0, []
        an, bn = np.asarray(a), np.asarray(b)
        for i in range(512):
            h = an[i] * h + bn[i]
            want.append(h)
        np.testing.assert_allclose(np.asarray(y_b), want, rtol=2e-3,
                                   atol=2e-3)
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_scan_sharded_exclusive():
    out = _run("""
        from repro.core import scan as scanlib
        mesh = jax.make_mesh((8,), ("d",))
        x = jnp.arange(1, 257, dtype=jnp.float32)
        spec = P("d")
        xs = jax.device_put(x, NamedSharding(mesh, spec))
        y = scanlib.scan_sharded(xs, "sum", mesh=mesh, axis_name="d",
                                 spec=spec, exclusive=True)
        ref = np.concatenate([[0.0], np.cumsum(np.asarray(x))[:-1]])
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_error_feedback():
    """int8 gradient compression: biased per step, unbiased with EF."""
    out = _run("""
        from repro.optim.compression import compressed_psum
        mesh = jax.make_mesh((4,), ("d",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)

        def worker(x, err):
            red, new_err = compressed_psum(x[0], "d", err[0])
            return red[None], new_err[None]

        fn = jax.shard_map(worker, mesh=mesh, in_specs=(P("d"), P("d")),
                           out_specs=(P("d"), P("d")))
        err = jnp.zeros_like(g)
        exact = np.asarray(jnp.sum(g, 0))
        # step 1: quantized sum close to exact; residual nonzero
        red, err = fn(g, err)
        q_err1 = np.abs(np.asarray(red[0]) - exact).max()
        assert q_err1 < 0.1, q_err1
        # EF: summed (reduced + carried error) over repeated steps -> the
        # accumulated average converges to the exact sum
        acc = np.zeros(64)
        err = jnp.zeros_like(g)
        for _ in range(50):
            red, err = fn(g, err)
            acc += np.asarray(red[0])
        np.testing.assert_allclose(acc / 50, exact, atol=0.02)
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """The dry-run path end-to-end on an 8-device debug mesh (structure
    identical to the 256/512-chip production run)."""
    out = _run("""
        import jax.numpy as jnp
        from repro import configs
        from repro.dist import sharding as shd
        from repro.train.step import (TrainStepConfig, make_train_step,
                                      shardings_for, init_params)
        from repro.optim import adamw_init
        cfg = configs.get_smoke_config("granite-moe-1b-a400m")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        params_s = jax.eval_shape(lambda k: init_params(k, cfg), key)
        opt_s = jax.eval_shape(adamw_init, params_s)
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32),
            "mask": jax.ShapeDtypeStruct((4, 64), jnp.float32),
        }
        with shd.use_mesh(mesh):
            step = make_train_step(cfg, TrainStepConfig(remat=True))
            in_sh, out_sh = shardings_for(mesh, params_s, opt_s, batch)
            low = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(0, 1)).lower(
                params_s, opt_s, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
            comp = low.compile()
        cost = comp.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        text = comp.as_text()
        assert any(op in text for op in
                   ("all-reduce", "all-gather", "reduce-scatter"))
        print("OK")
    """)
    assert "OK" in out


def test_distributed_train_step_executes():
    """Actually EXECUTE a sharded train step on 8 CPU devices and compare
    the loss with the single-device run (SPMD correctness, not just
    compilation)."""
    out = _run("""
        import dataclasses
        import jax.numpy as jnp
        from repro import configs
        from repro.dist import sharding as shd
        from repro.optim import adamw_init
        from repro.train.step import (TrainStepConfig, make_train_step,
                                      shardings_for, init_params)
        cfg = dataclasses.replace(
            configs.get_smoke_config("gemma2-9b"), dtype="float32")
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        opt = adamw_init(params)
        toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks,
                 "mask": jnp.ones((4, 32), jnp.float32)}
        step = make_train_step(cfg, TrainStepConfig(remat=False))
        # single device reference
        _, _, m_ref = jax.jit(step)(params, opt, batch, jnp.asarray(0))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.use_mesh(mesh):
            in_sh, out_sh = shardings_for(mesh, params, opt, batch)
            jstep = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            pp = jax.device_put(params, in_sh[0])
            oo = jax.device_put(opt, in_sh[1])
            bb = jax.device_put(dict(batch), in_sh[2])
            _, _, m = jstep(pp, oo, bb, jnp.asarray(0))
        np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                                   rtol=2e-4)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_moe_dispatch_on_mesh():
    """The per-shard MoE dispatch (beyond-paper opt) must (a) execute on a
    real data×model mesh and (b) agree with the G=1 global dispatch when
    capacity is unconstrained (no drops ⇒ identical math, different
    partitioning)."""
    out = _run("""
        import dataclasses, os
        import jax.numpy as jnp
        from repro import configs
        from repro.dist import sharding as shd
        from repro.models.layers.moe import apply_moe, init_moe
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="t", family="moe", d_model=32, num_heads=4,
                          num_kv_heads=4, head_dim=8, d_ff=64, moe_d_ff=64,
                          vocab_size=128, num_experts=4, top_k=2,
                          capacity_factor=8.0, dtype="float32")
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
        y_ref, aux_ref = apply_moe(params, x, cfg)   # no mesh -> G=1

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with shd.use_mesh(mesh):
            y_sh, aux_sh = jax.jit(
                lambda p, v: apply_moe(p, v, cfg))(params, x)
        np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        assert float(aux_sh.dropped_fraction) == 0.0
        print("OK")
    """)
    assert "OK" in out
