"""Paged KV cache tests (ISSUE 8).

Three walls:

  * allocator/page-table properties — the prefix-sum allocator never
    double-allocates, free -> alloc roundtrips, exhaustion is explicit
    (None + counter), defrag plans are stable partitions;
  * engine parity — decode on the paged layout is BITWISE identical to
    the contiguous layout at equal configs (token streams), chunked
    prefill is bitwise identical to one-shot on the dense route, and
    defrag mid-run does not change a single token;
  * paged semantics — admission backpressure (requests wait, none are
    lost), mid-decode allocator exhaustion surfaces as ``cache_full``,
    and the observability gauges/counters fire.

Plus the scan-engine page-indirection map: ``KVBlocks.kv_block_map``
feeds a block-permuted KV pool through the flash fold bitwise.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.obs.metrics import Registry
from repro.serve import (Engine, EngineConfig, PageAllocator, PageTable,
                         Request, pages_for)
from repro.train.step import init_params


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("stablelm-12b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(n, seed=7, lo=3, hi=9):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 500, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _ecfg(**kw):
    base = dict(max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
                temperature=0.0)
    base.update(kw)
    return EngineConfig(**base)


def _run(cfg, params, prompts, ecfg, max_ticks=300):
    eng = Engine(params, cfg, ecfg)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=max_ticks)
    eng.audit()
    return eng


def _outputs(eng):
    return {r.rid: list(r.output) for r in eng.finished}


# ---------------------------------------------------------------------------
# allocator / page-table properties
# ---------------------------------------------------------------------------


def test_allocator_never_double_allocates():
    rng = np.random.default_rng(0)
    alloc = PageAllocator(33, 8)
    held = []
    seen = set()
    for _ in range(200):
        if held and rng.random() < 0.45:
            pages = held.pop(int(rng.integers(len(held))))
            alloc.release(pages)
            seen.difference_update(pages.tolist())
            continue
        got = alloc.alloc([int(rng.integers(1, 4))])
        if got is None:
            assert alloc.free_count < 3    # only refuses when short
            continue
        (pages,) = got
        assert 0 not in pages.tolist()     # null page never handed out
        assert not (seen & set(pages.tolist())), "double allocation"
        seen.update(pages.tolist())
        held.append(pages)
    assert alloc.in_use == len(seen)


def test_allocator_roundtrip_and_batch_offsets():
    alloc = PageAllocator(10, 4)           # 9 allocatable
    got = alloc.alloc([2, 3, 1])           # batched: one prefix-sum plan
    assert got is not None and [len(g) for g in got] == [2, 3, 1]
    flat = np.concatenate(got)
    assert len(set(flat.tolist())) == 6    # disjoint across the batch
    assert alloc.free_count == 3
    alloc.release(got[1])
    assert alloc.free_count == 6
    again = alloc.alloc([6])
    assert again is not None and alloc.free_count == 0


def test_allocator_exhaustion_is_explicit_and_all_or_nothing():
    alloc = PageAllocator(6, 4)            # 5 allocatable
    assert alloc.alloc([3]) is not None
    before = alloc.free_count
    assert alloc.alloc([1, 2]) is None     # 3 > 2 free: refuse the BATCH
    assert alloc.free_count == before      # nothing partially handed out
    assert alloc.stats is None             # counter path is engine-side


def test_allocator_rejects_null_free_and_double_free():
    alloc = PageAllocator(8, 4)
    (pages,) = alloc.alloc([2])
    alloc.release(pages)
    with pytest.raises(ValueError):
        alloc.release(pages)               # double free
    with pytest.raises(ValueError):
        alloc.release(np.array([0]))       # null page is pinned
    with pytest.raises(ValueError):
        PageAllocator(1, 4)                # nothing left after null page


def test_defrag_plan_is_stable_partition():
    alloc = PageAllocator(9, 4)
    a = alloc.alloc([3])[0]
    b = alloc.alloc([3])[0]
    alloc.release(a)                       # holes at a's positions
    dest = alloc.defrag_plan()
    assert dest[0] == 0                    # null page pinned by stability
    # live pages keep their relative order, compacted to the front
    live_new = sorted(int(dest[p]) for p in b.tolist())
    assert live_new == list(range(1, 4))
    moved = alloc.apply_defrag(dest)
    assert moved == int((dest[b] != b).sum())
    assert alloc.free_count == 5
    assert alloc.fragmentation() == 0.0    # one contiguous free extent


def test_page_table_assign_release_remap():
    pt = PageTable(2, 4)
    pt.assign(0, np.array([5, 7]))
    pt.assign(0, np.array([2]))
    assert pt.pages_of(0).tolist() == [5, 7, 2]
    perm = np.arange(10)
    perm[[5, 7, 2]] = [1, 2, 3]
    pt.remap(perm)
    assert pt.pages_of(0).tolist() == [1, 2, 3]
    assert pt.release(0).tolist() == [1, 2, 3]
    assert pt.pages_of(0).size == 0 and int(pt.table[0].sum()) == 0
    with pytest.raises(ValueError):
        pt.assign(1, np.arange(1, 6))      # 5 > pages_per_seq


def test_pages_for_covers_next_write():
    assert pages_for(0, 8) == 1            # the first decode write
    assert pages_for(7, 8) == 1
    assert pages_for(8, 8) == 2            # position 8 needs page 1
    assert pages_for(17, 8) == 3


# ---------------------------------------------------------------------------
# engine parity: paged == contiguous, bitwise
# ---------------------------------------------------------------------------


def test_paged_decode_bitwise_identical(small_model):
    cfg, params = small_model
    prompts = _prompts(5)
    ref = _run(cfg, params, prompts, _ecfg())
    got = _run(cfg, params, prompts, _ecfg(cache_layout="paged",
                                           page_size=16))
    assert _outputs(ref) == _outputs(got)
    assert ({r.rid: r.finish_reason for r in ref.finished}
            == {r.rid: r.finish_reason for r in got.finished})
    assert got.stats.page_allocs > 0
    assert got.stats.page_frees == got.stats.page_allocs  # all returned


def test_paged_small_pages_many_rounds(small_model):
    """Multi-page sequences (page growth mid-decode) stay bitwise."""
    cfg, params = small_model
    prompts = _prompts(6, seed=11)
    ref = _run(cfg, params, prompts, _ecfg(max_new_tokens=9))
    got = _run(cfg, params, prompts, _ecfg(max_new_tokens=9,
                                           cache_layout="paged",
                                           page_size=8))
    assert _outputs(ref) == _outputs(got)


def test_chunked_prefill_bitwise_vs_one_shot(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(5)
    prompts = ([rng.integers(2, 500, size=21).astype(np.int32)]
               + _prompts(3, seed=13))
    base = dict(bucket_prompts=False, max_new_tokens=4)
    ref = _run(cfg, params, prompts, _ecfg(**base))
    got = _run(cfg, params, prompts, _ecfg(prefill_chunk=6, **base))
    assert _outputs(ref) == _outputs(got)
    assert got.stats.prefill_chunks == 4   # ceil(21 / 6)
    both = _run(cfg, params, prompts, _ecfg(prefill_chunk=6,
                                            cache_layout="paged",
                                            page_size=8, **base))
    assert _outputs(ref) == _outputs(both)


def test_chunked_prefill_flash_route_runs(small_model):
    """Flash prefill + chunked staging: the lax.cond guard keeps chunk
    boundaries on the cached-dense path; the run must complete clean."""
    cfg, params = small_model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(2, 500, size=19).astype(np.int32)]
    eng = _run(cfg, params, prompts, _ecfg(prefill_chunk=8,
                                           attn_impl="flash",
                                           bucket_prompts=False,
                                           max_new_tokens=4))
    assert [r.finish_reason for r in eng.finished] == ["length_budget"]
    assert eng.stats.prefill_chunks == 3


def test_defrag_mid_run_does_not_change_tokens(small_model):
    cfg, params = small_model
    prompts = _prompts(3, seed=3, lo=5, hi=10)
    ref = _run(cfg, params, prompts, _ecfg(max_slots=3, max_new_tokens=8,
                                           cache_layout="paged",
                                           page_size=8))
    eng = Engine(params, cfg, _ecfg(max_slots=3, max_new_tokens=8,
                                    cache_layout="paged", page_size=8))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for _ in range(2):
            eng.step()
        eng.cancel(1)                      # punch a hole in the pool
        moved = eng.defrag()
        eng.run_to_completion(max_ticks=300)
    eng.audit()
    assert moved > 0 and eng.stats.defrags == 1
    assert eng.allocator.fragmentation() == 0.0
    ref_out = _outputs(ref)
    for rid, out in _outputs(eng).items():
        if rid != 1:
            assert out == ref_out[rid]


# ---------------------------------------------------------------------------
# paged semantics: backpressure, cache_full, config validation
# ---------------------------------------------------------------------------


def test_admission_backpressure_loses_nothing(small_model):
    """More demand than pages: admission waits instead of rejecting;
    every request still terminates normally, and concurrency never
    exceeds what the pool can host."""
    cfg, params = small_model
    # sizes 3-4 + 3 new tokens: every sequence stays within ONE page,
    # so the only limiter is the pool (4 usable pages for 6 requests).
    prompts = _prompts(6, seed=2, lo=3, hi=5)
    eng = Engine(params, cfg, _ecfg(max_slots=4, cache_layout="paged",
                                    page_size=8, num_pages=5,
                                    max_new_tokens=3))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    peak = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        while eng.waiting or any(r is not None for r in eng.slot_req):
            eng.step()
            peak = max(peak, sum(r is not None for r in eng.slot_req))
            assert eng.stats.ticks < 300
    eng.audit()
    assert sorted(r.rid for r in eng.finished) == list(range(len(prompts)))
    assert all(r.finish_reason in ("eos", "length_budget")
               for r in eng.finished)
    assert peak <= 4                       # 4 usable pages, >=1 page each


def test_mid_decode_exhaustion_finishes_cache_full(small_model):
    """A pool too small for the requests' full extents: growth hits the
    empty allocator mid-decode and the victim finishes ``cache_full``
    (the paged meaning: allocator exhausted, not row full)."""
    cfg, params = small_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(2, 500, size=7).astype(np.int32)
               for _ in range(2)]
    eng = Engine(params, cfg, _ecfg(max_new_tokens=24, max_len=48,
                                    cache_layout="paged", page_size=8,
                                    num_pages=4))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=300)
    eng.audit()
    reasons = [r.finish_reason for r in eng.finished]
    assert "cache_full" in reasons
    assert eng.stats.page_alloc_failures >= 1


def test_paged_config_validation(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError):
        EngineConfig(cache_layout="banana")
    with pytest.raises(ValueError):
        # page_size must divide max_len (gathered view == contiguous)
        Engine(params, cfg, _ecfg(cache_layout="paged", page_size=10,
                                  max_len=48))


def test_policy_explains_cache_layout():
    from repro.core.scan.policy import (choose_cache_layout,
                                        explain_cache_layout)
    d = explain_cache_layout(8, 512, 16, num_pages=64)
    assert d.value == "paged"              # budget below worst case
    assert "page" in d.reason.lower()
    assert choose_cache_layout(8, 512, 16, expected_len=64) == "paged"
    assert choose_cache_layout(2, 64, 16) == "contiguous"


# ---------------------------------------------------------------------------
# observability: gauges + counters
# ---------------------------------------------------------------------------


def test_paged_gauges_and_counters_fire(small_model):
    cfg, params = small_model
    reg = Registry()
    eng = Engine(params, cfg, _ecfg(cache_layout="paged", page_size=16),
                 metrics=reg)
    for i, p in enumerate(_prompts(3)):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=200)
    gauges = reg.snapshot()["gauges"]
    for name in ("serve.pages.in_use", "serve.pages.free",
                 "serve.pages.fragmentation"):
        assert name in gauges
    assert gauges["serve.pages.in_use"] == 0          # all returned
    assert gauges["serve.stats.page_allocs"] == eng.stats.page_allocs > 0
    assert gauges["serve.stats.page_frees"] == eng.stats.page_frees
    s = eng.stats.summary()
    assert "pages[" in s and "prefill_chunks=" in s


# ---------------------------------------------------------------------------
# scan-engine page indirection: KVBlocks.kv_block_map
# ---------------------------------------------------------------------------


def test_kv_block_map_validation():
    from repro.kernels.scan_engine.layouts import KVBlocks
    with pytest.raises(ValueError):
        KVBlocks(bh=2, bh_kv=2, tq=64, tk=128, d=32, bq=32, bk=32,
                 kv_block_map=(0, 1))      # 2 entries, 4 logical blocks


@pytest.mark.parametrize("schedule", ["carry", "decoupled"])
def test_kv_block_map_bitwise_on_permuted_pool(schedule):
    """A block-permuted physical KV pool + the inverse map through the
    index maps == the contiguous layout, bitwise (masks and bounds are
    keyed on logical positions)."""
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_kernel)
    rng = np.random.default_rng(0)
    BH, BHkv, Tq, Tk, d, bq, bk = 4, 2, 64, 128, 32, 32, 32
    q = jnp.asarray(rng.standard_normal((BH, Tq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BHkv, Tk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BHkv, Tk, d)), jnp.float32)
    nk = Tk // bk
    perm = rng.permutation(nk)             # logical block j lives at perm[j]
    inv = np.empty(nk, np.int64)
    inv[perm] = np.arange(nk)
    kp = k.reshape(BHkv, nk, bk, d)[:, inv].reshape(BHkv, Tk, d)
    vp = v.reshape(BHkv, nk, bk, d)[:, inv].reshape(BHkv, Tk, d)
    for causal, kv_len in ((True, None), (False, 100)):
        ref = flash_attention_kernel(
            q, k, v, group=2, scale=0.125, causal=causal, kv_len=kv_len,
            block_q=bq, block_k=bk, schedule=schedule, interpret=True)
        got = flash_attention_kernel(
            q, kp, vp, group=2, scale=0.125, causal=causal, kv_len=kv_len,
            block_q=bq, block_k=bk, schedule=schedule, interpret=True,
            kv_block_map=tuple(perm.tolist()))
        assert np.array_equal(np.asarray(ref), np.asarray(got))
