"""Paged KV cache tests (ISSUE 8).

Three walls:

  * allocator/page-table properties — the prefix-sum allocator never
    double-allocates, free -> alloc roundtrips, exhaustion is explicit
    (None + counter), defrag plans are stable partitions;
  * engine parity — decode on the paged layout is BITWISE identical to
    the contiguous layout at equal configs (token streams), chunked
    prefill is bitwise identical to one-shot on the dense route, and
    defrag mid-run does not change a single token;
  * paged semantics — admission backpressure (requests wait, none are
    lost), mid-decode allocator exhaustion surfaces as ``cache_full``,
    and the observability gauges/counters fire.

Plus the scan-engine page-indirection map: ``KVBlocks.kv_block_map``
feeds a block-permuted KV pool through the flash fold bitwise.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.obs.metrics import Registry
from repro.serve import (Engine, EngineConfig, PageAllocator, PageTable,
                         Request, pages_for)
from repro.train.step import init_params


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("stablelm-12b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(n, seed=7, lo=3, hi=9):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 500, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _ecfg(**kw):
    base = dict(max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
                temperature=0.0)
    base.update(kw)
    return EngineConfig(**base)


def _run(cfg, params, prompts, ecfg, max_ticks=300):
    eng = Engine(params, cfg, ecfg)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=max_ticks)
    eng.audit()
    return eng


def _outputs(eng):
    return {r.rid: list(r.output) for r in eng.finished}


# ---------------------------------------------------------------------------
# allocator / page-table properties
# ---------------------------------------------------------------------------


def test_allocator_never_double_allocates():
    rng = np.random.default_rng(0)
    alloc = PageAllocator(33, 8)
    held = []
    seen = set()
    for _ in range(200):
        if held and rng.random() < 0.45:
            pages = held.pop(int(rng.integers(len(held))))
            alloc.release(pages)
            seen.difference_update(pages.tolist())
            continue
        got = alloc.alloc([int(rng.integers(1, 4))])
        if got is None:
            assert alloc.free_count < 3    # only refuses when short
            continue
        (pages,) = got
        assert 0 not in pages.tolist()     # null page never handed out
        assert not (seen & set(pages.tolist())), "double allocation"
        seen.update(pages.tolist())
        held.append(pages)
    assert alloc.in_use == len(seen)


def test_allocator_roundtrip_and_batch_offsets():
    alloc = PageAllocator(10, 4)           # 9 allocatable
    got = alloc.alloc([2, 3, 1])           # batched: one prefix-sum plan
    assert got is not None and [len(g) for g in got] == [2, 3, 1]
    flat = np.concatenate(got)
    assert len(set(flat.tolist())) == 6    # disjoint across the batch
    assert alloc.free_count == 3
    alloc.release(got[1])
    assert alloc.free_count == 6
    again = alloc.alloc([6])
    assert again is not None and alloc.free_count == 0


def test_allocator_exhaustion_is_explicit_and_all_or_nothing():
    alloc = PageAllocator(6, 4)            # 5 allocatable
    assert alloc.alloc([3]) is not None
    before = alloc.free_count
    assert alloc.alloc([1, 2]) is None     # 3 > 2 free: refuse the BATCH
    assert alloc.free_count == before      # nothing partially handed out
    assert alloc.stats is None             # counter path is engine-side


def test_allocator_rejects_null_free_and_double_free():
    alloc = PageAllocator(8, 4)
    (pages,) = alloc.alloc([2])
    alloc.release(pages)
    with pytest.raises(ValueError):
        alloc.release(pages)               # double free
    with pytest.raises(ValueError):
        alloc.release(np.array([0]))       # null page is pinned
    with pytest.raises(ValueError):
        PageAllocator(1, 4)                # nothing left after null page


def test_defrag_plan_is_stable_partition():
    alloc = PageAllocator(9, 4)
    a = alloc.alloc([3])[0]
    b = alloc.alloc([3])[0]
    alloc.release(a)                       # holes at a's positions
    dest = alloc.defrag_plan()
    assert dest[0] == 0                    # null page pinned by stability
    # live pages keep their relative order, compacted to the front
    live_new = sorted(int(dest[p]) for p in b.tolist())
    assert live_new == list(range(1, 4))
    moved = alloc.apply_defrag(dest)
    assert moved == int((dest[b] != b).sum())
    assert alloc.free_count == 5
    assert alloc.fragmentation() == 0.0    # one contiguous free extent


def test_page_table_assign_release_remap():
    pt = PageTable(2, 4)
    pt.assign(0, np.array([5, 7]))
    pt.assign(0, np.array([2]))
    assert pt.pages_of(0).tolist() == [5, 7, 2]
    perm = np.arange(10)
    perm[[5, 7, 2]] = [1, 2, 3]
    pt.remap(perm)
    assert pt.pages_of(0).tolist() == [1, 2, 3]
    assert pt.release(0).tolist() == [1, 2, 3]
    assert pt.pages_of(0).size == 0 and int(pt.table[0].sum()) == 0
    with pytest.raises(ValueError):
        pt.assign(1, np.arange(1, 6))      # 5 > pages_per_seq


def test_pages_for_covers_next_write():
    assert pages_for(0, 8) == 1            # the first decode write
    assert pages_for(7, 8) == 1
    assert pages_for(8, 8) == 2            # position 8 needs page 1
    assert pages_for(17, 8) == 3


# ---------------------------------------------------------------------------
# engine parity: paged == contiguous, bitwise
# ---------------------------------------------------------------------------


def test_paged_decode_bitwise_identical(small_model):
    cfg, params = small_model
    prompts = _prompts(5)
    ref = _run(cfg, params, prompts, _ecfg())
    got = _run(cfg, params, prompts, _ecfg(cache_layout="paged",
                                           page_size=16))
    assert _outputs(ref) == _outputs(got)
    assert ({r.rid: r.finish_reason for r in ref.finished}
            == {r.rid: r.finish_reason for r in got.finished})
    assert got.stats.page_allocs > 0
    assert got.stats.page_frees == got.stats.page_allocs  # all returned


def test_paged_small_pages_many_rounds(small_model):
    """Multi-page sequences (page growth mid-decode) stay bitwise."""
    cfg, params = small_model
    prompts = _prompts(6, seed=11)
    ref = _run(cfg, params, prompts, _ecfg(max_new_tokens=9))
    got = _run(cfg, params, prompts, _ecfg(max_new_tokens=9,
                                           cache_layout="paged",
                                           page_size=8))
    assert _outputs(ref) == _outputs(got)


def test_chunked_prefill_bitwise_vs_one_shot(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(5)
    prompts = ([rng.integers(2, 500, size=21).astype(np.int32)]
               + _prompts(3, seed=13))
    base = dict(bucket_prompts=False, max_new_tokens=4)
    ref = _run(cfg, params, prompts, _ecfg(**base))
    got = _run(cfg, params, prompts, _ecfg(prefill_chunk=6, **base))
    assert _outputs(ref) == _outputs(got)
    assert got.stats.prefill_chunks == 4   # ceil(21 / 6)
    both = _run(cfg, params, prompts, _ecfg(prefill_chunk=6,
                                            cache_layout="paged",
                                            page_size=8, **base))
    assert _outputs(ref) == _outputs(both)


def test_chunked_prefill_flash_route_runs(small_model):
    """Flash prefill + chunked staging: the lax.cond guard keeps chunk
    boundaries on the cached-dense path; the run must complete clean."""
    cfg, params = small_model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(2, 500, size=19).astype(np.int32)]
    eng = _run(cfg, params, prompts, _ecfg(prefill_chunk=8,
                                           attn_impl="flash",
                                           bucket_prompts=False,
                                           max_new_tokens=4))
    assert [r.finish_reason for r in eng.finished] == ["length_budget"]
    assert eng.stats.prefill_chunks == 3


def test_defrag_mid_run_does_not_change_tokens(small_model):
    cfg, params = small_model
    prompts = _prompts(3, seed=3, lo=5, hi=10)
    ref = _run(cfg, params, prompts, _ecfg(max_slots=3, max_new_tokens=8,
                                           cache_layout="paged",
                                           page_size=8))
    # auto_defrag off: this test pins the MANUAL defrag call count.
    eng = Engine(params, cfg, _ecfg(max_slots=3, max_new_tokens=8,
                                    cache_layout="paged", page_size=8,
                                    auto_defrag=False))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for _ in range(2):
            eng.step()
        eng.cancel(1)                      # punch a hole in the pool
        moved = eng.defrag()
        eng.run_to_completion(max_ticks=300)
    eng.audit()
    assert moved > 0 and eng.stats.defrags == 1
    assert eng.allocator.fragmentation() == 0.0
    ref_out = _outputs(ref)
    for rid, out in _outputs(eng).items():
        if rid != 1:
            assert out == ref_out[rid]


# ---------------------------------------------------------------------------
# paged semantics: backpressure, cache_full, config validation
# ---------------------------------------------------------------------------


def test_admission_backpressure_loses_nothing(small_model):
    """More demand than pages: admission waits instead of rejecting;
    every request still terminates normally, and concurrency never
    exceeds what the pool can host."""
    cfg, params = small_model
    # sizes 3-4 + 3 new tokens: every sequence stays within ONE page,
    # so the only limiter is the pool (4 usable pages for 6 requests).
    prompts = _prompts(6, seed=2, lo=3, hi=5)
    eng = Engine(params, cfg, _ecfg(max_slots=4, cache_layout="paged",
                                    page_size=8, num_pages=5,
                                    max_new_tokens=3))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    peak = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        while eng.waiting or any(r is not None for r in eng.slot_req):
            eng.step()
            peak = max(peak, sum(r is not None for r in eng.slot_req))
            assert eng.stats.ticks < 300
    eng.audit()
    assert sorted(r.rid for r in eng.finished) == list(range(len(prompts)))
    assert all(r.finish_reason in ("eos", "length_budget")
               for r in eng.finished)
    assert peak <= 4                       # 4 usable pages, >=1 page each


def test_mid_decode_exhaustion_finishes_cache_full(small_model):
    """A pool too small for the requests' full extents: growth hits the
    empty allocator mid-decode and the victim finishes ``cache_full``
    (the paged meaning: allocator exhausted, not row full)."""
    cfg, params = small_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(2, 500, size=7).astype(np.int32)
               for _ in range(2)]
    eng = Engine(params, cfg, _ecfg(max_new_tokens=24, max_len=48,
                                    cache_layout="paged", page_size=8,
                                    num_pages=4))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=300)
    eng.audit()
    reasons = [r.finish_reason for r in eng.finished]
    assert "cache_full" in reasons
    assert eng.stats.page_alloc_failures >= 1


def test_paged_config_validation(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError):
        EngineConfig(cache_layout="banana")
    with pytest.raises(ValueError):
        # page_size must divide max_len (gathered view == contiguous)
        Engine(params, cfg, _ecfg(cache_layout="paged", page_size=10,
                                  max_len=48))


def test_policy_explains_cache_layout():
    from repro.core.scan.policy import (choose_cache_layout,
                                        explain_cache_layout)
    d = explain_cache_layout(8, 512, 16, num_pages=64)
    assert d.value == "paged"              # budget below worst case
    assert "page" in d.reason.lower()
    assert choose_cache_layout(8, 512, 16, expected_len=64) == "paged"
    assert choose_cache_layout(2, 64, 16) == "contiguous"


# ---------------------------------------------------------------------------
# observability: gauges + counters
# ---------------------------------------------------------------------------


def test_paged_gauges_and_counters_fire(small_model):
    cfg, params = small_model
    reg = Registry()
    eng = Engine(params, cfg, _ecfg(cache_layout="paged", page_size=16),
                 metrics=reg)
    for i, p in enumerate(_prompts(3)):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.run_to_completion(max_ticks=200)
    gauges = reg.snapshot()["gauges"]
    for name in ("serve.pages.in_use", "serve.pages.free",
                 "serve.pages.fragmentation"):
        assert name in gauges
    assert gauges["serve.pages.in_use"] == 0          # all returned
    assert gauges["serve.stats.page_allocs"] == eng.stats.page_allocs > 0
    assert gauges["serve.stats.page_frees"] == eng.stats.page_frees
    s = eng.stats.summary()
    assert "pages[" in s and "prefill_chunks=" in s


# ---------------------------------------------------------------------------
# scan-engine page indirection: KVBlocks.kv_block_map
# ---------------------------------------------------------------------------


def test_kv_block_map_validation():
    from repro.kernels.scan_engine.layouts import KVBlocks
    with pytest.raises(ValueError):
        KVBlocks(bh=2, bh_kv=2, tq=64, tk=128, d=32, bq=32, bk=32,
                 kv_block_map=(0, 1))      # 2 entries, 4 logical blocks


@pytest.mark.parametrize("schedule", ["carry", "decoupled"])
def test_kv_block_map_bitwise_on_permuted_pool(schedule):
    """A block-permuted physical KV pool + the inverse map through the
    index maps == the contiguous layout, bitwise (masks and bounds are
    keyed on logical positions)."""
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_kernel)
    rng = np.random.default_rng(0)
    BH, BHkv, Tq, Tk, d, bq, bk = 4, 2, 64, 128, 32, 32, 32
    q = jnp.asarray(rng.standard_normal((BH, Tq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BHkv, Tk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BHkv, Tk, d)), jnp.float32)
    nk = Tk // bk
    perm = rng.permutation(nk)             # logical block j lives at perm[j]
    inv = np.empty(nk, np.int64)
    inv[perm] = np.arange(nk)
    kp = k.reshape(BHkv, nk, bk, d)[:, inv].reshape(BHkv, Tk, d)
    vp = v.reshape(BHkv, nk, bk, d)[:, inv].reshape(BHkv, Tk, d)
    for causal, kv_len in ((True, None), (False, 100)):
        ref = flash_attention_kernel(
            q, k, v, group=2, scale=0.125, causal=causal, kv_len=kv_len,
            block_q=bq, block_k=bk, schedule=schedule, interpret=True)
        got = flash_attention_kernel(
            q, kp, vp, group=2, scale=0.125, causal=causal, kv_len=kv_len,
            block_q=bq, block_k=bk, schedule=schedule, interpret=True,
            kv_block_map=tuple(perm.tolist()))
        assert np.array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# allocator refcounts + the ISSUE 9 bugfixes
# ---------------------------------------------------------------------------


def test_alloc_zero_total_is_noop():
    """An all-zero batch (growth tick where no row crosses a page
    boundary) is a legal no-op, not a ValueError."""
    a = PageAllocator(8, 4)
    out = a.alloc([0, 0, 0])
    assert [v.size for v in out] == [0, 0, 0]
    assert a.free_count == 7 and a.in_use == 0
    # mixed zero/nonzero batches slice correctly around the zeros
    out = a.alloc([0, 2, 0, 1])
    assert [v.size for v in out] == [0, 2, 0, 1]
    # negative counts still raise
    with pytest.raises(ValueError):
        a.alloc([-1, 1])


def test_double_allocation_raises_runtime_error():
    """The double-allocation guard is a real exception (asserts vanish
    under ``python -O``): corrupt the free bitmap so a live page looks
    free and the next alloc must refuse to hand it out."""
    a = PageAllocator(6, 4)
    (pages,) = a.alloc([2])
    a.free[pages] = True                  # simulated bookkeeping corruption
    with pytest.raises(RuntimeError, match="double allocation"):
        a.alloc([4])


def test_fragmentation_pinned_at_occupancy_extremes():
    """Gauge regression (ISSUE 9): 0 free pages -> 1.0 (the pool is
    maximally tight, NOT 'perfectly compact'), 1 free page -> 0.0, N
    contiguous free pages -> 0.0, shattered free space -> in between."""
    a = PageAllocator(10, 4)
    assert a.fragmentation() == 0.0       # 9 contiguous free pages
    (pages,) = a.alloc([9])
    assert a.free_count == 0 and a.fragmentation() == 1.0
    a.release(pages[4:5])
    assert a.free_count == 1 and a.fragmentation() == 0.0
    a.release(pages[6:8])
    # free = {5, 7, 8}: largest run 2 of 3
    assert a.fragmentation() == pytest.approx(1.0 - 2.0 / 3.0)
    a.release(np.concatenate([pages[:4], pages[5:6], pages[8:]]))
    assert a.free_count == 9 and a.fragmentation() == 0.0
    assert a.longest_free_run() == 9


def test_retain_release_refcount_lifecycle():
    a = PageAllocator(8, 4)
    (pages,) = a.alloc([2])
    assert (a.refcount[pages] == 1).all()
    a.retain(pages)                        # a second table row maps them
    assert (a.refcount[pages] == 2).all()
    a.release(pages)                       # first sharer drops out
    assert (a.refcount[pages] == 1).all() and a.in_use == 2
    a.release(pages)                       # last reference frees
    assert a.in_use == 0 and a.free[pages].all()
    with pytest.raises(ValueError, match="double free"):
        a.release(pages)
    with pytest.raises(ValueError, match="retain of free"):
        a.retain(pages)
    with pytest.raises(ValueError, match="null page"):
        a.retain(np.array([0]))
    # epochs advance on reuse so weak registry entries can detect it
    before = a.epoch[int(pages[0])]
    a.alloc([2])
    assert a.epoch[int(pages[0])] == before + 1


def test_prefix_registry_lru_and_weak_staleness():
    from repro.serve import PrefixRegistry
    a = PageAllocator(16, 4)
    prompt = np.arange(10, dtype=np.int32)         # 2 full pages + partial
    (pages,) = a.alloc([pages_for(10, 4)])

    # Capacity pressure: inserting the 3rd chunk evicts the OLDEST entry
    # (the first full page, strong) and releases its registry pin.
    small = PrefixRegistry(a, page_size=4, capacity=2)
    small.register(prompt, pages)
    assert len(small) == 2 and len(small.strong_pages()) == 1
    assert a.refcount[pages[0]] == 1               # evicted -> released
    assert a.refcount[pages[1]] == 2               # surviving strong pin
    assert a.refcount[pages[2]] == 1               # partial is weak: no ref
    assert small.match(prompt) == []               # chain broken at page 0
    small.clear()
    assert a.refcount[pages[1]] == 1

    # Ample capacity: full chain matches, weak tail validated via epoch.
    reg = PrefixRegistry(a, page_size=4, capacity=8)
    reg.register(prompt, pages)
    assert (a.refcount[pages[:2]] == 2).all()
    assert reg.match(prompt) == list(pages[:3])
    a.release(pages)                               # drop the table refs
    # Full pages survive on the registry pin; the weak page is freed...
    assert a.free[pages[2]] and not a.free[pages[:2]].any()
    a.alloc([1])                                   # ...and reused (epoch bump)
    assert reg.match(prompt) == list(pages[:2])    # stale weak tail dropped
    reg.clear()
    assert a.in_use == 1                           # just the realloc'd page


def test_policy_explains_defrag():
    from repro.core.scan import policy
    d = policy.explain_defrag(0.0, 9, 9)
    assert d.what == "defrag" and d.value == "skip"
    d = policy.explain_defrag(1.0, 0, 0)
    assert d.value == "skip" and "cannot create space" in d.reason
    d = policy.explain_defrag(0.75, 4, 1)
    assert d.value == "defrag" and d.inputs["free_pages"] == 4
    assert policy.choose_defrag(0.75, 4, 1) is True
    assert policy.choose_defrag(0.75, 4, 1, threshold=0.9) is False


# ---------------------------------------------------------------------------
# copy-on-write prefix sharing
# ---------------------------------------------------------------------------


def _shared_prompts(seed=0, tails=(4, 5)):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(2, 500, 16).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(2, 500, t).astype(np.int32)])
            for t in tails]


def test_shared_prefix_bitwise_and_page_savings(small_model):
    """Two requests with a common 16-token system prefix: sharing maps
    the prefix pages instead of re-allocating them, token streams stay
    bitwise identical to the unshared paged run, and the counters
    attribute the savings."""
    cfg, params = small_model
    prompts = _shared_prompts()
    base = dict(max_slots=2, cache_layout="paged", page_size=8)
    ref = _run(cfg, params, prompts, _ecfg(**base))
    eng = _run(cfg, params, prompts, _ecfg(**base, share_prefixes=True))
    assert _outputs(eng) == _outputs(ref)
    # consumer skipped allocating the two matched prefix pages
    assert eng.stats.page_allocs == ref.stats.page_allocs - 2
    assert eng.stats.prefix_hits == 1
    assert eng.stats.shared_page_maps == 2
    # the registry outlives its donors: strong pins keep in_use > 0
    assert eng.allocator.in_use == len(eng.registry.strong_pages()) > 0
    assert "refcount_copies=0" in eng.stats.summary()


def test_cow_fires_on_duplicate_prompts(small_model):
    """An exact-duplicate prompt matches the donor's PARTIAL tail page;
    the first decode write into the now-shared page must copy first, and
    both streams stay bitwise identical to the unshared run."""
    cfg, params = small_model
    prompts = _shared_prompts(seed=3, tails=(5,))
    prompts = [prompts[0], prompts[0].copy()]
    base = dict(max_slots=2, cache_layout="paged", page_size=8)
    ref = _run(cfg, params, prompts, _ecfg(**base))
    eng = _run(cfg, params, prompts, _ecfg(**base, share_prefixes=True))
    assert _outputs(eng) == _outputs(ref)
    assert eng.stats.prefix_hits == 1
    assert eng.stats.shared_page_maps == 3     # 2 full + the partial page
    assert eng.stats.refcount_copies >= 1
    assert f"refcount_copies={eng.stats.refcount_copies}" \
        in eng.stats.summary()


def test_cow_fuzzer_refcounts_no_double_free_bitwise(small_model):
    """Seeded rounds of submit (incl. forks of earlier prompts), step,
    and defrag under a tight pool with sharing on. After every round the
    audit asserts refcount == live table references + registry pins and
    free == (refcount == 0); any double-free raises inside the
    allocator. Every finished stream is bitwise identical to an
    unshared paged run of the same prompts."""
    cfg, params = small_model
    rng = np.random.default_rng(11)
    prefixes = [rng.integers(2, 500, 16).astype(np.int32),
                rng.integers(2, 500, 21).astype(np.int32)]
    prompts = []
    for i in range(10):
        if i == 1 or i % 3 == 2:
            prompts.append(prompts[i - 1].copy())         # immediate fork
        else:
            tail = rng.integers(2, 500,
                                int(rng.integers(1, 6))).astype(np.int32)
            prompts.append(np.concatenate([prefixes[i % 2], tail]))
    eng = Engine(params, cfg, _ecfg(
        max_slots=3, max_new_tokens=6, cache_layout="paged", page_size=8,
        num_pages=25, share_prefixes=True, prefix_cache_pages=8))
    nxt = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for rnd in range(60):
            # round 0 submits the donor+fork pair together so both share
            # the donor's partial tail page while the donor is live (the
            # only schedule that deterministically exercises real COW).
            for _ in range(2 if rnd == 0 else int(rng.integers(0, 3))):
                if nxt < len(prompts):
                    eng.submit(Request(rid=nxt, prompt=prompts[nxt]))
                    nxt += 1
            for _ in range(int(rng.integers(1, 4))):
                eng.step()
            if rng.random() < 0.25:
                eng.defrag()
            eng.audit()
            if (nxt == len(prompts) and not eng.waiting
                    and all(r is None for r in eng.slot_req)):
                break
        eng.run_to_completion(max_ticks=200)
    eng.audit()
    assert eng.stats.prefix_hits > 0
    assert eng.stats.refcount_copies > 0       # forks forced real COW
    assert {r.rid for r in eng.finished} == set(range(len(prompts)))
    ref = _run(cfg, params, prompts, _ecfg(
        max_slots=3, max_new_tokens=6, cache_layout="paged", page_size=8))
    ref_out = _outputs(ref)
    for rid, out in _outputs(eng).items():
        assert out == ref_out[rid], f"rid {rid} diverged under sharing"


def test_share_prefixes_requires_bucketable():
    cfg = configs.get_smoke_config("gemma3-12b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="share_prefixes"):
        Engine(params, cfg, _ecfg(cache_layout="paged", page_size=8,
                                  share_prefixes=True))


# ---------------------------------------------------------------------------
# windowed paged decode (gemma2/gemma3-style hybrids)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hybrid_model():
    cfg = configs.get_smoke_config("gemma3-12b")   # 5:1 local:global, w=32
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_windowed_paged_bitwise_vs_contiguous(hybrid_model):
    """A local/global hybrid decodes paged end-to-end — every attention
    layer on pages, the local rings riding the first window//page_size
    table entries — bitwise identical to the contiguous layout, past the
    point where the rings wrap (lengths > window)."""
    cfg, params = hybrid_model
    prompts = _prompts(3, seed=1, lo=4, hi=9)
    outs = {}
    for layout in ("contiguous", "paged"):
        eng = _run(cfg, params, prompts, _ecfg(
            max_slots=3, max_new_tokens=30, cache_layout=layout,
            page_size=8))
        assert all(r.finish_reason == "length_budget" for r in eng.finished)
        outs[layout] = _outputs(eng)
    # budget 30 on 4-8 token prompts: lengths reach ~38 > window 32
    assert outs["paged"] == outs["contiguous"]


def test_windowed_paged_construction_errors(hybrid_model):
    """Unsupported geometry fails at construction with the offending
    layer named — not mid-jit-trace (ISSUE 9 satellite)."""
    cfg, params = hybrid_model
    # ring extent min(32, 48) not a multiple of page_size=12
    with pytest.raises(ValueError, match=r"p0_local"):
        Engine(params, cfg, _ecfg(max_len=48, cache_layout="paged",
                                  page_size=12))
    from repro.serve import validate_paged_support
    with pytest.raises(ValueError, match=r"p0_local.*sliding_window"):
        validate_paged_support(
            dataclasses.replace(cfg, sliding_window=None), 48, 8)
    validate_paged_support(cfg, 48, 8)             # supported geometry


def test_auto_defrag_self_heals(small_model):
    """Fragmentation from a cancel mid-run triggers policy.choose_defrag
    on a later tick — no host call to defrag() — and the surviving token
    streams are unchanged."""
    cfg, params = small_model
    prompts = _prompts(3, seed=3, lo=5, hi=10)
    ref = _run(cfg, params, prompts, _ecfg(
        max_slots=3, max_new_tokens=8, cache_layout="paged", page_size=8,
        auto_defrag=False))
    eng = Engine(params, cfg, _ecfg(
        max_slots=3, max_new_tokens=8, cache_layout="paged", page_size=8,
        num_pages=13, defrag_threshold=0.1, defrag_cooldown=1))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for _ in range(2):
            eng.step()
        eng.cancel(1)                      # punch a hole in the pool
        eng.run_to_completion(max_ticks=300)
    eng.audit()
    assert eng.stats.auto_defrags >= 1
    assert eng.stats.auto_defrags <= eng.stats.defrags
    assert f"auto_defrags={eng.stats.auto_defrags}" in eng.stats.summary()
    ref_out = _outputs(ref)
    for rid, out in _outputs(eng).items():
        if rid != 1:
            assert out == ref_out[rid]
