"""Prefix-sum partitioning (the paper's §1 use case) + MoE dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scan.segmented import dispatch_offsets, packed_segment_ids


@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
@settings(max_examples=30, deadline=None)
def test_dispatch_plan_invariants(ids):
    """dest must be a bijection token -> bucket slots in expert order."""
    E = 8
    plan = dispatch_offsets(jnp.asarray(ids, jnp.int32), E)
    counts = np.asarray(plan.counts)
    offsets = np.asarray(plan.offsets)
    ranks = np.asarray(plan.ranks)
    dest = np.asarray(plan.dest)
    # histogram correct
    np.testing.assert_array_equal(counts, np.bincount(ids, minlength=E))
    # offsets = exclusive scan of counts
    np.testing.assert_array_equal(offsets, np.concatenate(
        [[0], np.cumsum(counts)[:-1]]))
    # dest is a permutation of [0, T)
    assert sorted(dest.tolist()) == list(range(len(ids)))
    # ranks stay within expert bucket
    assert (ranks < counts[np.asarray(ids)]).all()
    # stability: tokens of the same expert keep input order
    for e in range(E):
        tok = [t for t, i in enumerate(ids) if i == e]
        assert sorted(dest[tok].tolist()) == dest[tok].tolist()


def test_packed_segment_ids():
    lengths = jnp.asarray([3, 2, 4], jnp.int32)
    seg = packed_segment_ids(lengths, total=9)
    np.testing.assert_array_equal(
        np.asarray(seg), [0, 0, 0, 1, 1, 2, 2, 2, 2])


def test_packing_offsets_and_scatter():
    from repro.data.packing import pack_documents, packing_offsets
    lengths = jnp.asarray([3, 4, 2, 5, 1], jnp.int32)
    rows, cols = packing_offsets(lengths, row_len=8)
    rows, cols = np.asarray(rows), np.asarray(cols)
    # no document crosses its row boundary
    assert ((cols + np.asarray(lengths)) <= 8).all()
    # documents within a row do not overlap and are in order
    docs = jnp.asarray(np.arange(1, 5 * 6 + 1).reshape(5, 6), jnp.int32)
    toks, segs = pack_documents(docs, lengths, row_len=8, num_rows=3)
    toks, segs = np.asarray(toks), np.asarray(segs)
    # each document's tokens appear contiguously with its segment id
    for d, ln in enumerate(np.asarray(lengths)):
        r, c = rows[d], cols[d]
        np.testing.assert_array_equal(
            toks[r, c: c + ln], np.asarray(docs)[d, :ln])
        np.testing.assert_array_equal(segs[r, c: c + ln], d + 1)


def test_packing_zero_length_docs():
    """lengths == 0 entries must not perturb the packing of real docs
    or open phantom rows (regression: zero-length doc at a row boundary
    used to scatter a duplicate start flag onto the next doc's slot)."""
    from repro.data.packing import pack_documents, packing_offsets
    lengths = jnp.asarray([8, 0, 3, 0, 0, 5], jnp.int32)  # 8 fills a row
    rows, cols = packing_offsets(lengths, row_len=8)
    rows, cols = np.asarray(rows), np.asarray(cols)
    dense = np.asarray(lengths)[np.asarray(lengths) > 0]
    drows, dcols = packing_offsets(jnp.asarray(dense), row_len=8)
    np.testing.assert_array_equal(rows[np.asarray(lengths) > 0],
                                  np.asarray(drows))
    np.testing.assert_array_equal(cols[np.asarray(lengths) > 0],
                                  np.asarray(dcols))
    # packed output: zero-length docs contribute no tokens, no segments
    docs = jnp.asarray(np.arange(1, 6 * 9 + 1).reshape(6, 9), jnp.int32)
    toks, segs = pack_documents(docs, lengths, row_len=8, num_rows=3)
    assert int((np.asarray(segs) == 2).sum()) == 0  # doc 1 is empty
    assert int((np.asarray(segs) == 3).sum()) == 3  # doc 2 intact
    assert int((np.asarray(segs) == 6).sum()) == 5  # doc 5 intact


def test_segment_starts_tolerate_duplicate_starts():
    """Scatter-added begin-flags can exceed 1 where a zero-length doc
    collapses onto the next doc's start; ids must not skip (no phantom
    segments)."""
    from repro.data.packing import segment_starts_to_ids
    starts = jnp.asarray([1, 0, 2, 0, 1, 0], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(segment_starts_to_ids(starts)), [1, 1, 2, 2, 3, 3])


def test_dispatch_offsets_int32_guard():
    """Offsets stay int32 for normal sizes; totals at/after 2^31 demand
    x64 (the relational join build path leans on this guard)."""
    from repro.core.scan.segmented import _offsets_dtype
    assert _offsets_dtype(10) == jnp.int32
    assert _offsets_dtype(2 ** 31 - 1) == jnp.int32
    import jax as _jax
    if not _jax.config.jax_enable_x64:
        with pytest.raises(OverflowError):
            _offsets_dtype(2 ** 31)
    plan = dispatch_offsets(jnp.asarray([1, 0, 1], jnp.int32), 2)
    assert plan.offsets.dtype == jnp.int32
    assert plan.dest.dtype == jnp.int32


def test_moe_layer_forward_and_grad():
    from repro.models.config import ModelConfig
    from repro.models.layers.moe import apply_moe, init_moe
    cfg = ModelConfig(name="t", family="moe", d_model=32, num_heads=4,
                      num_kv_heads=4, head_dim=8, d_ff=64, moe_d_ff=64,
                      vocab_size=128, num_experts=4, top_k=2,
                      capacity_factor=2.0, dtype="float32")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(y ** 2) + aux.load_balance_loss
    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_moe_capacity_drops_accounted():
    """With a tiny capacity factor, dropped_fraction must be > 0 and the
    output for dropped tokens must be exactly zero (residual passthrough)."""
    from repro.models.config import ModelConfig
    from repro.models.layers.moe import apply_moe, init_moe
    cfg = ModelConfig(name="t", family="moe", d_model=16, num_heads=2,
                      num_kv_heads=2, head_dim=8, d_ff=32, moe_d_ff=32,
                      vocab_size=64, num_experts=2, top_k=2,
                      capacity_factor=0.1, dtype="float32")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 16))
    y, aux = apply_moe(params, x, cfg)
    assert float(aux.dropped_fraction) > 0.0
    assert bool(jnp.isfinite(y).all())


def test_top_p_sampling_uses_cumsum():
    from repro.serve.sampling import sample_logits
    key = jax.random.PRNGKey(0)
    logits = jnp.log(jnp.asarray(
        [[0.50, 0.30, 0.15, 0.04, 0.01]], jnp.float32))
    # top_p=0.6: nucleus = {0, 1} (0.5 alone < 0.6 needs one more)
    draws = [int(sample_logits(jax.random.fold_in(key, i), logits,
                               temperature=1.0, top_p=0.6)[0])
             for i in range(64)]
    assert set(draws) <= {0, 1}
    # greedy
    assert int(sample_logits(key, logits, temperature=0.0)[0]) == 0
