"""Roofline analyzer: HLO collective parsing + term arithmetic."""

import numpy as np
import pytest

from repro.roofline.analyze import (_parse_replica_groups, _shape_bytes,
                                    collective_bytes_from_hlo, model_flops)


def test_shape_bytes():
    assert _shape_bytes("f32[16,1024]") == 16 * 1024 * 4
    assert _shape_bytes("bf16[8,128,256]") == 8 * 128 * 256 * 2
    assert _shape_bytes("s8[100]") == 100
    assert _shape_bytes("pred[4]") == 4
    assert _shape_bytes("f32[]") == 4


def test_collective_parse_sync_and_async():
    hlo = """
      %ag = f32[64,128] all-gather(%p0), replica_groups={{0,1}}
      %ar.1 = bf16[32] all-reduce(%x), to_apply=%add
      %cp = f32[16] collective-permute(%y), source_target_pairs={{0,1}}
      %ags = (f32[8,8], f32[8,8]) all-gather-start(%a), dims={0}
      %agd = f32[8,8] all-gather-done(%ags)
      %rs = f32[4,4] reduce-scatter(%b), dimensions={0}
      %fusion = f32[99] fusion(%c), kind=kLoop
    """
    got = collective_bytes_from_hlo(hlo)
    # async -start tuples count the RESULT element once (not operand+result)
    assert got["all-gather"] == 64 * 128 * 4 + 8 * 8 * 4  # sync + start
    assert got["all-reduce"] == 32 * 2
    assert got["collective-permute"] == 16 * 4
    assert got["reduce-scatter"] == 4 * 4 * 4
    # done ops and non-collectives not double counted
    assert sum(got.values()) == (64 * 128 * 4 + 8 * 8 * 4 + 64 + 64 + 64)


def test_replica_group_iota_parsing():
    line = "replica_groups=[4,4]<=[16]"
    groups = list(_parse_replica_groups(line))
    assert groups[0] == [0, 1, 2, 3]
    assert groups[3] == [12, 13, 14, 15]

    line_t = "replica_groups=[4,4]<=[4,4]T(1,0)"
    groups_t = list(_parse_replica_groups(line_t))
    assert groups_t[0] == [0, 4, 8, 12]

    line_e = "replica_groups={{0,5},{1,6}}"
    groups_e = list(_parse_replica_groups(line_e))
    assert groups_e == [[0, 5], [1, 6]]


def test_cross_pod_detection():
    from repro.roofline.analyze import _cross_pod_bytes
    # group [0..255] stays in pod 0; [0,256] spans pods (256 chips/pod)
    hlo_in = "%ar = f32[100] all-reduce(%x), replica_groups={{0,255}}"
    hlo_span = "%ar = f32[100] all-reduce(%x), replica_groups={{0,256}}"
    assert _cross_pod_bytes(hlo_in, 256) == 0
    assert _cross_pod_bytes(hlo_span, 256) == 400
    # iota spanning: 2 groups of 256 -> in-pod; 256 groups of 2 (stride
    # 256 via transpose) -> spans
    hlo_iota = "%ag = f32[10] all-gather(%x), replica_groups=[256,2]<=[2,256]T(1,0)"
    assert _cross_pod_bytes(hlo_iota, 256) == 40


def test_model_flops_conventions():
    from repro import configs
    cfg = configs.get_config("granite-moe-1b-a400m")
    n_active = cfg.active_param_count()
    assert model_flops(cfg, 256, 4096, "train") == 6.0 * n_active * 256 * 4096
    assert model_flops(cfg, 32, 32768, "prefill") == 2.0 * n_active * 32 * 32768
    assert model_flops(cfg, 128, 32768, "decode") == 2.0 * n_active * 128


def test_end_to_end_tiny_lowering():
    """analyze_compiled on a real (1-device) compile produces finite terms."""
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.roofline.analyze import analyze_compiled
    cfg = configs.get_smoke_config("xlstm-125m")
    from repro.train.step import TrainStepConfig, make_train_step, init_params
    from repro.optim import adamw_init
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: init_params(k, cfg), key)
    opt_s = jax.eval_shape(adamw_init, params_s)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "mask": jax.ShapeDtypeStruct((2, 32), jnp.float32)}
    step = make_train_step(cfg, TrainStepConfig(remat=False))
    comp = jax.jit(step).lower(
        params_s, opt_s, batch, jax.ShapeDtypeStruct((), jnp.int32)
    ).compile()
    rep = analyze_compiled(comp, arch="xlstm-125m", shape="t", mesh_name="1",
                           chips=1, cfg=cfg, batch=2, seq=32, kind="train")
    assert rep.compute_s > 0 and rep.memory_s > 0
    assert rep.dominant in ("compute", "memory", "collective", "dcn")
    assert 0 < rep.useful_ratio
