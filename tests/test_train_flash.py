"""End-to-end training on the engine: dense | blockwise | flash peers.

``TrainStepConfig.attn_impl="flash"`` routes the tiny LM's attention
through the engine-backed kernel whose custom VJP runs the backward as
scan-engine folds. The wall: loss, per-leaf gradients, and one full
AdamW optimizer step must agree with the jnp autodiff peers within
float tolerance — training is no longer a detour through
``blockwise_ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm as lm_mod
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.step import TrainStepConfig, make_train_step

IMPLS = ("dense", "blockwise", "flash")


def _tiny_cfg(**over):
    base = dict(name="tiny-flash", family="dense", num_layers=2,
                d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=128, layer_pattern=("global",),
                dtype="float32")
    base.update(over)
    return ModelConfig(**base)


def _batch(rng, B=2, S=64, V=128):
    return {
        "tokens": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }


def _loss_and_grads(cfg, params, batch, impl, schedule="auto", remat=True):
    return jax.value_and_grad(
        lambda p: lm_mod.lm_loss(p, batch, cfg, attn_impl=impl,
                                 attn_schedule=schedule, remat=remat),
        has_aux=True)(params)


def _max_leaf_err(a, b):
    errs = jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree.leaves(errs))


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(np.random.default_rng(0))
    return cfg, params, batch


def test_loss_and_grad_parity_across_impls(setup):
    cfg, params, batch = setup
    results = {impl: _loss_and_grads(cfg, params, batch, impl)
               for impl in IMPLS}
    losses = {impl: float(r[0][0]) for impl, r in results.items()}
    for impl in ("blockwise", "flash"):
        assert abs(losses[impl] - losses["dense"]) < 1e-5, losses
    for impl in ("blockwise", "flash"):
        err = _max_leaf_err(results[impl][1], results["dense"][1])
        assert err < 1e-4, (impl, err)


@pytest.mark.parametrize("schedule", ["carry", "decoupled"])
def test_flash_grad_parity_both_schedules(setup, schedule):
    """The training route accepts an explicit fold schedule; both match
    the dense autodiff grads."""
    cfg, params, batch = setup
    (_, _), g_dense = _loss_and_grads(cfg, params, batch, "dense")
    (_, _), g_flash = _loss_and_grads(cfg, params, batch, "flash",
                                      schedule=schedule)
    assert _max_leaf_err(g_flash, g_dense) < 1e-4


def test_optimizer_step_parity(setup):
    """One full AdamW step per impl: identical parameter updates within
    tolerance — the end state of the grad-parity chain."""
    cfg, params, batch = setup
    acfg = AdamWConfig(lr=1e-3, grad_clip=1.0, weight_decay=0.1)
    stepped = {}
    for impl in IMPLS:
        (_, _), grads = _loss_and_grads(cfg, params, batch, impl)
        opt = adamw_init(params)
        new_params, _, _ = adamw_update(grads, opt, params, acfg, lr=1e-3)
        stepped[impl] = new_params
    for impl in ("blockwise", "flash"):
        err = _max_leaf_err(stepped[impl], stepped["dense"])
        assert err < 1e-4, (impl, err)
        # and the step actually moved the parameters
        assert _max_leaf_err(stepped[impl], params) > 1e-6


def test_make_train_step_runs_flash(setup):
    """The full jitted train step (remat + lax.scan over periods +
    chunked CE) accepts attn_impl='flash' and matches the blockwise
    route's loss and updated params."""
    cfg, params, batch = setup
    outs = {}
    for impl in ("blockwise", "flash"):
        step = jax.jit(make_train_step(
            cfg, TrainStepConfig(remat=True, attn_impl=impl,
                                 total_steps=10)))
        opt = adamw_init(params)
        new_p, _, metrics = step(params, opt, batch,
                                 jnp.zeros((), jnp.int32))
        outs[impl] = (new_p, float(metrics["loss"]))
    assert abs(outs["flash"][1] - outs["blockwise"][1]) < 1e-5
    assert _max_leaf_err(outs["flash"][0], outs["blockwise"][0]) < 1e-4


def test_gqa_model_flash_grads(setup):
    """GQA (4 q heads over 2 kv heads is the fixture); also exercise a
    softcapped config through the train loss."""
    cfg = _tiny_cfg(attn_softcap=30.0)
    params = lm_mod.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch(np.random.default_rng(1))
    (_, _), g_dense = _loss_and_grads(cfg, params, batch, "dense")
    (_, _), g_flash = _loss_and_grads(cfg, params, batch, "flash")
    assert _max_leaf_err(g_flash, g_dense) < 1e-4
