import sys

import numpy as np
import pytest

try:  # gate the optional property-testing dep (container may lack it)
    import hypothesis  # noqa: F401
except ImportError:
    import os
    import types

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback as _hf

    mod = types.ModuleType("hypothesis")
    mod.given = _hf.given
    mod.settings = _hf.settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("floats", "integers", "booleans", "sampled_from", "lists",
                 "tuples"):
        setattr(strategies, name, getattr(_hf, name))
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


def pytest_configure(config):
    # Exhaustive sweeps (large-shape grad walls) ride behind -m slow so
    # tools/verify.sh --fast and local iteration can deselect them with
    # -m "not slow"; the tier-1 run executes everything.
    config.addinivalue_line(
        "markers", "slow: exhaustive sweep; deselect with -m 'not slow'")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _bound_live_executables():
    # The full suite JITs thousands of programs into one process; past
    # ~500 tests the accumulated live executables can segfault XLA's CPU
    # client inside a later (tiny, unrelated) backend_compile. Dropping
    # the compilation caches at module teardown bounds that population;
    # each module recompiles its own programs, which it would on a
    # standalone run anyway.
    yield
    import jax

    jax.clear_caches()
