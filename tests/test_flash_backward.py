"""Gradient-parity wall for the engine-backed flash backward.

``flash_attention`` carries a ``jax.custom_vjp`` whose backward runs as
two scan-engine folds (dq over ``KVBlocks``, dk/dv over the transposed
``QBlocks``). The wall: dq/dk/dv under BOTH fold schedules must match
``jax.grad`` of the autodiff-able ``blockwise_ref`` AND of the dense
``mha_ref`` (atol 1e-4 f32) on every config of the 8-config grid
{causal, window, softcap, GQA 2/4, ragged kv_len, all-masked rows},
plus cross-schedule grad parity and split-invariance.

Also here: the regression tests for the reference guard — fully-masked
rows must emit exactly 0 with zero gradients (the unguarded softmax
leaked a uniform-average output and a nonzero cotangent into ``v``,
making the baseline ill-defined and grid-extent-dependent).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_bwd_kernel, flash_attention_kernel)

SCHEDULES = ("carry", "decoupled")

# (name, B, Hkv, group, Tq, Tk, D, causal, window, softcap, bq, bk)
CONFIGS = [
    ("causal", 2, 2, 1, 256, 256, 32, True, None, None, 128, 128),
    ("noncausal", 1, 2, 1, 256, 256, 32, False, None, None, 128, 128),
    ("window", 1, 2, 1, 256, 256, 32, True, 64, None, 64, 128),
    ("softcap", 1, 1, 1, 256, 256, 32, True, None, 30.0, 128, 128),
    ("gqa2", 2, 2, 2, 256, 256, 32, True, None, None, 128, 128),
    ("gqa4_window_cap", 1, 2, 4, 256, 256, 16, True, 96, 20.0, 128, 64),
    ("ragged_kv", 1, 2, 1, 300, 300, 32, True, None, None, 128, 128),
    ("ragged_kv_noncausal", 1, 1, 1, 200, 300, 16, False, None, None,
     128, 128),
]


def _rand_qkv(rng, B, Hq, Hkv, Tq, Tk, D, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, Hq, Tq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    return q, k, v


def _flat(x):
    B, H, T, D = x.shape
    return x.reshape(B * H, T, D)


def _loss_of(out_fn):
    """A non-trivial scalar so dO varies per element (sum alone would
    make every cotangent 1 and hide dP/delta mistakes)."""
    return lambda *ops: jnp.sum(out_fn(*ops) ** 2)


def _ref_grads(q, k, v, *, group, ref, block_k=64, **kw):
    B, Hq, Tq, D = q.shape

    def out(q, k, v):
        extra = {} if ref is fa_ref.mha_ref else {"block_k": block_k}
        return ref(_flat(q), _flat(k), _flat(v), group=group, **kw,
                   **extra).reshape(B, Hq, Tq, D)

    return jax.grad(_loss_of(out), argnums=(0, 1, 2))(q, k, v)


def _flash_grads(q, k, v, *, schedule, bq, bk, **kw):
    def out(q, k, v):
        return fa_ops.flash_attention(
            q, k, v, block_q=bq, block_k=bk, schedule=schedule,
            interpret=True, **kw)

    return jax.grad(_loss_of(out), argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_flash_grad_wall(cfg):
    """dq/dk/dv vs autodiff of blockwise AND dense refs, both schedules,
    plus carry-vs-decoupled cross-schedule parity — the acceptance bar
    (atol 1e-4 f32) for training on the engine."""
    name, B, Hkv, g, Tq, Tk, D, causal, window, softcap, bq, bk = cfg
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    q, k, v = _rand_qkv(rng, B, Hkv * g, Hkv, Tq, Tk, D)
    kw = dict(scale=D ** -0.5, causal=causal, window=window,
              softcap=softcap)
    refs = {
        "blockwise": _ref_grads(q, k, v, group=g, ref=fa_ref.blockwise_ref,
                                **kw),
        "dense": _ref_grads(q, k, v, group=g, ref=fa_ref.mha_ref, **kw),
    }
    flash = {s: _flash_grads(q, k, v, schedule=s, bq=bq, bk=bk, **kw)
             for s in SCHEDULES}
    for s in SCHEDULES:
        for rname, rg in refs.items():
            for leaf, (got, want) in enumerate(zip(flash[s], rg)):
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), atol=1e-4,
                    rtol=1e-4,
                    err_msg=f"{name}/{s} vs {rname} leaf {leaf}")
    # carry vs decoupled: same folds re-associated at chunk boundaries
    for got, want in zip(flash["carry"], flash["decoupled"]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("splits", [1, 2, 4, 8])
def test_flash_grad_split_invariance(splits):
    """The decoupled backward must not depend on the chunk count."""
    rng = np.random.default_rng(7)
    q, k, v = _rand_qkv(rng, 1, 2, 1, 128, 1024, 16)
    kw = dict(scale=0.25, causal=True)
    want = _ref_grads(q, k, v, group=2, ref=fa_ref.blockwise_ref, **kw)

    def out(q, k, v):
        return fa_ops.flash_attention(
            q, k, v, schedule="decoupled", kv_splits=splits, block_k=128,
            interpret=True, **kw)

    got = jax.grad(_loss_of(out), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_flash_grads_all_masked_rows(schedule):
    """Rows whose whole KV band is masked (q past kv_len + window) emit
    0 and must contribute ZERO gradient everywhere — no NaN, no leak."""
    rng = np.random.default_rng(17)
    Tq = Tk = 256
    D, kv_len, window = 16, 64, 32
    q = jnp.asarray(rng.standard_normal((2, Tq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, Tk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, Tk, D)), jnp.float32)

    # the kernel itself is not the custom_vjp carrier; drive its backward
    # explicitly like the ops wrapper does, with a cotangent that weights
    # ONLY the fully-masked rows: every gradient must vanish
    out, m, l = flash_attention_kernel(
        q, k, v, scale=D ** -0.5, causal=True, window=window,
        kv_len=kv_len, block_q=64, block_k=64, schedule=schedule,
        return_stats=True, interpret=True)
    g = jnp.zeros_like(out).at[:, kv_len + window:].set(
        2.0 * out[:, kv_len + window:])
    delta = jnp.sum(g * out, axis=-1, keepdims=True)
    dq, dk, dv = flash_attention_bwd_kernel(
        q, k, v, g, m, l, delta, scale=D ** -0.5, causal=True,
        window=window, kv_len=kv_len, block_q=64, block_k=64,
        schedule=schedule, interpret=True)
    for name, arr in [("dq", dq), ("dk", dk), ("dv", dv)]:
        assert not bool(jnp.any(jnp.isnan(arr))), name
        assert float(jnp.max(jnp.abs(arr))) == 0.0, name


@pytest.mark.parametrize(
    "ref", [fa_ref.mha_ref, fa_ref.blockwise_ref],
    ids=["mha_ref", "blockwise_ref"])
def test_reference_fully_masked_rows_guarded(ref):
    """Regression for the reference guard: fully-masked rows previously
    returned the uniform average of the masked values (an output that
    depends on how many masked columns the formulation visits) and
    leaked a nonzero cotangent into v under autodiff. Now: exactly 0
    forward, exactly 0 gradients, no NaN."""
    rng = np.random.default_rng(3)
    Tq = Tk = 128
    D, kv_len, window = 16, 32, 16
    q = jnp.asarray(rng.standard_normal((2, Tq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, Tk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, Tk, D)), jnp.float32)
    kw = dict(scale=D ** -0.5, causal=True, window=window, kv_len=kv_len)

    out = ref(q, k, v, **kw)
    dead = kv_len + window
    assert bool(jnp.all(out[:, dead:] == 0.0))
    assert not bool(jnp.any(jnp.isnan(out)))

    def loss(q, k, v):
        return jnp.sum(ref(q, k, v, **kw)[:, dead:] ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert not bool(jnp.any(jnp.isnan(g)))
        assert float(jnp.max(jnp.abs(g))) == 0.0


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_flash_grads_bf16_finite(schedule):
    """bf16 operands: grads come back in bf16, finite, and loosely track
    the f32 reference (the backward accumulates in f32 internally)."""
    rng = np.random.default_rng(13)
    q, k, v = _rand_qkv(rng, 1, 4, 2, 128, 128, 32, jnp.bfloat16)

    def out(q, k, v):
        return fa_ops.flash_attention(q, k, v, scale=32 ** -0.5,
                                      schedule=schedule, interpret=True)

    got = jax.grad(_loss_of(out), argnums=(0, 1, 2))(q, k, v)
    want = _ref_grads(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), group=2, ref=fa_ref.blockwise_ref,
        scale=32 ** -0.5, causal=True)
    for g, w in zip(got, want):
        assert g.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w), atol=0.15, rtol=0.15)


def test_flash_grad_under_jit_and_vjp_api():
    """The custom_vjp composes with jit and jax.vjp (the train step uses
    value_and_grad under jit under lax.scan)."""
    rng = np.random.default_rng(23)
    q, k, v = _rand_qkv(rng, 1, 2, 1, 128, 128, 16)

    @jax.jit
    def loss(q, k, v):
        return jnp.sum(fa_ops.flash_attention(
            q, k, v, scale=0.25, interpret=True) ** 2)

    out, pullback = jax.vjp(loss, q, k, v)
    dq, dk, dv = pullback(jnp.ones(()))
    want = _ref_grads(q, k, v, group=2, ref=fa_ref.blockwise_ref,
                      scale=0.25, causal=True)
    for a, b in zip((dq, dk, dv), want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_flash_grad_wall_large(schedule):
    """Larger-shape sweep (T=512, GQA, window+softcap together) — the
    exhaustive tail of the wall, behind -m slow."""
    rng = np.random.default_rng(29)
    B, Hkv, g, T, D = 2, 2, 2, 512, 32
    q, k, v = _rand_qkv(rng, B, Hkv * g, Hkv, T, T, D)
    kw = dict(scale=D ** -0.5, causal=True, window=160, softcap=25.0)
    want = _ref_grads(q, k, v, group=g, ref=fa_ref.blockwise_ref,
                      block_k=128, **kw)
    got = _flash_grads(q, k, v, schedule=schedule, bq=128, bk=128, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
