"""Causal-aware KV bounds: bitwise identity + strictly fewer cells.

The attention fold layouts (``KVBlocks`` forward/dq, ``QBlocks`` dk/dv)
carry an optional per-q-block KV extent ``(causal, window, kv_len)``;
the fold schedules skip grid cells whose mask is provably all-dead.
With the zeroed-probability convention a skipped cell's element is the
monoid identity, so:

  * forward outputs and dq/dk/dv are BITWISE identical bound-on vs
    bound-off, under both fold schedules;
  * causal prefill executes ~half the cells (instrumented count +
    analytic ``active_cells``);
  * the liveness predicate is conservative: every skipped cell is
    verifiably all-masked against the dense mask.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import scan_engine
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_bwd_kernel, flash_attention_kernel)

SCHEDULES = ("carry", "decoupled")

BOUND_CONFIGS = [
    # (name, Tq, Tk, D, causal, window, kv_len, bq, bk)
    ("causal", 256, 256, 16, True, None, None, 64, 64),
    ("causal_window", 256, 256, 16, True, 96, None, 64, 64),
    ("causal_short_kv", 256, 256, 16, True, None, 160, 64, 64),
    ("window_all_masked_tail", 256, 256, 16, True, 32, 64, 64, 64),
    ("noncausal", 128, 256, 16, False, None, 200, 64, 64),
]


def _qkv(rng, Tq, Tk, D, H=2):
    q = jnp.asarray(rng.standard_normal((H, Tq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((H, Tk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((H, Tk, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize(
    "cfg", BOUND_CONFIGS, ids=[c[0] for c in BOUND_CONFIGS])
def test_forward_bitwise_bound_on_off(cfg, schedule):
    name, Tq, Tk, D, causal, window, kv_len, bq, bk = cfg
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    q, k, v = _qkv(rng, Tq, Tk, D)
    kw = dict(scale=D ** -0.5, causal=causal, window=window,
              kv_len=kv_len, block_q=bq, block_k=bk, schedule=schedule,
              interpret=True)
    on = flash_attention_kernel(q, k, v, use_kv_bounds=True, **kw)
    off = flash_attention_kernel(q, k, v, use_kv_bounds=False, **kw)
    assert bool(jnp.all(on == off)), f"{name}/{schedule} diverged"


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize(
    "cfg", BOUND_CONFIGS, ids=[c[0] for c in BOUND_CONFIGS])
def test_backward_bitwise_bound_on_off(cfg, schedule):
    name, Tq, Tk, D, causal, window, kv_len, bq, bk = cfg
    rng = np.random.default_rng(abs(hash(name)) % 2**31 + 1)
    q, k, v = _qkv(rng, Tq, Tk, D)
    kw = dict(scale=D ** -0.5, causal=causal, window=window,
              kv_len=kv_len, block_q=bq, block_k=bk, schedule=schedule,
              interpret=True)
    out, m, l = flash_attention_kernel(q, k, v, return_stats=True,
                                       use_kv_bounds=True, **kw)
    g = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)
    delta = jnp.sum(g * out, axis=-1, keepdims=True)
    grads = {
        b: flash_attention_bwd_kernel(q, k, v, g, m, l, delta,
                                      use_kv_bounds=b, **kw)
        for b in (True, False)
    }
    for leaf, (a, b) in enumerate(zip(grads[True], grads[False])):
        assert bool(jnp.all(a == b)), f"{name}/{schedule} leaf {leaf}"


def test_causal_prefill_cell_count_instrumented():
    """Causal prefill must EXECUTE ~half the (q-block, kv-block) cells:
    the carry fold's count_cells instrumentation returns the per-row
    executed counts, which must equal the analytic ``active_cells`` and
    be strictly fewer than the full grid."""
    rng = np.random.default_rng(0)
    Tq = Tk = 1024
    D, bq, bk = 16, 128, 128
    q, k, v = _qkv(rng, Tq, Tk, D)
    out, counts = flash_attention_kernel(
        q, k, v, scale=D ** -0.5, causal=True, block_q=bq, block_k=bk,
        count_cells=True, interpret=True)
    nq = nk = Tq // bq
    layout = scan_engine.KVBlocks(
        bh=2, bh_kv=2, tq=Tq, tk=Tk, d=D, bq=bq, bk=bk,
        kv_bounds=(True, None, Tk))
    per_row = layout.active_cells()
    assert counts.shape == (2, nq)
    assert int(counts.sum()) == 2 * per_row
    # causal: the lower block triangle, nq(nq+1)/2 of nq² cells
    assert per_row == nq * (nq + 1) // 2
    full = nq * nk
    assert per_row < full
    assert per_row / full <= 0.6  # ~half, plus the diagonal
    # and the instrumented run's output is bitwise the uninstrumented one
    plain = flash_attention_kernel(
        q, k, v, scale=D ** -0.5, causal=True, block_q=bq, block_k=bk,
        interpret=True)
    assert bool(jnp.all(out == plain))


def test_bounds_off_counts_full_grid():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 256, 256, 16)
    _, counts = flash_attention_kernel(
        q, k, v, scale=0.25, causal=True, block_q=64, block_k=64,
        use_kv_bounds=False, count_cells=True, interpret=True)
    assert int(counts.sum()) == 2 * 4 * 4


def test_qblocks_active_cells_matches_kvblocks():
    """The transposed backward layout skips the SAME (qi, kj) cells —
    group-scaled, since each q head of the group walks the plane."""
    for window, kv_len in [(None, None), (96, None), (None, 160)]:
        bounds = (True, window, kv_len if kv_len is not None else 256)
        kv = scan_engine.KVBlocks(bh=4, bh_kv=2, tq=256, tk=256, d=16,
                                  bq=64, bk=64, group=2, kv_bounds=bounds)
        qb = scan_engine.QBlocks(bh=4, bh_kv=2, tq=256, tk=256, d=16,
                                 bq=64, bk=64, group=2, kv_bounds=bounds)
        assert qb.active_cells() == 2 * kv.active_cells()


@pytest.mark.parametrize("window,kv_len,causal", [
    (None, 256, True), (96, 256, True), (None, 160, True),
    (32, 64, True), (None, 200, False), (64, 100, True)])
def test_block_live_is_conservative(window, kv_len, causal):
    """Property: whenever the liveness predicate says DEAD, every
    (row, col) in the cell is masked under the dense mask — skipping is
    provably exact. And every LIVE cell it reports for causal/kv_len
    bounds alone contains a live entry (the bound is tight there)."""
    Tq = Tk = 256
    bq = bk = 64
    rows = np.arange(Tq)[:, None]
    cols = np.arange(Tk)[None, :]
    mask = np.broadcast_to(cols < kv_len, (Tq, Tk))
    if causal:
        mask = mask & (cols <= rows)
    if window is not None:
        mask = mask & (cols > rows - window)
    for qi in range(Tq // bq):
        for kj in range(Tk // bk):
            cell = mask[qi * bq:(qi + 1) * bq, kj * bk:(kj + 1) * bk]
            live = scan_engine.block_live(
                qi, kj, bq=bq, bk=bk, causal=causal, window=window,
                kv_len=kv_len)
            if not live:
                assert not cell.any(), (qi, kj)
            elif window is None:
                # without a window the predicate is exact, not merely
                # conservative
                assert cell.any(), (qi, kj)


def test_degenerate_bounds_count_full_grid():
    """Regression: kv_bounds=(False, None, None) has no live constraint
    — block_live would be the python constant True, which the schedule
    bodies can't trace. fold_active must normalize it to "no bound" so
    count_cells still works and reports the full grid."""
    from repro.core.scan.assoc import softmax_pair_kernel_spec

    lay = scan_engine.KVBlocks(bh=2, bh_kv=2, tq=128, tk=128, d=16,
                               bq=64, bk=64,
                               kv_bounds=(False, None, None))
    assert lay.fold_active((0, 0, 0)) is None
    assert lay.active_cells() == 4
    rng = np.random.default_rng(11)
    q, k, v = _qkv(rng, 128, 128, 16)
    spec = softmax_pair_kernel_spec(scale=0.25, causal=False,
                                    block_q=64, block_k=64)
    (out,), counts = scan_engine.scan(
        (q, k, v), spec, lay, schedule="carry", interpret=True,
        count_cells=True)
    assert int(counts.sum()) == 2 * 4


def test_flash_attention_grad_bitwise_with_bounds_knob():
    """End to end through the public wrapper + custom_vjp: grads with
    the bounds knob on vs off are bitwise identical."""
    rng = np.random.default_rng(5)
    B, Hq, Hkv, T, D = 1, 4, 2, 256, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)

    def grads(use_bounds):
        def loss(q, k, v):
            return jnp.sum(fa_ops.flash_attention(
                q, k, v, causal=True, window=96,
                use_kv_bounds=use_bounds, interpret=True) ** 2)
        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    for a, b in zip(grads(True), grads(False)):
        assert bool(jnp.all(a == b))
