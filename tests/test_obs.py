"""Observability layer tests (ISSUE 7).

The contract under test:

  * the tracer's export is valid Chrome ``trace_event`` JSON with
    properly nested spans, and the DISABLED tracer is a true no-op —
    serve outputs are bitwise identical with tracing on or off;
  * streaming histograms land within one log-bucket (~9%) of numpy
    percentiles without storing samples;
  * the policy ``explain_*`` surface returns the documented branch at
    each boundary, agrees with ``choose_*``, and emits decision events;
  * ``EngineStats`` attached to a metrics registry stays write-through
    identical to the dataclass under a seeded chaos run, and
    ``summary()`` prints every monotonic counter ``as_dict`` carries;
  * ``time_fn``'s ``TimingStats`` is a float that remembers the run,
    ``Table.to_records()`` serializes it, and ``tools/bench_gate.py``
    passes a self-diff, fails an injected 2x slowdown, and validates
    the committed ``BENCH_*.json`` baselines;
  * the scan engine emits a ``kernel.launch`` event per compilation.
"""

import copy
import dataclasses
import glob
import json
import math
import os
import sys
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:  # benchmarks/ + tools/ live at the repo root
    sys.path.insert(0, _REPO)

from benchmarks.common import Table, TimingStats, time_fn  # noqa: E402
from tools import bench_gate  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.scan import policy  # noqa: E402
from repro.obs import Registry, trace  # noqa: E402
from repro.obs.metrics import Histogram  # noqa: E402
from repro.serve import (Engine, EngineConfig, FaultInjector,  # noqa: E402
                         Request)
from repro.train.step import init_params  # noqa: E402


@pytest.fixture
def tracer():
    """A live tracer, guaranteed disabled again afterwards."""
    t = trace.enable()
    t.clear()
    yield t
    trace.disable()


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(configs.get_smoke_config("stablelm-12b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, injector=None, metrics=None, n=4, seed=7):
    rng = np.random.default_rng(seed)
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1,
        temperature=0.0), injector=injector, metrics=metrics)
    for rid in range(n):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            2, 500, size=int(rng.integers(3, 9))).astype(np.int32)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        done = eng.run_to_completion()
    eng.audit()
    return eng, {r.rid: list(r.output) for r in done}


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_schema(tracer, tmp_path):
    with trace.span("outer", depth=0):
        with trace.span("inner", depth=1):
            trace.instant("marker", k="v")
        trace.counter("queue", depth=3)
    path = tmp_path / "t.json"
    doc = trace.export(str(path))

    # File round-trips as JSON and matches the in-memory doc.
    assert json.loads(path.read_text()) == doc
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    assert set(by_name) == {"outer", "inner", "marker", "queue"}

    # Chrome trace_event invariants per phase.
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        assert isinstance(e["ts"], float) and e["ts"] >= 0
    assert by_name["outer"]["ph"] == "X" and by_name["inner"]["ph"] == "X"
    assert by_name["marker"]["ph"] == "i" and by_name["marker"]["s"] == "t"
    assert by_name["queue"]["ph"] == "C"
    assert by_name["queue"]["args"] == {"depth": 3}

    # Nesting = containment on the same track: inner within outer,
    # marker within inner.
    outer, inner, marker = (by_name[k] for k in ("outer", "inner", "marker"))
    assert outer["tid"] == inner["tid"] == threading.get_ident() % 1_000_000
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["ts"] <= marker["ts"] <= inner["ts"] + inner["dur"]
    assert inner["args"] == {"depth": 1}


def test_span_records_even_when_body_raises(tracer):
    with pytest.raises(RuntimeError):
        with trace.span("doomed"):
            raise RuntimeError("boom")
    assert [e["name"] for e in tracer.events()] == ["doomed"]


def test_ring_buffer_bounds_memory():
    t = trace.enable(capacity=8)
    try:
        for i in range(50):
            trace.instant("e", i=i)
        evs = t.events()
        assert len(evs) == 8
        assert [e["args"]["i"] for e in evs] == list(range(42, 50))
    finally:
        trace.disable()


def test_disabled_tracer_is_noop():
    trace.disable()
    assert not trace.enabled()
    # No allocation path: the shared no-op span comes back identically.
    s1, s2 = trace.span("a", x=1), trace.span("b")
    assert s1 is s2
    trace.instant("a")
    trace.counter("a", v=1)
    assert trace.export()["traceEvents"] == []


def test_jsonable_coerces_exotic_args(tracer):
    trace.instant("e", arr=np.int64(3), tup=(1, "a"), d={"k": np.float32(2)})
    args = tracer.events()[0]["args"]
    assert json.loads(json.dumps(args)) == args  # JSON-safe
    assert args["tup"] == [1, "a"]


def test_serve_outputs_bitwise_identical_with_tracing(small_model):
    cfg, params = small_model
    trace.disable()
    _, base = _serve(cfg, params)
    t = trace.enable()
    try:
        _, traced = _serve(cfg, params)
        assert t.events(), "tracing on but nothing recorded"
    finally:
        trace.disable()
    assert traced == base  # token-for-token identical histories


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_histogram_percentiles_track_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-3.0, sigma=1.2, size=5000)
    h = Histogram()
    for s in samples:
        h.record(float(s))
    for q in (50.0, 90.0, 99.0):
        want = float(np.percentile(samples, q))
        got = h.percentile(q)
        assert abs(got - want) / want < 0.10, (q, got, want)
    s = h.summary()
    assert s["count"] == 5000
    assert s["min"] == pytest.approx(samples.min())
    assert s["max"] == pytest.approx(samples.max())
    assert s["mean"] == pytest.approx(samples.mean())


def test_histogram_edge_cases():
    h = Histogram()
    assert np.isnan(h.percentile(50))
    h.record(0.0)  # non-positive lands in the underflow bucket
    h.record(2.5)
    assert h.count == 2 and h.min == 0.0 and h.max == 2.5
    assert h.percentile(0) <= h.percentile(100) == 2.5


def test_histogram_non_positive_observations_never_reach_log():
    """Regression wall: ``record`` must route v <= 0 to the underflow
    bucket BEFORE the log-bucket index — ``math.log`` on zero/negative
    raises. Latency histograms do see exact zeros (clock granularity)
    and negatives (wall-clock steps backward under NTP slew)."""
    h = Histogram()
    for v in (0.0, -1.0, -1e-9, -math.inf):
        h.record(v)               # must not raise
    assert h.count == 4
    assert h._underflow == 4
    assert h._buckets == {}       # nothing indexed into the log buckets
    # summary/percentiles stay finite-path (no NaN from the log)
    assert h.percentile(50.0) == h.min == -math.inf
    h2 = Histogram()
    h2.record(-2.0)
    h2.record(0.0)
    h2.record(1.0)
    h2.record(4.0)
    assert h2._underflow == 2 and h2.count == 4
    s = h2.summary()
    assert s["count"] == 4 and s["min"] == -2.0 and s["max"] == 4.0
    assert s["mean"] == pytest.approx(0.75)
    # underflow mass pins the low percentiles at/below zero, the
    # positive mass keeps the high ones in the log buckets
    assert h2.percentile(0.0) <= 0.0
    assert 0.0 < h2.percentile(99.0) <= 4.0
    # monotone in q even across the underflow/bucket seam
    qs = [h2.percentile(q) for q in (0, 25, 50, 75, 100)]
    assert qs == sorted(qs)


def test_registry_snapshot_and_reset():
    reg = Registry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(1.5)
    reg.histogram("h").record(0.25)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 1.5
    assert snap["histograms"]["h"]["count"] == 1
    assert json.loads(json.dumps(snap)) == snap
    assert reg.names() == ["c", "g", "h"]
    reg.reset()
    assert reg.names() == []


# ---------------------------------------------------------------------------
# policy explain surface
# ---------------------------------------------------------------------------

def test_explain_schedule_branches_and_boundaries():
    cores = 8
    # batch >= cores: rows fill the machine.
    d = policy.explain_schedule(cores, 1 << 20, cores=cores)
    assert d.value == "carry" and "fill every core" in d.reason
    # Exactly at the flip: batch one short of cores, plenty of chunks.
    d = policy.explain_schedule(cores - 1, 1 << 20, cores=cores)
    assert d.value == "carry"  # spare = 8//7 = 1 < 2: nothing to feed
    d = policy.explain_schedule(1, 1 << 20, cores=cores)
    assert d.value == "fused" and "spread the row" in d.reason
    assert d.inputs["spare"] == cores
    d = policy.explain_schedule(1, 1 << 20, cores=cores, prefer_fused=False)
    assert d.value == "decoupled"
    # Short row: chunks < spare cores.
    d = policy.explain_schedule(1, 1024, cores=cores, block_elems=2048)
    assert d.value == "carry" and "nothing to spread" in d.reason
    # explain == choose, everywhere on a small grid.
    for b in (1, 2, 7, 8, 64):
        for n in (512, 1 << 14, 1 << 22):
            assert (policy.explain_schedule(b, n).value
                    == policy.choose_schedule(b, n))


def test_explain_attention_schedule_branches():
    cores = 8
    # Decode shape: one row, long chain -> split-KV via the idle-core rule.
    d = policy.explain_attention_schedule(1, 4096, cores=cores)
    assert d.value == "decoupled" and "cores idle" in d.reason
    # Saturated rows + short chain -> carry.
    d = policy.explain_attention_schedule(64, 4096, cores=cores)
    assert d.value == "carry"
    # Long-context rule: chain >= SPLIT_KV_CHUNKS, rows below the cap.
    kv = policy.SPLIT_KV_CHUNKS * 128
    d = policy.explain_attention_schedule(16, kv, cores=cores)
    assert d.value == "decoupled" and "dominates" in d.reason
    # One chunk short of the threshold: carry again.
    d = policy.explain_attention_schedule(16, kv - 128, cores=cores)
    assert d.value == "carry"
    # Rows at the saturation cap: splitting returns nothing.
    d = policy.explain_attention_schedule(
        cores * policy.SPLIT_KV_ROW_CAP, kv, cores=cores)
    assert d.value == "carry"
    for rows in (1, 8, 64, 128):
        for kv_len in (512, 1 << 15, 1 << 20):
            assert (policy.explain_attention_schedule(rows, kv_len).value
                    == policy.choose_attention_schedule(rows, kv_len))


def test_policy_decisions_emit_trace_events(tracer):
    policy.explain_schedule(1, 1 << 20)
    policy.explain_attention_schedule(1, 4096)
    policy.choose(1 << 22)
    names = [e["name"] for e in tracer.events()]
    assert "policy.schedule" in names
    assert "policy.attention_schedule" in names
    assert "policy.choose" in names
    ev = next(e for e in tracer.events() if e["name"] == "policy.schedule")
    assert ev["args"]["value"] == "fused"
    assert ev["args"]["batch"] == 1 and "reason" in ev["args"]


def test_choice_carries_inputs_without_breaking_equality():
    a = policy.choose(1 << 22)
    b = copy.copy(a)
    object.__setattr__(b, "inputs", {})
    assert a == b  # inputs excluded from comparison
    assert a.inputs["n"] == 1 << 22 and "schedule" not in a.inputs


# ---------------------------------------------------------------------------
# EngineStats <-> registry mirroring
# ---------------------------------------------------------------------------

def test_engine_stats_summary_prints_every_counter(small_model):
    from repro.serve.stats import EngineStats
    st = EngineStats()
    # Drive every int counter to a distinct nonzero value so a dropped
    # field cannot hide behind a zero.
    for i, (k, v) in enumerate(st.as_dict().items()):
        if isinstance(v, int) and k != "total_finished":
            setattr(st, k, i + 2)
    st.record_finish("eos")
    text = st.summary()
    missing = [k for k, v in st.as_dict().items()
               if isinstance(v, int) and k not in (
                   "total_finished", "queue_depth")  # gauge, not monotonic
               and str(getattr(st, k, v)) not in text]
    # Name-level check: the once-dropped counters must appear by name.
    for name in ("prefill_retries", "nonfinite", "slow_ticks",
                 "prefill_evictions"):
        assert name in text, f"summary() dropped {name}"
    assert not missing, f"summary() lost counters: {missing}"


def test_engine_stats_registry_parity_under_chaos(small_model):
    cfg, params = small_model
    reg = Registry()
    inj = FaultInjector.from_seed(3, ticks=40, p_error=0.15, p_nan=0.15,
                                  p_stall=0.05, stall_s=0.002,
                                  poison_rids=[2])
    eng, _ = _serve(cfg, params, injector=inj, metrics=reg, n=4)
    st = eng.stats.as_dict()
    snap = reg.snapshot()
    # Something actually happened under chaos.
    assert st["step_retries"] > 0 or st["degradations"] > 0
    # Every int counter mirrors into a gauge, value-identical.
    for k, v in st.items():
        if isinstance(v, int):
            assert snap["gauges"][f"serve.stats.{k}"] == v, k
    # Finishes mirror into per-reason counters.
    for reason, nn in st["finished"].items():
        assert snap["counters"][f"serve.finished.{reason}"] == nn
    # The engine also feeds the tick-latency histogram.
    assert snap["histograms"]["serve.tick_s"]["count"] == st["ticks"]


def test_engine_without_registry_has_no_mirror(small_model):
    cfg, params = small_model
    eng, _ = _serve(cfg, params, n=2)
    assert getattr(eng.stats, "_registry", None) is None


# ---------------------------------------------------------------------------
# bench trajectory: TimingStats, Table.to_records, the gate
# ---------------------------------------------------------------------------

def test_timing_stats_is_a_float_with_memory():
    t = TimingStats([0.3, 0.1, 0.2])
    assert float(t) == pytest.approx(0.2)      # median
    assert (t.t_min, t.t_max, t.iters) == (0.1, 0.3, 3)
    ms = t * 1e3
    assert isinstance(ms, TimingStats)
    assert float(ms) == pytest.approx(200.0)
    assert ms.t_max == pytest.approx(300.0)
    assert isinstance(1e3 * t, TimingStats)
    half = t / 2
    assert isinstance(half, TimingStats)
    assert half.t_min == pytest.approx(0.05)
    # Degrades to plain float when stats stop being meaningful.
    assert not isinstance(t * t, TimingStats)
    assert not isinstance(5.0 / t, TimingStats)
    assert t.to_dict() == {"p50": float(t), "min": 0.1, "max": 0.3,
                           "iters": 3}
    assert f"{t:.4g}" == "0.2"                 # table formatter path


def test_time_fn_returns_timing_stats():
    t = time_fn(lambda: jnp.ones(4), iters=3, warmup=1)
    assert isinstance(t, TimingStats)
    assert t.iters == 3 and 0 < t.t_min <= float(t) <= t.t_max


def test_table_to_records_round_trips():
    t = Table("demo", ["name", "n", "Belem/s", "ms"])
    t.add("row", np.int64(4), np.float64(1.5), TimingStats([1.0, 2.0, 3.0]))
    rec = t.to_records()
    assert json.loads(json.dumps(rec)) == rec
    assert rec["columns"] == ["name", "n", "Belem/s", "ms"]
    name, n, tput, ms = rec["rows"][0]
    assert (name, n, tput) == ("row", 4, 1.5)
    assert ms == {"p50": 2.0, "min": 1.0, "max": 3.0, "iters": 3}


def _doc(rows, columns=("name", "Belem/s", "ms"), suite="engine"):
    return {"schema": bench_gate.SCHEMA, "suites": {suite: [{
        "title": "t", "columns": list(columns), "rows": rows}]}}


def test_bench_gate_passes_self_and_fails_2x_slowdown():
    base = _doc([["sum", 2.0, {"p50": 0.1, "min": 0.09, "max": 0.2,
                               "iters": 3}]])
    assert bench_gate.gate(copy.deepcopy(base), {"engine": base},
                           out=lambda *_: None) == []
    slow = copy.deepcopy(base)
    slow["suites"]["engine"][0]["rows"][0][2]["p50"] = 0.2  # 2x > 1.75x
    fails = bench_gate.gate(slow, {"engine": base}, out=lambda *_: None)
    assert len(fails) == 1 and "ms" in fails[0]
    # Generous tolerance swallows it; getting FASTER never fails.
    assert bench_gate.gate(slow, {"engine": base}, time_tol=3.0,
                           out=lambda *_: None) == []
    fast = copy.deepcopy(base)
    fast["suites"]["engine"][0]["rows"][0][2]["p50"] = 0.01
    assert bench_gate.gate(fast, {"engine": base},
                           out=lambda *_: None) == []


def test_bench_gate_rules_by_cell_kind():
    base = _doc([["sum", 2.0, {"p50": 0.1, "min": 0.1, "max": 0.1,
                               "iters": 1}]])
    # Throughput is inverted: collapsing Belem/s fails, rising doesn't.
    slow_tput = copy.deepcopy(base)
    slow_tput["suites"]["engine"][0]["rows"][0][1] = 0.5
    assert bench_gate.gate(slow_tput, {"engine": base},
                           out=lambda *_: None)
    fast_tput = copy.deepcopy(base)
    fast_tput["suites"]["engine"][0]["rows"][0][1] = 8.0
    assert not bench_gate.gate(fast_tput, {"engine": base},
                               out=lambda *_: None)
    # String drift (parity cell flipping to DIVERGED) fails.
    diverged = copy.deepcopy(base)
    diverged["suites"]["engine"][0]["rows"][0][0] = "DIVERGED"
    assert bench_gate.gate(diverged, {"engine": base},
                           out=lambda *_: None)
    # Structural drift: a lost row fails.
    short = copy.deepcopy(base)
    short["suites"]["engine"][0]["rows"] = []
    assert bench_gate.gate(short, {"engine": base}, out=lambda *_: None)
    # Disjoint suites gate nothing (reported, not failed).
    assert not bench_gate.gate(base, {"other": base}, out=lambda *_: None)


def test_bench_gate_schema_checker():
    good = _doc([["sum", 2.0, 0.1]])
    assert bench_gate.check_schema(good) == []
    assert bench_gate.check_schema({"schema": "nope", "suites": {}})
    ragged = _doc([["sum", 2.0]])  # row shorter than columns
    assert any("shape" in e for e in bench_gate.check_schema(ragged))


def test_committed_baselines_are_valid():
    paths = glob.glob(os.path.join(_REPO, "BENCH_*.json"))
    assert {os.path.basename(p) for p in paths} >= {
        "BENCH_engine.json", "BENCH_attention.json", "BENCH_serve.json"}
    for path in paths:
        doc = json.load(open(path))
        assert bench_gate.check_schema(doc, path) == []
        suite = os.path.basename(path)[len("BENCH_"):-len(".json")]
        assert suite in doc["suites"]
        assert doc["environment"]["backend"]  # provenance recorded
        # A baseline must gate cleanly against itself.
        assert bench_gate.gate(copy.deepcopy(doc), {suite: doc},
                               out=lambda *_: None) == []


# ---------------------------------------------------------------------------
# kernel launch events
# ---------------------------------------------------------------------------

def test_kernel_launch_event_per_compilation(tracer):
    from repro.kernels.scan_blocked import ops as sb_ops
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 2048)),
                    jnp.float32)
    sb_ops.cumsum(x, interpret=True, schedule="decoupled", block_n=512)
    evs = [e for e in tracer.events() if e["name"] == "kernel.launch"]
    assert evs, "no kernel.launch event for a fresh scan"
    args = evs[0]["args"]
    assert args["monoid"] == "sum" and args["schedule"] == "decoupled"
    # Launch grid: row blocks x 4 sequence chunks (2048 / block_n=512).
    assert args["grid"][-1] == 4 and len(args["grid"]) == 2
    # Decoupled reads the data twice (reduce pass + rescan pass).
    assert args["hbm_read_bytes_est"] == 2 * args["hbm_write_bytes_est"]
    assert args["vmem_block_bytes_est"] > 0
