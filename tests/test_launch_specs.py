"""Launch layer: input specs, state specs, shape bookkeeping (no mesh)."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import SHAPES, cells
from repro.launch.specs import input_specs, state_specs


def test_all_cells_have_specs():
    count = 0
    for arch in configs.ARCHS:
        for shape in cells(arch):
            specs = input_specs(arch, shape)
            assert specs, (arch, shape)
            count += 1
    assert count == 34


@pytest.mark.parametrize("arch", ["gemma3-12b", "qwen3-moe-235b-a22b",
                                  "seamless-m4t-large-v2",
                                  "llava-next-mistral-7b"])
def test_train_specs_shapes(arch):
    cfg = configs.get_config(arch)
    specs = input_specs(arch, "train_4k", cfg)
    batch = specs["batch"]
    sp = SHAPES["train_4k"]
    assert batch["tokens"].shape[0] == sp.global_batch
    total = batch["tokens"].shape[1]
    if "embeds" in batch:
        total += batch["embeds"].shape[1]
    assert total == sp.seq_len  # frontend + text = the assigned seq_len
    assert batch["tokens"].dtype == jnp.int32


def test_decode_specs_have_cache():
    specs = input_specs("phi3-medium-14b", "decode_32k")
    assert specs["tokens"].shape == (128, 1)  # ONE new token
    leaves = jax.tree.leaves(specs["cache"])
    assert leaves, "decode must carry a cache"
    # KV cache covers the full 32k context
    assert any(32_768 in l.shape for l in leaves)


def test_encdec_decode_has_memory():
    specs = input_specs("seamless-m4t-large-v2", "decode_32k")
    assert "memory" in specs
    assert specs["memory"].shape[0] == 128


def test_state_specs_no_allocation_and_match_param_count():
    """eval_shape param bytes ≈ the analytic param_count (within 12%) —
    validates the MODEL_FLOPS=6·N·D inputs for the roofline, including
    for the 235B config that could never allocate on this host."""
    for arch in ("qwen3-moe-235b-a22b", "gemma3-12b", "zamba2-7b"):
        cfg = configs.get_config(arch)
        params, opt = state_specs(cfg)
        n_exact = sum(l.size for l in jax.tree.leaves(params))
        n_est = cfg.param_count()
        assert abs(n_exact - n_est) / n_exact < 0.12, (
            arch, n_exact, n_est)


def test_long_500k_only_for_subquadratic():
    for arch in configs.ARCHS:
        shapes = cells(arch)
        if "long_500k" in shapes:
            assert arch in ("gemma3-12b", "gemma2-9b", "xlstm-125m",
                            "zamba2-7b")


def test_production_mesh_constants():
    from repro.launch import mesh as m
    assert m.PEAK_FLOPS_BF16 == 197e12
    assert m.HBM_BW == 819e9
    assert m.ICI_BW == 50e9
