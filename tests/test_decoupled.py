"""Decoupled reduce-then-scan schedule vs oracles and the carry chain.

The acceptance bar for the decoupled engine (interpret mode on CPU):
  * equivalence vs ``reference.scan_ref`` for all three monoids,
  * BIT-identity vs the carry schedule (same float association order),
  * block-size invariance, exclusive mode, cross-chunk segments,
  * the policy's batch-vs-cores schedule rule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scan as scanlib
from repro.core.scan import policy, reference
from repro.kernels.scan_blocked import ops as sb_ops
from repro.kernels.segscan import ops as seg_ops
from repro.kernels.ssm_scan import ops as ssm_ops


# ---------------------------------------------------------------------------
# cumsum (sum monoid)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 4096), (4, 1024), (3, 2300),
                                   (1, 16384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_cumsum_decoupled_matches_reference(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(-9, 9, shape), dtype)
    else:
        x = jnp.asarray(rng.standard_normal(shape), dtype)
    got = sb_ops.cumsum(x, interpret=True, schedule="decoupled",
                        block_n=1024)
    ref = reference.cumsum_ref(x.astype(jnp.float32))
    # f32 tree vs sequential association drifts with N (not an error)
    tol = 0.15 if dtype == jnp.bfloat16 else 3e-3
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("block_n", [128, 512, 2048])
def test_cumsum_decoupled_block_invariance(block_n):
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 8192)), jnp.float32)
    got = sb_ops.cumsum(x, block_n=block_n, interpret=True,
                        schedule="decoupled")
    np.testing.assert_allclose(
        np.asarray(got), np.cumsum(np.asarray(x), -1), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("exclusive", [False, True])
def test_cumsum_decoupled_bit_identical_to_carry(exclusive):
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((2, 8192)), jnp.float32)
    carry = sb_ops.cumsum(x, exclusive=exclusive, interpret=True,
                          schedule="carry", block_n=1024)
    dec = sb_ops.cumsum(x, exclusive=exclusive, interpret=True,
                        schedule="decoupled", block_n=1024)
    assert jnp.all(carry == dec), "schedules must agree BITWISE"


def test_cumsum_decoupled_exclusive():
    x = jnp.asarray(
        np.random.default_rng(2).standard_normal((1, 4096)), jnp.float32)
    got = sb_ops.cumsum(x, exclusive=True, interpret=True,
                        schedule="decoupled", block_n=512)
    inc = np.cumsum(np.asarray(x), -1)
    ref = np.concatenate([np.zeros((1, 1), np.float32), inc[:, :-1]], -1)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# segscan ((flag, value) monoid)
# ---------------------------------------------------------------------------


def test_segscan_decoupled_matches_reference():
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.standard_normal((3, 4096)), jnp.float32)
    f = jnp.asarray(rng.random((3, 4096)) < 0.02, jnp.int32)
    got = seg_ops.segmented_cumsum(v, f, interpret=True,
                                   schedule="decoupled", block_n=512)
    ref = reference.segmented_scan_ref(v, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_n", [128, 1024])
def test_segscan_decoupled_cross_chunk_segments(block_n):
    """A segment spanning several chunks must carry; a flag INSIDE a later
    chunk must kill the incoming carry — per chunk, not per block row."""
    rng = np.random.default_rng(4)
    v = jnp.asarray(rng.standard_normal((2, 4096)), jnp.float32)
    f = jnp.zeros((2, 4096), jnp.int32)
    # row 0: flags only at 0 and deep inside chunk 3; row 1: flag-free
    # after position 0 => the carry must cross every chunk boundary.
    f = f.at[:, 0].set(1).at[0, 3500].set(1).at[1, 130].set(1)
    got = seg_ops.segmented_cumsum(v, f, block_n=block_n, interpret=True,
                                   schedule="decoupled")
    ref = reference.segmented_scan_ref(v, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_segscan_decoupled_bit_identical_to_carry():
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.standard_normal((2, 4096)), jnp.float32)
    f = jnp.asarray(rng.random((2, 4096)) < 0.01, jnp.int32)
    carry = seg_ops.segmented_cumsum(v, f, interpret=True, schedule="carry",
                                     block_n=512)
    dec = seg_ops.segmented_cumsum(v, f, interpret=True,
                                   schedule="decoupled", block_n=512)
    assert jnp.all(carry == dec)


# ---------------------------------------------------------------------------
# ssm_scan (affine monoid)
# ---------------------------------------------------------------------------


def _affine_ref(a, b):
    (_, hb) = reference.scan_ref((a, b), "affine", axis=1)
    return hb


@pytest.mark.parametrize("shape", [(1, 2048, 128), (2, 1024, 256),
                                   (1, 1000, 64)])
def test_ssm_decoupled_matches_reference(shape):
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = jnp.asarray(rng.uniform(0.7, 1.0, shape), jnp.float32)
    b = jnp.asarray(rng.standard_normal(shape) * 0.1, jnp.float32)
    got = ssm_ops.ssm_scan(a, b, interpret=True, schedule="decoupled",
                           block_t=128)
    ref = _affine_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block_t", [64, 256])
def test_ssm_decoupled_block_invariance_and_bit_identity(block_t):
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (1, 2048, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 2048, 128)), jnp.float32)
    carry = ssm_ops.ssm_scan(a, b, block_t=block_t, interpret=True,
                             schedule="carry")
    dec = ssm_ops.ssm_scan(a, b, block_t=block_t, interpret=True,
                           schedule="decoupled")
    assert jnp.all(carry == dec)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(_affine_ref(a, b)),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# policy + routing
# ---------------------------------------------------------------------------


def test_policy_schedule_rule():
    # serve/decode class: one long row -> parallel sequence (Obs 3); the
    # single-launch fused form is preferred, two-launch on request
    assert policy.choose_schedule(1, 1 << 22) == "fused"
    assert policy.choose_schedule(1, 1 << 22, prefer_fused=False) \
        == "decoupled"
    assert policy.choose(1 << 22, batch=1).schedule == "fused"
    # training class: rows fill the cores -> carry chain (Obs 2)
    assert policy.choose_schedule(policy.NUM_CORES, 1 << 22) == "carry"
    assert policy.choose(1 << 22, batch=64).schedule == "carry"
    # short row: nothing to parallelize -> carry
    assert policy.choose_schedule(1, 1024) == "carry"
    # shape-oblivious callers keep the old default
    assert policy.choose(1 << 26).schedule == "carry"


def test_ops_auto_schedule_routes_by_shape():
    assert sb_ops.resolve_schedule("auto", 1, 1 << 22, 2048) == "fused"
    assert sb_ops.resolve_schedule("auto", 64, 1 << 22, 2048) == "carry"
    assert sb_ops.resolve_schedule("carry", 1, 1 << 22, 2048) == "carry"
    assert sb_ops.resolve_schedule("decoupled", 64, 1 << 22, 2048) \
        == "decoupled"
    # the policy sees the REAL chunk length: a huge block leaves too few
    # chunks to feed the idle cores, so auto falls back to the carry chain
    assert sb_ops.resolve_schedule("auto", 1, 1 << 14, 1 << 13) == "carry"
    with pytest.raises(ValueError):
        sb_ops.resolve_schedule("bogus", 1, 1, 2048)


def test_api_kernel_schedule_passthrough():
    x = jnp.asarray(
        np.random.default_rng(8).standard_normal(4096), jnp.float32)
    got = scanlib.scan(x, "sum", algorithm="kernel", interpret=True,
                       schedule="decoupled")
    np.testing.assert_allclose(np.asarray(got), np.cumsum(np.asarray(x)),
                               rtol=2e-4, atol=2e-4)
