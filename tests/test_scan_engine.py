"""Monoid-generic scan engine: schedule parity, policy boundaries.

The acceptance bar for the engine refactor (interpret mode on CPU):
  * carry / decoupled / fused return BIT-identical results for all four
    registered monoids across dtypes — the paper's organization/operator
    split holds exactly, not just approximately;
  * the tree schedule (Blelloch in-tile sweep) is bitwise identical to
    the other three wherever ``combine`` is associative in machine
    arithmetic — integers, and floats on exactly-representable data —
    and agrees to float tolerance on arbitrary normals (its balanced
    tree associates differently, so bitwise equality on arbitrary
    floats is mathematically impossible, not an implementation gap);
  * the four-way ``policy.choose_schedule`` rule at its boundaries
    (batch == cores, single-block rows, the tree block threshold,
    itemsize mixes);
  * the engine registry covers the five families and the library monoids
    carry their kernel specs.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scan import assoc, policy, reference
from repro.kernels import scan_engine
from repro.kernels.compact import ops as kc_ops
from repro.kernels.scan_blocked import ops as sb_ops
from repro.kernels.scan_engine import monoids
from repro.kernels.segscan import ops as seg_ops
from repro.kernels.ssm_scan import ops as ssm_ops

# The trio whose in-tile network is shared — bitwise on ANY data.
SCHEDULES = ("carry", "decoupled", "fused")
# All four — bitwise on exactly-representable data (the tree's different
# association is exact there).
SCHEDULES4 = ("carry", "decoupled", "fused", "tree")


def _all_bit_identical(outs):
    first = outs[0]
    return all(
        all(bool(jnp.all(a == b)) for a, b in zip(first, o))
        for o in outs[1:])


# ---------------------------------------------------------------------------
# schedule-parity sweep: 3 schedules x 4 monoids x dtypes, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
@pytest.mark.parametrize("exclusive", [False, True])
def test_parity_sum(dtype, exclusive):
    rng = np.random.default_rng(0)
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(-9, 9, (2, 4096)), dtype)
    else:
        x = jnp.asarray(rng.standard_normal((2, 4096)), dtype)
    outs = [
        (sb_ops.cumsum(x, exclusive=exclusive, interpret=True, schedule=s,
                       block_n=512),)
        for s in SCHEDULES
    ]
    assert _all_bit_identical(outs), "sum schedules must agree BITWISE"
    ref = reference.cumsum_ref(x.astype(jnp.float32))
    if exclusive:
        ref = jnp.pad(ref, ((0, 0), (1, 0)))[:, :-1]
    tol = 0.15 if dtype == jnp.bfloat16 else 3e-3
    np.testing.assert_allclose(
        np.asarray(outs[0][0], np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_parity_segmented(dtype):
    rng = np.random.default_rng(1)
    if dtype == jnp.int32:
        v = jnp.asarray(rng.integers(-9, 9, (2, 4096)), dtype)
    else:
        v = jnp.asarray(rng.standard_normal((2, 4096)), dtype)
    f = jnp.asarray(rng.random((2, 4096)) < 0.02, jnp.int32)
    outs = [
        (seg_ops.segmented_cumsum(v, f, interpret=True, schedule=s,
                                  block_n=512),)
        for s in SCHEDULES
    ]
    assert _all_bit_identical(outs)
    ref = reference.segmented_scan_ref(v.astype(jnp.float32), f)
    np.testing.assert_allclose(
        np.asarray(outs[0][0], np.float64), np.asarray(ref, np.float64),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_affine(dtype):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(0.7, 1.0, (1, 2048, 128)), dtype)
    b = jnp.asarray(rng.standard_normal((1, 2048, 128)) * 0.1, dtype)
    outs = [
        (ssm_ops.ssm_scan(a, b, interpret=True, schedule=s, block_t=128),)
        for s in SCHEDULES
    ]
    assert _all_bit_identical(outs)
    _, ref = reference.scan_ref(
        (a.astype(jnp.float32), b.astype(jnp.float32)), "affine", axis=1)
    tol = 0.1 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(outs[0][0], np.float64), np.asarray(ref, np.float64),
        rtol=tol, atol=tol)


def test_parity_mask():
    rng = np.random.default_rng(3)
    m = jnp.asarray(rng.random((3, 4096)) < 0.5, jnp.int32)
    outs = [
        kc_ops.mask_compact(m, interpret=True, schedule=s, block_n=512)
        for s in SCHEDULES
    ]
    assert _all_bit_identical(outs)
    mn = np.asarray(m)
    excl = np.cumsum(mn, -1) - mn
    np.testing.assert_array_equal(
        np.asarray(outs[0][0]), np.where(mn != 0, excl, 4096))
    np.testing.assert_array_equal(np.asarray(outs[0][1]), mn.sum(-1))


# ---------------------------------------------------------------------------
# 4-schedule parity (tree included) on exact data, + tree float tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("exclusive", [False, True])
def test_parity4_sum_exact(dtype, exclusive):
    """All FOUR schedules bitwise on integer-valued data — f32/bf16
    included, since small integers are exactly representable and the
    engine widens bf16 accumulation to f32."""
    rng = np.random.default_rng(20)
    x = jnp.asarray(rng.integers(-9, 9, (2, 4096)), dtype)
    outs = [
        (sb_ops.cumsum(x, exclusive=exclusive, interpret=True, schedule=s,
                       block_n=512),)
        for s in SCHEDULES4
    ]
    assert _all_bit_identical(outs), \
        "tree must match carry BITWISE on exact data"


def test_parity4_segmented_exact():
    rng = np.random.default_rng(21)
    v = jnp.asarray(rng.integers(-9, 9, (2, 4096)), jnp.float32)
    f = jnp.asarray(rng.random((2, 4096)) < 0.02, jnp.int32)
    outs = [
        (seg_ops.segmented_cumsum(v, f, interpret=True, schedule=s,
                                  block_n=512),)
        for s in SCHEDULES4
    ]
    assert _all_bit_identical(outs)


def test_parity4_affine_exact():
    """Exact affine data: gates in {±1} and integer offsets compose to
    integer-valued states, so the tree's re-association is bit-exact."""
    rng = np.random.default_rng(22)
    a = jnp.asarray(rng.choice([-1.0, 1.0], (1, 2048, 128)), jnp.float32)
    b = jnp.asarray(rng.integers(-3, 4, (1, 2048, 128)), jnp.float32)
    outs = [
        (ssm_ops.ssm_scan(a, b, interpret=True, schedule=s, block_t=128),)
        for s in SCHEDULES4
    ]
    assert _all_bit_identical(outs)
    _, ref = reference.scan_ref((a, b), "affine", axis=1)
    np.testing.assert_array_equal(np.asarray(outs[0][0]), np.asarray(ref))


def test_parity4_mask_exact():
    rng = np.random.default_rng(23)
    m = jnp.asarray(rng.random((3, 4096)) < 0.5, jnp.int32)
    outs = [
        kc_ops.mask_compact(m, interpret=True, schedule=s, block_n=512)
        for s in SCHEDULES4
    ]
    assert _all_bit_identical(outs)


@pytest.mark.parametrize("exclusive", [False, True])
def test_tree_float_tolerance(exclusive):
    """On arbitrary float normals the tree associates differently —
    bitwise is impossible, but it must agree with carry (and the
    oracle) to float tolerance."""
    rng = np.random.default_rng(24)
    x = jnp.asarray(rng.standard_normal((2, 4096)), jnp.float32)
    tree = sb_ops.cumsum(x, exclusive=exclusive, interpret=True,
                         schedule="tree", block_n=512)
    carry = sb_ops.cumsum(x, exclusive=exclusive, interpret=True,
                          schedule="carry", block_n=512)
    np.testing.assert_allclose(np.asarray(tree), np.asarray(carry),
                               rtol=2e-4, atol=2e-4)
    ref = reference.cumsum_ref(x)
    if exclusive:
        ref = jnp.pad(ref, ((0, 0), (1, 0)))[:, :-1]
    np.testing.assert_allclose(np.asarray(tree), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_tree_non_pow2_tile():
    """Tiles whose length is not a power of two exercise the identity
    pad inside the Blelloch network (96 -> 128)."""
    rng = np.random.default_rng(25)
    x = jnp.asarray(rng.integers(-9, 9, (2, 480)), jnp.int32)
    lay = scan_engine.Rows(2, 480, 1, 96)
    (tree,) = scan_engine.scan((x,), monoids.SUM, lay, schedule="tree",
                               interpret=True)
    (carry,) = scan_engine.scan((x,), monoids.SUM, lay, schedule="carry",
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(tree), np.asarray(carry))


def test_tree_fold_routes_to_carry_fold():
    """Carried-payload (transform) monoids have no in-block element axis
    to tree-organize: schedule='tree' must run the carry fold — same
    outputs as carry, no error."""
    rng = np.random.default_rng(26)
    q = jnp.asarray(rng.standard_normal((2, 128, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 16)), jnp.float32)
    spec = monoids.softmax_pair(scale=0.25)
    lay = scan_engine.KVBlocks(bh=2, bh_kv=2, tq=128, tk=128, d=16,
                               bq=128, bk=64)
    out_t = scan_engine.scan((q, k, v), spec, lay, schedule="tree",
                             interpret=True)
    out_c = scan_engine.scan((q, k, v), spec, lay, schedule="carry",
                             interpret=True)
    assert _all_bit_identical([out_t, out_c])


def test_segmented_messy_flags_match_reference():
    """Fractional and negative nonzero flags are boundaries too — the
    kernel route must normalize with ``!= 0``, not truncate or max."""
    v = jnp.ones((8,), jnp.float32)
    for flags in (jnp.asarray([0, 0, 0.5, 0, 0.5, 0, 0, 0], jnp.float32),
                  jnp.asarray([0, 0, -1, 0, -3, 0, 0, 0], jnp.int32)):
        got = seg_ops.segmented_cumsum(v, flags, interpret=True)
        ref = reference.segmented_scan_ref(v, flags)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        assert np.asarray(got).tolist() == [1, 2, 1, 2, 1, 2, 3, 4]


def test_fused_falls_back_to_decoupled():
    """Whenever the native single-launch path can't (or mustn't) run —
    interpret mode, no TPU, or the validation gate still closed — the
    fused schedule must run the two-launch decoupled organization: same
    bits, no semaphore path."""
    from repro.kernels.scan_engine import schedules
    # the native path stays gated off until validated on real TPU (ROADMAP)
    assert not schedules.FUSED_NATIVE_ENABLED
    x = jnp.asarray(
        np.random.default_rng(4).standard_normal((1, 8192)), jnp.float32)
    # interpret=True forces the fallback on every backend
    fused = sb_ops.cumsum(x, interpret=True, schedule="fused", block_n=1024)
    dec = sb_ops.cumsum(x, interpret=True, schedule="decoupled",
                        block_n=1024)
    assert bool(jnp.all(fused == dec))


# ---------------------------------------------------------------------------
# engine surface: registry, specs, validation
# ---------------------------------------------------------------------------


def test_registry_covers_five_families():
    assert set(scan_engine.monoids.REGISTRY) == {
        "sum", "segmented_sum", "affine", "mask", "softmax_pair"}
    for name, factory in scan_engine.monoids.REGISTRY.items():
        spec = factory()
        assert isinstance(spec, assoc.KernelSpec)
        assert len(spec.fills) == spec.n_leaves


def test_totals_chain_bitwise_across_schedules():
    """``scan(..., return_totals=True)`` returns the RUNNING chunk-totals
    chain (combined through chunk j): identical bits under all FOUR
    schedules (integer data, so the tree is exact too), last column ==
    the row reduction — what ``mask_compact`` uses for O(B·chunks)
    survivor counts (ROADMAP follow-up)."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(-9, 9, (3, 2048)), jnp.int32)
    lay = scan_engine.Rows(3, 2048, 1, 256)
    chains = []
    for s in SCHEDULES4:
        (out,), (tot,) = scan_engine.scan(
            (x,), monoids.SUM, lay, schedule=s, interpret=True,
            return_totals=True)
        assert tot.shape == (3, 8)
        np.testing.assert_array_equal(
            np.asarray(tot[:, -1]), np.asarray(x).sum(-1))
        chains.append((out, tot))
    assert _all_bit_identical(chains)


def test_mask_compact_counts_from_totals_chain():
    """Counts derived from the totals chain == a full jnp reduction,
    for every schedule, ragged lengths and float masks included."""
    rng = np.random.default_rng(10)
    for shape in ((2, 517), (4, 4096), (1, 128)):
        m = jnp.asarray(rng.random(shape) < 0.3, jnp.float32)
        for s in SCHEDULES4:
            _, counts = kc_ops.mask_compact(m, interpret=True, schedule=s,
                                            block_n=256)
            np.testing.assert_array_equal(
                np.asarray(counts), (np.asarray(m) != 0).sum(-1))


def test_library_monoids_carry_kernel_specs():
    assert assoc.SUM.kernel_spec is assoc.SUM_KERNEL
    assert assoc.AFFINE.kernel_spec is assoc.AFFINE_KERNEL
    assert assoc.segmented(assoc.SUM).kernel_spec \
        is assoc.SEGMENTED_SUM_KERNEL
    assert assoc.segmented(assoc.MAX).kernel_spec is None  # not registered


def test_engine_rejects_unknown_schedule_and_bad_exclusive():
    x = jnp.ones((2, 256), jnp.float32)
    lay = scan_engine.Rows(2, 256, 2, 128)
    with pytest.raises(ValueError):
        scan_engine.scan((x,), monoids.SUM, lay, schedule="bogus")
    m = jnp.ones((2, 256), jnp.int32)
    with pytest.raises(ValueError):
        scan_engine.scan((m,), monoids.mask(256), lay, schedule="carry",
                         exclusive=True)
    with pytest.raises(ValueError):
        scan_engine.Rows(2, 300, 2, 128)  # not divisible by the block


# ---------------------------------------------------------------------------
# policy boundaries (four-way choose_schedule)
# ---------------------------------------------------------------------------


def test_choose_schedule_batch_boundary():
    n = 1 << 22
    cores = policy.NUM_CORES
    # batch == cores: rows exactly fill the machine -> carry
    assert policy.choose_schedule(cores, n) == "carry"
    # one fewer row: spare = cores // (cores-1) == 1 < 2 -> still carry
    assert policy.choose_schedule(cores - 1, n) == "carry"
    # half the cores busy -> parallel-sequence schedule
    assert policy.choose_schedule(cores // 2, n) == "fused"
    assert policy.choose_schedule(cores // 2, n, prefer_fused=False) \
        == "decoupled"


def test_choose_schedule_single_block_rows():
    # a row inside ONE block has nothing to parallelize, whatever batch is
    assert policy.choose_schedule(1, 2048, block_elems=2048) == "carry"
    assert policy.choose_schedule(1, 4096, block_elems=4096) == "carry"
    # chunks must cover the spare cores: 4 chunks < 8 spare -> carry
    assert policy.choose_schedule(1, 8192, block_elems=2048) == "carry"
    # exactly spare chunks -> flip
    n = policy.NUM_CORES * 2048
    assert policy.choose_schedule(1, n, block_elems=2048) == "fused"


def test_choose_schedule_tree_boundary():
    """Tree fires only when rows saturate the cores AND the block is big
    enough to amortize the sweep; the default block (2048) never trips
    it, so every pre-tree auto decision is unchanged."""
    n = 1 << 22
    cores = policy.NUM_CORES
    # saturated rows + big block -> tree
    assert policy.choose_schedule(cores, n,
                                  block_elems=policy.TREE_BLOCK_ELEMS) \
        == "tree"
    assert policy.choose_schedule(cores * 4, n, block_elems=16384) == "tree"
    # one element under the threshold -> carry (the old answer)
    assert policy.choose_schedule(cores, n,
                                  block_elems=policy.TREE_BLOCK_ELEMS - 1) \
        == "carry"
    # default block: unchanged decisions
    assert policy.choose_schedule(cores, n) == "carry"
    # under-subscribed rows never pick tree, whatever the block size
    assert policy.choose_schedule(cores // 2, n,
                                  block_elems=policy.TREE_BLOCK_ELEMS) \
        == "fused"
    d = policy.explain_schedule(cores, n,
                                block_elems=policy.TREE_BLOCK_ELEMS)
    assert d.value == "tree" and "block_elems" in d.reason


@pytest.mark.parametrize("itemsize", [1, 2, 4, 8])
def test_choose_itemsize_mixes(itemsize):
    """The algorithm threshold scales with itemsize; the schedule rule is
    itemsize-blind (it counts chunks, not bytes)."""
    n = 1 << 21  # 2M elems: spans the VMEM budget across the dtype sweep
    choice = policy.choose(n, itemsize=itemsize, batch=1)
    if n * itemsize <= policy.VMEM_BLOCK_BUDGET:
        assert choice.algorithm == "horizontal"
    else:
        assert choice.algorithm == "kernel"
        assert choice.schedule == "fused"
    assert policy.choose_schedule(1, n) == "fused"


def test_schedule_threaded_through_api():
    """core.scan.api 'auto' hands the policy's schedule to the kernel."""
    from repro.core import scan as scanlib
    x = jnp.asarray(
        np.random.default_rng(5).standard_normal(4096), jnp.float32)
    got = scanlib.scan(x, "sum", algorithm="kernel", interpret=True,
                       schedule="fused")
    np.testing.assert_allclose(np.asarray(got), np.cumsum(np.asarray(x)),
                               rtol=2e-4, atol=2e-4)
