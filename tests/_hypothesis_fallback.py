"""Minimal stand-in for `hypothesis` when it isn't installed.

The container bakes its dependency set; property tests fall back to a
deterministic random sweep (seeded per example index) with the same
`given`/`settings`/`strategies` surface the tests use. Shrinking and
the database are out of scope — failures report the drawn values.

Registered from conftest.py as `sys.modules["hypothesis"]` ONLY when the
real package is missing.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def floats(min_value=0.0, max_value=1.0, width=64, **_):
    def draw(rng):
        v = float(rng.uniform(min_value, max_value))
        return float(np.float32(v)) if width == 32 else v

    return _Strategy(draw)


def integers(min_value=0, max_value=100):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements, min_size=0, max_size=10, **_):
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(size)]

    return _Strategy(draw)


def tuples(*elems):
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def settings(max_examples=20, deadline=None, **_):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*sargs, **skwargs):
    """Run the test over ``max_examples`` seeded draws.

    Positional strategies bind to the function's last N parameters (the
    hypothesis convention); keyword strategies bind by name. Remaining
    parameters stay visible to pytest (fixtures / parametrize).
    """

    def deco(fn):
        params = list(inspect.signature(fn).parameters.values())
        names = [p.name for p in params]
        pos_names = names[len(names) - len(sargs):] if sargs else []
        drawn = dict(zip(pos_names, sargs), **skwargs)
        passthrough = [p for p in params if p.name not in drawn]
        n_examples = getattr(fn, "_fallback_max_examples", 20)

        @functools.wraps(fn)
        def run(*args, **kwargs):
            bound = dict(zip([p.name for p in passthrough], args), **kwargs)
            for i in range(n_examples):
                rng = np.random.default_rng([0xF411, i])
                vals = {k: s.example(rng) for k, s in drawn.items()}
                try:
                    fn(**bound, **vals)
                except Exception as e:  # noqa: BLE001 — report the draw
                    raise AssertionError(
                        f"falsifying example (draw {i}): {vals!r}") from e

        run.__signature__ = inspect.Signature(passthrough)
        del run.__wrapped__  # keep pytest off fn's full signature
        return run

    return deco
