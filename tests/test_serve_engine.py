"""Serving engine: continuous batching, slot compaction, sampling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.serve import Engine, EngineConfig, Request
from repro.train.step import init_params


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("stablelm-12b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_more_requests_than_slots(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=48, max_new_tokens=5, eos_id=-1))
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=np.arange(3, dtype=np.int32)))
    done = eng.run_to_completion()
    assert len(done) == 5
    assert all(len(r.output) == 5 for r in done)  # max_new_tokens total


def test_engine_greedy_matches_direct_decode(small_model):
    """Engine output == hand-rolled prefill+decode loop (greedy)."""
    cfg, params = small_model
    from repro.models import lm
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    n_new = 6

    logits, cache = lm.prefill(params, jnp.asarray(prompt)[None], cfg,
                               max_len=32)
    want = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([[want[-1]]], jnp.int32)
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = lm.decode_step(params, tok, cache,
                                       jnp.asarray(pos, jnp.int32), cfg)
        want.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        pos += 1

    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=32, max_new_tokens=n_new, temperature=0.0,
        eos_id=-1))
    eng.submit(Request(rid=0, prompt=prompt))
    done = eng.run_to_completion()
    assert done[0].output == want


def test_eos_frees_slot(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=64, max_new_tokens=50, temperature=0.0))
    # figure out the greedy first token, then make IT the eos id so the
    # request finishes immediately and the slot frees for the next one.
    probe = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=64, max_new_tokens=1, temperature=0.0,
        eos_id=-1))
    probe.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32)))
    first = probe.run_to_completion()[0].output[0]

    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=64, max_new_tokens=50, temperature=0.0,
        eos_id=first))
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32)))
    eng.submit(Request(rid=1, prompt=np.asarray([1, 2, 3], np.int32)))
    done = eng.run_to_completion()
    assert len(done) == 2
    assert all(r.output[-1] == first for r in done)


def test_out_of_cache_surfaces_as_cache_full(small_model):
    """Regression (ISSUE 6 satellite): a sequence running out of KV cache
    before its token budget used to finish indistinguishably from EOS —
    it must now carry finish_reason="cache_full" and warn."""
    cfg, params = small_model
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=12, max_new_tokens=20, eos_id=-1,
        temperature=0.0, strict_admission=False))
    eng.submit(Request(rid=0, prompt=np.arange(2, 8, dtype=np.int32)))
    with pytest.warns(RuntimeWarning, match="cache_full"):
        done = eng.run_to_completion()
    assert done[0].finish_reason == "cache_full"
    # prefill token + decode up to the cache edge, short of the budget
    assert 0 < len(done[0].output) < 20
    assert eng.stats.finished["cache_full"] == 1


def test_run_to_completion_deadline_vs_strict(small_model):
    """Regression (ISSUE 6 satellite): exhausting max_ticks used to
    silently return with requests still waiting/active."""
    cfg, params = small_model
    def fresh():
        eng = Engine(params, cfg, EngineConfig(
            max_slots=1, max_len=48, max_new_tokens=10, eos_id=-1))
        for rid in range(3):
            eng.submit(Request(rid=rid, prompt=np.arange(3, dtype=np.int32)))
        return eng

    done = fresh().run_to_completion(max_ticks=2)
    reasons = sorted(r.finish_reason for r in done)
    assert len(done) == 3 and "deadline" in reasons  # survivors marked

    from repro.serve import EngineDeadlineError
    with pytest.raises(EngineDeadlineError):
        fresh().run_to_completion(max_ticks=2, strict=True)


def test_free_slot_compaction_ranks(small_model):
    cfg, params = small_model
    eng = Engine(params, cfg, EngineConfig(max_slots=4, max_len=32))
    eng.slot_req = [None, Request(rid=0, prompt=np.zeros(1)), None, None]
    free_idx, ranks = eng._free_slots()
    np.testing.assert_array_equal(free_idx, [0, 2, 3])
    # exclusive prefix sum of the free bitmap = compacted ranks
    np.testing.assert_array_equal(np.asarray(ranks), [0, 1, 1, 2])


def test_ssm_decode_resolves_parallel_schedule():
    """The serve engine's SSM decode/prefill class — B=1 slot, long
    sequence — must land on a parallel-sequence schedule end to end: the
    engine prefills one request at a time (B=1), and ``apply_ssm`` routes
    the cache path through ``ssm_scan(schedule="auto")``."""
    from repro.kernels.ssm_scan import ops as ssm_ops
    # decode/prefill class: one sequence, long time axis, one channel block
    assert ssm_ops.resolved_schedule((1, 1 << 22, 256)) in (
        "fused", "decoupled")
    # training class: many (batch, channel-block) stripes -> carry chain
    assert ssm_ops.resolved_schedule((8, 4096, 4096)) == "carry"


def test_ssm_engine_end_to_end():
    """A hybrid-SSM model served end to end through ``impl="auto"`` (on
    TPU this is the kernel route; off-TPU the gate keeps the reference
    scan — either way the serve path must run)."""
    cfg = configs.get_smoke_config("zamba2-7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=48, max_new_tokens=4, eos_id=-1))
    eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32)))
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].output) == 4


def test_ssm_serve_kernel_route_matches_reference():
    """The serve configuration's kernel route (what ``impl="auto"`` picks
    on TPU): prefill-with-cache through the Pallas affine scan must match
    the chunked reference path."""
    from repro.models.layers.ssm import apply_ssm, init_ssm, init_ssm_cache
    cfg = configs.get_smoke_config("zamba2-7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_ssm(jax.random.PRNGKey(0), cfg)
    cache = init_ssm_cache(cfg, batch=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 33, cfg.d_model))
    y_k, c_k = apply_ssm(params, x, cfg, cache=cache, impl="kernel")
    y_r, c_r = apply_ssm(params, x, cfg, cache=cache, impl="chunked")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(c_k["h"]), np.asarray(c_r["h"]),
                               rtol=2e-4, atol=2e-4)


def test_encdec_serve_path():
    cfg = configs.get_smoke_config("seamless-m4t-large-v2")
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.models import encdec
    from repro.serve.steps import make_prefill_fn, make_serve_step
    B = 2
    embeds = jax.random.normal(jax.random.PRNGKey(1), (B, 8, 1024))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 6), 0,
                              cfg.vocab_size)
    logits, cache, memory = make_prefill_fn(cfg, max_len=16)(
        params, toks, embeds)
    assert logits.shape == (B, cfg.vocab_size)
    step = make_serve_step(cfg)
    logits2, cache = step(params, toks[:, :1], cache,
                          jnp.asarray(6, jnp.int32), memory)
    assert bool(jnp.isfinite(logits2).all())


# ---------------------------------------------------------------------------
# engine-backed flash prefill through serve/steps.py (schedule="auto")
# ---------------------------------------------------------------------------


def test_flash_prefill_matches_default_padded_cache(small_model):
    """Prefill through ``serve/steps.py`` with attention routed onto the
    engine-backed flash fold (schedule="auto") must score like the
    default path — including the padded-KV-cache case (cache of
    ``max_len`` slots much longer than the live prefix)."""
    from repro.serve.steps import make_prefill_fn
    cfg, params = small_model
    prompt = jnp.asarray([[5, 9, 2, 7, 1, 3]], jnp.int32)  # S=6 << 32
    lg_ref, cache_ref = make_prefill_fn(cfg, max_len=32)(params, prompt)
    lg_fl, cache_fl = make_prefill_fn(
        cfg, max_len=32, attn_impl="flash", attn_schedule="auto")(
        params, prompt)
    np.testing.assert_allclose(np.asarray(lg_fl), np.asarray(lg_ref),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(jax.tree.leaves(cache_fl), jax.tree.leaves(cache_ref)):
        assert a.shape == b.shape  # same padded-cache geometry
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("schedule", ["auto", "carry", "decoupled"])
def test_flash_prefill_vs_decode_score_parity(small_model, schedule):
    """Prefill-then-decode must score the continuation exactly like a
    one-token-longer flash prefill: the engine-backed prefill cache and
    the dense decode path agree on every schedule route."""
    from repro.models import lm
    from repro.serve.steps import make_prefill_fn
    cfg, params = small_model
    toks = jnp.asarray([[5, 9, 2, 7, 1, 3, 8]], jnp.int32)
    # scores from a full flash prefill of all 7 tokens
    lg_full, _ = make_prefill_fn(
        cfg, max_len=32, attn_impl="flash", attn_schedule=schedule)(
        params, toks)
    # scores from flash prefill of 6 + dense decode of token 7
    _, cache = make_prefill_fn(
        cfg, max_len=32, attn_impl="flash", attn_schedule=schedule)(
        params, toks[:, :-1])
    lg_dec, _ = lm.decode_step(params, toks[:, -1:], cache,
                               jnp.asarray(6, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=2e-4, atol=2e-4)


def test_engine_flash_route_greedy_parity(small_model):
    """End to end: an Engine configured to prefill on the flash fold
    generates the same greedy tokens as the default engine."""
    cfg, params = small_model
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    outs = []
    for kw in ({}, {"attn_impl": "flash", "attn_schedule": "auto"}):
        eng = Engine(params, cfg, EngineConfig(
            max_slots=1, max_len=32, max_new_tokens=5, temperature=0.0,
            eos_id=-1, **kw))
        eng.submit(Request(rid=0, prompt=prompt))
        outs.append(eng.run_to_completion()[0].output)
    assert outs[0] == outs[1]


def test_long_kv_serve_class_lands_on_split_kv():
    """The 32k/500k-context serve class — decode/scoring rows against a
    long padded cache — must resolve schedule="auto" to the split-KV
    decoupled fold, while saturated training prefill keeps carry."""
    from repro.kernels.flash_attention import resolved_attention_schedule
    # B=1 decode, 32 q-heads, 32k cache -> decoupled
    assert resolved_attention_schedule((1, 32, 1, 128), 1 << 15) \
        == "decoupled"
    # 500k-context scoring step
    assert resolved_attention_schedule((1, 8, 1, 128), 500_000) \
        == "decoupled"
    # training prefill: 8 x 32 heads x many q blocks -> carry
    assert resolved_attention_schedule((8, 32, 8192, 128), 8192) == "carry"


def test_flash_route_keeps_cached_keys_mid_stream(small_model):
    """The padded-cache flash prefill route is guarded by a runtime
    ``cache_len == 0`` cond: a multi-token continuation against a warm
    cache (cache_len > 0) must keep the dense path's cached keys, not
    silently restart attention at position 0."""
    from repro.models import lm
    cfg, params = small_model
    toks = jnp.asarray([[5, 9, 2, 7, 1, 3, 8, 4]], jnp.int32)
    # warm the cache with the first 5 tokens (default path)
    _, _, cache = lm.forward(
        params, toks[:, :5], cfg, cache=lm.init_cache(cfg, 1, 32),
        cache_len=jnp.zeros((), jnp.int32))
    # continue with a 3-token chunk: flash-routed forward must equal the
    # dense-routed forward (the cond falls back because cache_len != 0)
    outs = {}
    for impl in (None, "flash"):
        h, _, _ = lm.forward(
            params, toks[:, 5:], cfg, cache=jax.tree.map(lambda x: x, cache),
            cache_len=jnp.asarray(5, jnp.int32), attn_impl=impl)
        outs[impl] = h
    np.testing.assert_allclose(np.asarray(outs["flash"]),
                               np.asarray(outs[None]),
                               rtol=1e-5, atol=1e-5)
