"""Benchmark harness: one table per paper figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig6 fig10 # subset
    PYTHONPATH=src python -m benchmarks.run --dry-run  # CI smoke

``--dry-run`` resolves every registered suite (so a renamed or broken
entry point fails loudly) and executes the figures that support a
``smoke=True`` shrink at toy sizes, end to end.
"""

from __future__ import annotations

import inspect
import sys
import time

from benchmarks import (fig6_single_thread, fig7_traffic, fig8_inplace,
                        fig10_partition_size, fig11_dilation, fig13_policy,
                        fig_attention, fig_decoupled, fig_engine,
                        fig_relational, moe_dispatch, roofline_table)

SUITES = {
    "fig6": [fig6_single_thread.run],
    "fig7": [fig7_traffic.run, fig7_traffic.run_device_parallel],
    "fig8": [fig8_inplace.run],
    "fig10": [fig10_partition_size.run,
              fig10_partition_size.run_kernel_vmem],
    "fig11": [fig11_dilation.run],
    "fig13": [fig13_policy.run, fig13_policy.run_traffic_model],
    "attention": [fig_attention.run, fig_attention.run_bwd],
    "decoupled": [fig_decoupled.run, fig_decoupled.run_traffic],
    "engine": [fig_engine.run],
    "moe": [moe_dispatch.run],
    "relational": [fig_relational.run, fig_relational.run_sort_join],
    "roofline": [roofline_table.run],
}


def main(argv=None):
    names = list(argv if argv is not None else sys.argv[1:])
    dry_run = "--dry-run" in names
    if dry_run:
        names.remove("--dry-run")
    names = names or list(SUITES)
    t0 = time.time()
    for name in names:
        if name not in SUITES:
            print(f"unknown suite {name!r}; known: {sorted(SUITES)}")
            return 1
        for fn in SUITES[name]:
            if dry_run:
                if "smoke" in inspect.signature(fn).parameters:
                    fn(smoke=True).show()
                else:
                    print(f"[dry-run] {fn.__module__}.{fn.__name__}: ok")
            else:
                fn().show()
    print(f"[benchmarks done in {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
