"""Benchmark harness: one table per paper figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig6 fig10 # subset
    PYTHONPATH=src python -m benchmarks.run --dry-run  # CI smoke

``--dry-run`` resolves every registered suite (so a renamed or broken
entry point fails loudly) and executes the figures that support a
``smoke=True`` shrink at toy sizes, end to end.

``--json PATH`` additionally writes one schema-versioned document of
everything that EXECUTED (suite -> table records, with full timing
stats per ``time_fn`` cell, plus an environment block and the obs
metrics snapshot).  The committed ``BENCH_<suite>.json`` baselines are
such documents captured in ``--dry-run`` mode;
``tools/bench_gate.py`` diffs a fresh run against them.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time

from benchmarks import (fig6_single_thread, fig7_traffic, fig8_inplace,
                        fig10_partition_size, fig11_dilation, fig13_policy,
                        fig_attention, fig_decoupled, fig_engine,
                        fig_relational, moe_dispatch, roofline_table)

#: Bench-trajectory document version. Bump on any structural change to
#: the --json output; tools/bench_gate.py refuses documents it does not
#: understand.
SCHEMA = "repro-bench/v1"

SUITES = {
    "fig6": [fig6_single_thread.run],
    "fig7": [fig7_traffic.run, fig7_traffic.run_device_parallel],
    "fig8": [fig8_inplace.run],
    "fig10": [fig10_partition_size.run,
              fig10_partition_size.run_kernel_vmem],
    "fig11": [fig11_dilation.run],
    "fig13": [fig13_policy.run, fig13_policy.run_traffic_model],
    "attention": [fig_attention.run, fig_attention.run_bwd],
    "decoupled": [fig_decoupled.run, fig_decoupled.run_traffic],
    "engine": [fig_engine.run],
    "moe": [moe_dispatch.run],
    "relational": [fig_relational.run, fig_relational.run_sort_join],
    "roofline": [roofline_table.run],
    "serve": [fig7_traffic.run_faults, fig7_traffic.run_traffic],
}


def _environment() -> dict:
    import jax
    import numpy as np
    return {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def main(argv=None):
    import inspect

    ap = argparse.ArgumentParser(prog="benchmarks.run", description=__doc__)
    ap.add_argument("suites", nargs="*", metavar="SUITE",
                    help=f"subset to run (default: all). "
                         f"Known: {' '.join(sorted(SUITES))}")
    ap.add_argument("--dry-run", action="store_true",
                    help="smoke sizes; skip suites without a smoke mode")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the bench-trajectory document here")
    args = ap.parse_args(argv)

    names = args.suites or list(SUITES)
    doc = {"schema": SCHEMA, "dry_run": bool(args.dry_run), "suites": {}}
    t0 = time.time()
    for name in names:
        if name not in SUITES:
            print(f"unknown suite {name!r}; known: {sorted(SUITES)}")
            return 1
        records = []
        for fn in SUITES[name]:
            if args.dry_run:
                if "smoke" in inspect.signature(fn).parameters:
                    table = fn(smoke=True)
                else:
                    print(f"[dry-run] {fn.__module__}.{fn.__name__}: ok")
                    continue
            else:
                table = fn()
            table.show()
            records.append(table.to_records())
        if records:
            doc["suites"][name] = records
    elapsed = time.time() - t0
    print(f"[benchmarks done in {elapsed:.1f}s]")

    if args.json is not None:
        from repro.obs import default_registry
        doc["environment"] = _environment()
        doc["elapsed_s"] = elapsed
        doc["metrics"] = default_registry().snapshot()
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[bench trajectory -> {args.json}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
