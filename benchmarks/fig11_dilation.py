"""Paper Fig. 11/12 — effect of dilation factors.

The dilated two-pass algorithm shrinks partition 0 to balance the scan
(not vectorizable) vs increment/accumulate (vectorizable) subprocedures.
We sweep d over the paper's Fig. 12 range for both variants and report
wall time — reproducing the paper's observation that the best d varies
and equal partitions + cache partitioning is the robust choice (Obs 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, throughput, time_fn
from repro.core import scan as scanlib

N = 1 << 22
DILATIONS = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0]


def run() -> Table:
    x = jnp.asarray(np.random.default_rng(0).standard_normal(N), jnp.float32)
    t = Table("Fig 11/12 — dilation sweep (two-pass, 8 partitions)",
              ["variant", "dilation", "Belem/s"])
    for variant in (1, 2):
        for d in DILATIONS:
            fn = jax.jit(functools.partial(
                scanlib.scan_two_pass, op="sum", num_partitions=8,
                variant=variant, dilation=d))
            sec = time_fn(fn, x, iters=3)
            t.add(f"v{variant}", d, throughput(N, sec))
    # reference: the partitioned scan the paper recommends instead
    fn = jax.jit(functools.partial(scanlib.scan_blocked, op="sum",
                                   block_size=128 * 1024))
    t.add("Blocked(-P)", "-", throughput(N, time_fn(fn, x, iters=3)))
    return t


if __name__ == "__main__":
    run().show()
