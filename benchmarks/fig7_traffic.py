"""Paper Fig. 7 — the multithreaded result, told through memory traffic.

The paper's headline: once bandwidth-bound, the partitioned (fused
two-pass) algorithm wins because it moves HALF the slow-memory bytes of
the unfused two-pass (read+write+read+read vs read+write once). On this
1-core container wall-clock cannot show thread scaling, so we measure the
quantity that *caused* the paper's scaling difference — bytes moved per
element, from the compiled HLO — plus the collective bytes of the
device-parallel version (devices = the paper's threads) from an 8-device
lowering.
"""

from __future__ import annotations

import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, hlo_bytes
from repro.core import scan as scanlib

N = 1 << 22


def run() -> Table:
    x = jax.ShapeDtypeStruct((N,), jnp.float32)

    variants = {
        "Blocked(-P, fused)": functools.partial(
            scanlib.scan_blocked, op="sum", block_size=128 * 1024),
        "TwoPass v1 (scan+inc)": functools.partial(
            scanlib.scan_two_pass, op="sum", num_partitions=8, variant=1),
        "TwoPass v2 (acc+scan)": functools.partial(
            scanlib.scan_two_pass, op="sum", num_partitions=8, variant=2),
        "lib:jnp.cumsum": lambda v: jnp.cumsum(v),
    }

    t = Table("Fig 7 — bytes/element moved (compiled HLO; lower is "
              "better when bandwidth-bound)", ["variant", "bytes/elem",
                                               "flops/elem"])
    for name, fn in variants.items():
        c = hlo_bytes(fn, x)
        t.add(name, c["bytes"] / N, c["flops"] / N)
    return t


def run_device_parallel() -> Table:
    """The m-device two-pass scan's collective footprint (subprocess with
    8 host devices; prints the `sums`-exchange bytes per schedule)."""
    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import scan as scanlib
from repro.roofline.analyze import collective_bytes_from_hlo
mesh = jax.make_mesh((8,), ("d",))
N = 1 << 22
x = jax.ShapeDtypeStruct((N,), jnp.float32)
sh = NamedSharding(mesh, P("d"))
for ex in ("all_gather", "hillis_permute", "ring"):
    fn = lambda v: scanlib.scan_sharded(
        v, "sum", mesh=mesh, axis_name="d", spec=P("d"), variant=2,
        carry_exchange=ex, local_algorithm="blocked", block_size=262144)
    comp = jax.jit(fn, in_shardings=(sh,), out_shardings=sh).lower(x).compile()
    coll = collective_bytes_from_hlo(comp.as_text())
    print(f"{ex}\t{sum(coll.values())}\t{coll}")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600, env=env)
    t = Table("Fig 7b — carry-exchange collective bytes (8 devices, "
              "variant 2)", ["exchange", "total bytes", "detail"])
    if res.returncode:
        t.add("FAILED", res.stderr[-200:], "")
        return t
    for line in res.stdout.strip().splitlines():
        ex, total, detail = line.split("\t")
        t.add(ex, float(total), detail)
    return t


def run_faults(fault_seed: int = 3, requests: int = 12,
               smoke: bool = False) -> Table:
    """Serve-chaos mode (``--faults``): goodput and tick-latency tail of
    the hardened engine under seeded injection of step errors, NaN
    logits, and stalls — the 'availability under mutation' framing of
    the paper's service scenario. Compares a fault-free run against the
    same request mix under the injector."""
    import dataclasses
    import time
    import warnings

    if smoke:
        requests = min(requests, 6)

    from repro import configs
    from repro.serve import Engine, EngineConfig, FaultInjector, Request
    from repro.train.step import init_params

    cfg = dataclasses.replace(configs.get_smoke_config("stablelm-12b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(fault_seed)
    prompts = [rng.integers(2, cfg.vocab_size, size=int(rng.integers(3, 9)))
               .astype(np.int32) for _ in range(requests)]

    def drive(injector):
        eng = Engine(params, cfg, EngineConfig(
            max_slots=4, max_len=64, max_new_tokens=8, eos_id=-1,
            temperature=0.0), injector=injector)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p))
        tick_s = []
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            while eng.waiting or any(r is not None for r in eng.slot_req):
                t0 = time.perf_counter()
                eng.step()
                tick_s.append(time.perf_counter() - t0)
        eng.audit()
        done = eng.finished
        ok = sum(r.finish_reason in ("eos", "length_budget") for r in done)
        toks = sum(len(r.output) for r in done)
        lat = np.asarray(tick_s[1:] or tick_s)  # drop the compile tick
        return (f"{ok}/{len(done)}", toks / max(sum(tick_s), 1e-9),
                1e3 * float(np.percentile(lat, 50)),
                1e3 * float(np.percentile(lat, 99)), eng.stats)

    t = Table(f"Fig 7c — serve goodput under injected failures "
              f"(seed {fault_seed}, {requests} requests)",
              ["mode", "goodput", "tok/s", "p50 tick ms", "p99 tick ms",
               "retries", "degr", "quar"])
    good, tps, p50, p99, st = drive(None)
    t.add("fault-free", good, round(tps, 1), round(p50, 2), round(p99, 2),
          st.step_retries, st.degradations, st.quarantined)
    inj = FaultInjector.from_seed(
        fault_seed, ticks=256, p_error=0.1, p_nan=0.1, p_stall=0.05,
        stall_s=0.01, poison_rids=[requests - 1])
    good, tps, p50, p99, st = drive(inj)
    t.add("chaos", good, round(tps, 1), round(p50, 2), round(p99, 2),
          st.step_retries, st.degradations, st.quarantined)
    return t


def run_traffic(seed: int = 0, requests: int = 16,
                smoke: bool = False) -> Table:
    """Traffic mode (``--traffic``): bursty arrivals against a FIXED
    cache-memory budget — contiguous vs paged vs paged+COW prefix
    sharing (ISSUE 8 / ISSUE 9).

    Every engine gets the same 256-cache-token budget: contiguous
    spends it on 4 worst-case rows (4 slots x max_len 64); the paged
    rows spend it on 32 allocatable 8-token pages, admitting by ACTUAL
    length. All requests share a 24-token (3-page) system prompt and
    differ only in a 1-4 token tail, sized so no request ever grows
    past its 4th page: unshared, each costs 4 pages (peak 32/4 = 8
    concurrent); with ``share_prefixes`` the 3 prefix pages are mapped
    from the registry and each admission allocates ONE page, so the
    same pool sustains the full 14-slot burst — the ~1.75x peak-
    concurrency win the table pins. Same arrival trace, greedy
    sampling, eos disabled: all three token streams are asserted
    bitwise identical, so the deterministic columns (peak, ticks,
    page_allocs, tick-counted latency) gate tightly in CI while tok/s
    (wall-clock) gates loosely.
    """
    import dataclasses
    import time
    import warnings

    from repro import configs
    from repro.serve import Engine, EngineConfig, Request
    from repro.train.step import init_params

    requests = min(requests, 16) if smoke else requests
    cfg = dataclasses.replace(configs.get_smoke_config("stablelm-12b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prefix = rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(2, cfg.vocab_size,
                                            size=int(rng.integers(1, 5)))
                               .astype(np.int32)])
               for _ in range(requests)]
    # Arrival trace: an initial burst (saturates every pool) + Poisson.
    burst = min(14, requests)
    arrivals = [0] * burst
    tick = 0
    while len(arrivals) < requests:
        tick += 1
        for _ in range(int(rng.poisson(0.8))):
            if len(arrivals) < requests:
                arrivals.append(tick)

    base = dict(max_len=64, max_new_tokens=4, eos_id=-1, temperature=0.0)
    paged = dict(max_slots=14, cache_layout="paged", page_size=8,
                 num_pages=33, **base)
    layouts = {
        "contiguous (4 slots)": EngineConfig(max_slots=4, **base),
        "paged (14 slots, 32 pages)": EngineConfig(**paged),
        "paged + COW shared prefix": EngineConfig(share_prefixes=True,
                                                  **paged),
    }

    t = Table("Fig 7d — traffic: contiguous vs paged vs COW-shared KV "
              "at an equal 256-token cache budget",
              ["layout", "finished", "peak_active", "ticks",
               "p50 lat ticks", "p99 lat ticks", "page_allocs", "tok/s"])
    outputs, peaks = {}, {}
    for name, ecfg in layouts.items():
        eng = Engine(params, cfg, ecfg)
        nxt = peak = ticks = 0
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            while (nxt < requests or eng.waiting
                   or any(r is not None for r in eng.slot_req)):
                while nxt < requests and arrivals[nxt] <= ticks:
                    eng.submit(Request(rid=nxt, prompt=prompts[nxt]))
                    nxt += 1
                eng.step()
                peak = max(peak,
                           sum(r is not None for r in eng.slot_req))
                ticks += 1
                assert ticks < 10_000, "traffic run did not drain"
        wall = time.perf_counter() - t0
        eng.audit()
        toks = sum(len(r.output) for r in eng.finished)
        lat = np.asarray([r.finish_tick - r.submit_tick
                          for r in eng.finished], float)
        outputs[name] = {r.rid: list(r.output) for r in eng.finished}
        peaks[name] = peak
        t.add(name, len(eng.finished), peak, ticks,
              float(np.percentile(lat, 50)), float(np.percentile(lat, 99)),
              eng.stats.page_allocs,
              round(toks / max(wall, 1e-9), 1))
    ref = outputs["contiguous (4 slots)"]
    for name, out in outputs.items():
        assert out == ref, f"{name} token streams diverged from contiguous"
    ratio = peaks["paged + COW shared prefix"] / peaks[
        "paged (14 slots, 32 pages)"]
    assert ratio >= 1.5, (
        f"COW sharing should lift peak concurrency >=1.5x at an equal "
        f"page budget (got {ratio:.2f}x)")
    return t


if __name__ == "__main__":
    if "--faults" in sys.argv:
        run_faults().show()
    elif "--traffic" in sys.argv:
        run_traffic().show()
    else:
        run().show()
        run_device_parallel().show()
