"""Shared benchmark utilities: wall-clock timing + compiled-artifact
byte/flop counters (the CPU container measures algorithmic structure;
TPU numbers come from the roofline analysis of the dry-run)."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of ``fn(*args)`` (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def throughput(n_elems: int, seconds: float) -> float:
    """Billion elements per second."""
    return n_elems / seconds / 1e9


def hlo_bytes(fn: Callable, *args) -> dict:
    """flops + bytes accessed of the compiled fn (cost_analysis)."""
    comp = jax.jit(fn).lower(*args).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


class Table:
    def __init__(self, title: str, columns: list[str]):
        self.title = title
        self.columns = columns
        self.rows: list[list] = []

    def add(self, *row):
        self.rows.append(list(row))

    def render(self) -> str:
        widths = [len(c) for c in self.columns]
        srows = []
        for row in self.rows:
            srow = [f"{v:.4g}" if isinstance(v, float) else str(v)
                    for v in row]
            widths = [max(w, len(s)) for w, s in zip(widths, srow)]
            srows.append(srow)
        fmt = "  ".join(f"{{:<{w}}}" for w in widths)
        lines = [f"== {self.title} ==", fmt.format(*self.columns),
                 fmt.format(*["-" * w for w in widths])]
        lines += [fmt.format(*r) for r in srows]
        return "\n".join(lines)

    def show(self):
        print(self.render(), flush=True)
        print()
