"""Shared benchmark utilities: wall-clock timing + compiled-artifact
byte/flop counters (the CPU container measures algorithmic structure;
TPU numbers come from the roofline analysis of the dry-run).

Timing values are ``TimingStats`` — a float (the median, so every
existing ``t.add(..., sec * 1e3)`` call site and the table formatter are
unchanged) that additionally remembers the full run (p50/min/max/iters),
which is what ``Table.to_records()`` serializes into the bench-trajectory
JSON that ``tools/bench_gate.py`` diffs against the committed
``BENCH_*.json`` baselines."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


class TimingStats(float):
    """Median wall seconds that still remembers the run.

    Compares / formats / arithmetics as a plain float equal to the
    median; scaling by a plain number (unit conversion like ``* 1e3``)
    scales the remembered samples too, so the stats survive into the
    table cell. Mixing with another ``TimingStats`` degrades to float —
    there is no meaningful sample-wise pairing."""

    __slots__ = ("times",)

    def __new__(cls, times) -> "TimingStats":
        ts = tuple(float(t) for t in np.ravel(times))
        if not ts:
            raise ValueError("TimingStats needs at least one sample")
        self = super().__new__(cls, float(np.median(ts)))
        self.times = ts
        return self

    @property
    def p50(self) -> float:
        return float(self)

    @property
    def t_min(self) -> float:
        return min(self.times)

    @property
    def t_max(self) -> float:
        return max(self.times)

    @property
    def iters(self) -> int:
        return len(self.times)

    def _scaled(self, k):
        if isinstance(k, TimingStats) or not isinstance(k, (int, float)):
            return NotImplemented
        if k <= 0:  # median(k*x) == k*median(x) only for k > 0
            return float(self) * k
        return TimingStats([t * k for t in self.times])

    def __mul__(self, other):
        out = self._scaled(other)
        if out is not NotImplemented:
            return out
        if isinstance(other, (int, float)):
            return float(self) * float(other)  # both coerced: no recursion
        return NotImplemented

    __rmul__ = __mul__

    def __truediv__(self, other):
        if (isinstance(other, (int, float))
                and not isinstance(other, TimingStats) and other > 0):
            return self._scaled(1.0 / other)
        if isinstance(other, (int, float)):
            return float(self) / float(other)
        return NotImplemented

    def to_dict(self) -> dict:
        return {"p50": self.p50, "min": self.t_min, "max": self.t_max,
                "iters": self.iters}


def time_fn(fn: Callable, *args, iters: int = 5,
            warmup: int = 2) -> TimingStats:
    """Median wall seconds of ``fn(*args)`` (block_until_ready), as a
    ``TimingStats`` carrying the full sample set."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return TimingStats(ts)


def throughput(n_elems: int, seconds: float) -> float:
    """Billion elements per second."""
    return n_elems / seconds / 1e9


def hlo_bytes(fn: Callable, *args) -> dict:
    """flops + bytes accessed of the compiled fn (cost_analysis).

    Also accumulated into the obs default registry (``bench.hlo.flops``
    / ``bench.hlo.bytes`` / ``bench.hlo.compiles``) so a ``--json`` run's
    metrics block records the total compiled footprint it measured."""
    comp = jax.jit(fn).lower(*args).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }
    from repro.obs import default_registry
    reg = default_registry()
    reg.counter("bench.hlo.compiles").inc()
    reg.counter("bench.hlo.flops").inc(out["flops"])
    reg.counter("bench.hlo.bytes").inc(out["bytes"])
    return out


class Table:
    def __init__(self, title: str, columns: list[str]):
        self.title = title
        self.columns = columns
        self.rows: list[list] = []

    def add(self, *row):
        self.rows.append(list(row))

    def render(self) -> str:
        widths = [len(c) for c in self.columns]
        srows = []
        for row in self.rows:
            srow = [f"{v:.4g}" if isinstance(v, float) else str(v)
                    for v in row]
            widths = [max(w, len(s)) for w, s in zip(widths, srow)]
            srows.append(srow)
        fmt = "  ".join(f"{{:<{w}}}" for w in widths)
        lines = [f"== {self.title} ==", fmt.format(*self.columns),
                 fmt.format(*["-" * w for w in widths])]
        lines += [fmt.format(*r) for r in srows]
        return "\n".join(lines)

    def to_records(self) -> dict:
        """JSON-safe document for the bench trajectory: title + columns
        + rows, with ``TimingStats`` cells expanded to their full stats
        dict (everything else passes through as the scalar the table
        shows)."""
        def cell(v):
            if isinstance(v, TimingStats):
                return v.to_dict()
            if isinstance(v, bool):
                return v
            if isinstance(v, (int, np.integer)):
                return int(v)
            if isinstance(v, (float, np.floating)):
                return float(v)
            if isinstance(v, str) or v is None:
                return v
            return str(v)
        return {
            "title": self.title,
            "columns": list(self.columns),
            "rows": [[cell(v) for v in row] for row in self.rows],
        }

    def show(self):
        print(self.render(), flush=True)
        print()
