"""Scan-engine sweep: schedule × monoid, one table.

The engine's promise is that each grid organization is written once and
runs over every registered monoid. This sweep drives all sixteen
(schedule, monoid) cells through the family ``ops`` wrappers, checks the
cross-schedule parity invariant on the fly — BIT-parity for the
carry/decoupled/fused trio (shared in-tile network), tolerance for the
tree's different association on float data (``atol<=2e-4``; integral
monoids stay bitwise) — and reports wall-clock plus what
``policy.choose_schedule`` would pick for the shape, so the four-way
policy rule can be eyeballed against measurement on real hardware (on
the CPU container the kernels run in interpret mode and wall-clock
mostly reflects algorithmic structure).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, time_fn, throughput
from repro.core.scan import policy
from repro.kernels.compact import ops as kc_ops
from repro.kernels.scan_blocked import ops as sb_ops
from repro.kernels.segscan import ops as seg_ops
from repro.kernels.ssm_scan import ops as ssm_ops

SCHEDULES = ("carry", "decoupled", "fused", "tree")
TREE_ATOL = 2e-4


def _parity(baseline, leaves, schedule: str) -> str:
    same = all(bool(jnp.all(a == b)) for a, b in zip(baseline, leaves))
    if same:
        return "bitwise"
    if schedule == "tree" and all(
            np.allclose(np.asarray(a, np.float64), np.asarray(b, np.float64),
                        rtol=TREE_ATOL, atol=TREE_ATOL)
            for a, b in zip(baseline, leaves)):
        return f"atol<={TREE_ATOL:g}"
    return "DIVERGED"


def _cases(smoke: bool):
    rng = np.random.default_rng(0)
    if smoke:
        B, N = 1, 1 << 13
        T, D = 1 << 10, 128
    else:
        B, N = 1, 1 << 20
        T, D = 1 << 17, 256
    x = jnp.asarray(rng.standard_normal((B, N)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, N)), jnp.float32)
    f = jnp.asarray(rng.random((B, N)) < 0.01, jnp.int32)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (1, T, D)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, T, D)), jnp.float32)
    m = jnp.asarray(rng.random((B, N)) < 0.5, jnp.int32)
    bn = 1 << 11
    return [
        ("sum", B * N, B, N,
         lambda s: functools.partial(sb_ops.cumsum, x, interpret=True,
                                     schedule=s, block_n=bn)),
        ("segmented", B * N, B, N,
         lambda s: functools.partial(seg_ops.segmented_cumsum, v, f,
                                     interpret=True, schedule=s,
                                     block_n=bn)),
        ("affine", T * D, 1, T,
         lambda s: functools.partial(ssm_ops.ssm_scan, a, b, interpret=True,
                                     schedule=s)),
        ("mask", B * N, B, N,
         lambda s: functools.partial(kc_ops.mask_compact, m, interpret=True,
                                     schedule=s, block_n=bn)),
    ]


def run(smoke: bool = False) -> Table:
    t = Table("Scan engine: schedule x monoid (kernel interpret mode)",
              ["monoid", "schedule", "policy", "parity", "Belem/s", "ms"])
    for name, elems, batch, n, make in _cases(smoke):
        chosen = policy.choose_schedule(batch, n)
        baseline = None
        for schedule in SCHEDULES:
            fn = make(schedule)
            out = fn()
            leaves = out if isinstance(out, tuple) else (out,)
            if baseline is None:
                baseline = leaves
                parity = "ref"
            else:
                parity = _parity(baseline, leaves, schedule)
            sec = time_fn(fn, iters=3, warmup=1)
            mark = " <- policy" if schedule == chosen else ""
            t.add(name, schedule + mark,
                  chosen if schedule == "carry" else "",
                  parity, throughput(elems, sec), sec * 1e3)
    return t


if __name__ == "__main__":
    run().show()
