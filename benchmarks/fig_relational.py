"""Relational operators on the scan substrate vs reference baselines.

Two tables:
  * filter selectivity sweep — prefix-sum stream compaction (library
    scan and fused Pallas kernel paths) against XLA's nonzero-gather,
    at low/mid/high selectivity (compaction work is selectivity-
    independent; the gather baseline is not).
  * sort / join — LSD radix sort (composed prefix-sum partition passes)
    against ``jnp.sort``/``jnp.argsort``, and the partitioned hash join
    against a sort-merge expansion, with correctness checked against
    numpy on every cell.

On the CPU container the Pallas path runs in interpret mode, so
wall-clock reflects algorithmic structure, not TPU speed. ``smoke=True``
shrinks every size so ``benchmarks.run --dry-run`` can exercise the
whole figure in seconds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, throughput, time_fn
from repro import relational as rel

SELECTIVITIES = (0.01, 0.5, 0.99)


def run(smoke: bool = False) -> Table:
    """Filter (stream compaction) selectivity sweep."""
    N = 1 << 12 if smoke else 1 << 18
    t = Table("Relational filter: prefix-sum compaction vs nonzero-gather",
              ["N", "selectivity", "path", "Melem/s", "ms"])
    paths = {
        "scan-ref": jax.jit(functools.partial(
            rel.filter_compact, algorithm="ref")),
        "kernel": jax.jit(functools.partial(
            rel.filter_compact, algorithm="kernel", interpret=True)),
        "nonzero-gather": jax.jit(
            lambda v, m: (v[jnp.nonzero(m, size=v.shape[0],
                                        fill_value=v.shape[0] - 1)[0]],
                          jnp.sum(m.astype(jnp.int32)))),
    }
    for sel in SELECTIVITIES:
        rng = np.random.default_rng(int(sel * 100))
        x = jnp.asarray(rng.integers(0, 1 << 20, N), jnp.int32)
        mask = jnp.asarray(rng.random(N) < sel)
        want = np.asarray(x)[np.asarray(mask)]
        for name, fn in paths.items():
            out, count = fn(x, mask)
            assert int(count) == len(want), name
            np.testing.assert_array_equal(
                np.asarray(out)[: len(want)], want, err_msg=name)
            sec = time_fn(fn, x, mask, iters=3, warmup=1)
            t.add(N, sel, name, throughput(N, sec) * 1e3, sec * 1e3)
    return t


def run_sort_join(smoke: bool = False) -> Table:
    """Radix sort and partitioned hash join vs XLA sort baselines."""
    N = 1 << 9 if smoke else 1 << 13
    t = Table("Relational sort/join (prefix-sum partition passes)",
              ["op", "N", "dtype", "path", "Melem/s", "ms"])
    for dt, name in ((jnp.int32, "int32"), (jnp.float32, "float32")):
        rng = np.random.default_rng(7)
        if name == "int32":
            x = jnp.asarray(rng.integers(-(1 << 30), 1 << 30, N), dt)
        else:
            x = jnp.asarray(rng.standard_normal(N), dt)
        want = np.sort(np.asarray(x))
        for path, fn in (("radix_sort", jax.jit(rel.radix_sort)),
                         ("jnp.sort", jax.jit(jnp.sort))):
            np.testing.assert_array_equal(np.asarray(fn(x)), want,
                                          err_msg=path)
            sec = time_fn(fn, x, iters=3, warmup=1)
            t.add("sort", N, name, path, throughput(N, sec) * 1e3,
                  sec * 1e3)

    # Join: key range sized for ~4 matches per probe row.
    L = R = N
    rng = np.random.default_rng(11)
    lk = jnp.asarray(rng.integers(0, max(R // 4, 1), L), jnp.int32)
    rk = jnp.asarray(rng.integers(0, max(R // 4, 1), R), jnp.int32)
    res = rel.hash_join(lk, rk)
    pairs = int(res.count)
    cap = res.left_index.shape[0]

    def merge_baseline(lk, rk):
        # sort-merge expansion with the same fixed-size output contract
        order = jnp.argsort(rk)
        srk = rk[order]
        lo = jnp.searchsorted(srk, lk, side="left")
        hi = jnp.searchsorted(srk, lk, side="right")
        m = hi - lo
        off = jnp.cumsum(m) - m
        p = jnp.arange(cap, dtype=jnp.int32)
        li = jnp.clip(jnp.searchsorted(off, p, side="right") - 1, 0, L - 1)
        rs = jnp.clip(lo[li] + (p - off[li]), 0, R - 1)
        return li, order[rs], off[-1] + m[-1]

    for path, fn in (
            ("hash_join", jax.jit(functools.partial(
                rel.hash_join, max_matches=cap))),
            ("sort-merge", jax.jit(merge_baseline))):
        got = fn(lk, rk)
        assert int(got[2]) == pairs, path  # count field in both contracts
        sec = time_fn(fn, lk, rk, iters=3, warmup=1)
        t.add("join", N, f"{pairs} pairs", path,
              throughput(L + R, sec) * 1e3, sec * 1e3)
    return t


if __name__ == "__main__":
    run().show()
    run_sort_join().show()
