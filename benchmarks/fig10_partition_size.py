"""Paper Fig. 10 — effect of partition (block) sizes.

The paper finds ½·L2 per thread optimal on CPU, and L1-sized partitions
for gather/scatter algorithms. The TPU analogue: the Pallas kernel's
block_n bounds its VMEM working set; we sweep block sizes through the
blocked (lax.scan-fused) scan and report wall time + the compiled
temp-allocation footprint, and the kernel's VMEM-claim per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, throughput, time_fn
from repro.core import scan as scanlib

N = 1 << 22
BLOCKS = [1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 17, 1 << 18, 1 << 20]


def run() -> Table:
    x = jnp.asarray(np.random.default_rng(0).standard_normal(N), jnp.float32)
    t = Table("Fig 10 — block (partition) size sweep, blocked scan",
              ["block floats", "working set KiB", "Belem/s", "ms"])
    for b in BLOCKS:
        fn = jax.jit(functools.partial(
            scanlib.scan_blocked, op="sum", block_size=b))
        sec = time_fn(fn, x, iters=3)
        t.add(b, b * 4 // 1024, throughput(N, sec), sec * 1e3)
    return t


def run_kernel_vmem() -> Table:
    """The kernel's per-block VMEM claim for the same sweep (the quantity
    the paper's ½-L2 heuristic controls; v5e VMEM ≈ 128 MiB/core class,
    we budget ≤ 1/8)."""
    t = Table("Fig 10b — Pallas kernel block VMEM claim",
              ["block_n", "in+out+carry KiB", "fits 16MiB budget"])
    for bn in (512, 2048, 8192, 32768, 131072):
        kib = (2 * 8 * bn * 4 + 8 * 4) / 1024  # in+out tiles (8, bn) f32
        t.add(bn, kib, bool(kib <= 16 * 1024))
    return t


if __name__ == "__main__":
    run().show()
    run_kernel_vmem().show()
