"""Render the §Roofline table from the dry-run JSON records."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Table

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def load_records(d: str = DEFAULT_DIR) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(d: str = DEFAULT_DIR) -> Table:
    t = Table("Roofline terms per dry-run cell (seconds, per-device)",
              ["arch", "shape", "mesh", "status", "compute", "memory",
               "collective", "dcn", "dominant", "useful%"])
    for r in load_records(d):
        if r.get("status") != "ok":
            t.add(r["arch"], r["shape"], r["mesh"], "FAIL", "-", "-", "-",
                  "-", "-", "-")
            continue
        if r.get("knobs", {}).get("tag"):
            continue
        t.add(r["arch"], r["shape"], r["mesh"], "ok",
              r["compute_s"], r["memory_s"], r["collective_s"], r["dcn_s"],
              r["dominant"], 100.0 * r["useful_ratio"])
    return t


if __name__ == "__main__":
    run().show()
