"""Engine-backed flash attention sweep: fold schedule × config, one table.

The SOFTMAX_PAIR registration's promise is that the generic fold
schedules pay nothing versus the old hand-rolled kernel: this sweep
drives both organizations (carry accumulate / split-KV decoupled)
through the public ``flash_attention`` wrapper across the masking grid
(causal, sliding window, softcap, GQA), checks parity against the dense
oracle on the fly, and reports wall-clock plus what
``policy.choose_attention_schedule`` would pick for the shape — so the
two-way attention rule can be eyeballed against measurement on real
hardware (on the CPU container the kernels run in interpret mode and
wall-clock mostly reflects algorithmic structure).

    PYTHONPATH=src python -m benchmarks.fig_attention            # full
    PYTHONPATH=src python -m benchmarks.fig_attention --dry-run  # smoke
"""

from __future__ import annotations

import functools
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, time_fn, throughput
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref

SCHEDULES = ("carry", "decoupled")


def _cases(smoke: bool):
    rng = np.random.default_rng(0)
    if smoke:
        B, Hkv, g, T, D = 1, 2, 2, 256, 32
    else:
        B, Hkv, g, T, D = 1, 8, 4, 4096, 128

    def qkv(seed):
        r = np.random.default_rng(seed)
        q = jnp.asarray(r.standard_normal((B, Hkv * g, T, D)), jnp.float32)
        k = jnp.asarray(r.standard_normal((B, Hkv, T, D)), jnp.float32)
        v = jnp.asarray(r.standard_normal((B, Hkv, T, D)), jnp.float32)
        return q, k, v

    grid = [
        ("causal", dict(causal=True)),
        ("window", dict(causal=True, window=max(T // 4, 64))),
        ("softcap", dict(causal=True, softcap=30.0)),
        ("full", dict(causal=False)),
    ]
    del rng
    return [(name, qkv(i), dict(kw, scale=D ** -0.5))
            for i, (name, kw) in enumerate(grid)]


def run(smoke: bool = False) -> Table:
    t = Table("Flash attention on the scan engine: fold schedule x config "
              "(kernel interpret mode)",
              ["config", "schedule", "policy", "max|err| vs dense",
               "Gdot/s", "ms"])
    for name, (q, k, v), kw in _cases(smoke):
        B, Hq, T, D = q.shape
        Hkv = k.shape[1]
        ref = fa_ref.mha_ref(
            q.reshape(B * Hq, T, D), k.reshape(B * Hkv, T, D),
            v.reshape(B * Hkv, T, D), group=Hq // Hkv, **kw,
        ).reshape(q.shape)
        chosen = fa_ops.resolved_attention_schedule(q.shape, T)
        for schedule in SCHEDULES:
            fn = functools.partial(
                fa_ops.flash_attention, q, k, v, schedule=schedule,
                interpret=True, **kw)
            err = float(jnp.max(jnp.abs(fn() - ref)))
            sec = time_fn(fn, iters=3, warmup=1)
            mark = " <- policy" if schedule == chosen else ""
            # logits + weighted-value dot elements per pass
            elems = 2 * B * Hq * T * T * D
            t.add(name, schedule + mark,
                  chosen if schedule == "carry" else "",
                  err, throughput(elems, sec), sec * 1e3)
    return t


def main(argv=None):
    names = list(argv if argv is not None else sys.argv[1:])
    run(smoke="--dry-run" in names).show()
    return 0


if __name__ == "__main__":
    sys.exit(main())
