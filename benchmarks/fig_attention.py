"""Engine-backed flash attention sweep: fold schedule × config, one table.

The SOFTMAX_PAIR registration's promise is that the generic fold
schedules pay nothing versus the old hand-rolled kernel: this sweep
drives both organizations (carry accumulate / split-KV decoupled)
through the public ``flash_attention`` wrapper across the masking grid
(causal, sliding window, softcap, GQA), checks parity against the dense
oracle on the fly, and reports wall-clock plus what
``policy.choose_attention_schedule`` would pick for the shape — so the
two-way attention rule can be eyeballed against measurement on real
hardware (on the CPU container the kernels run in interpret mode and
wall-clock mostly reflects algorithmic structure).

    PYTHONPATH=src python -m benchmarks.fig_attention            # full
    PYTHONPATH=src python -m benchmarks.fig_attention --dry-run  # smoke
"""

from __future__ import annotations

import functools
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, time_fn, throughput
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref

SCHEDULES = ("carry", "decoupled")


def _cases(smoke: bool):
    if smoke:
        B, Hkv, g, T, D = 1, 2, 2, 256, 32
    else:
        B, Hkv, g, T, D = 1, 8, 4, 4096, 128

    def qkv(seed):
        r = np.random.default_rng(seed)
        q = jnp.asarray(r.standard_normal((B, Hkv * g, T, D)), jnp.float32)
        k = jnp.asarray(r.standard_normal((B, Hkv, T, D)), jnp.float32)
        v = jnp.asarray(r.standard_normal((B, Hkv, T, D)), jnp.float32)
        return q, k, v

    grid = [
        ("causal", dict(causal=True)),
        ("window", dict(causal=True, window=max(T // 4, 64))),
        ("softcap", dict(causal=True, softcap=30.0)),
        ("full", dict(causal=False)),
    ]
    return [(name, qkv(i), dict(kw, scale=D ** -0.5))
            for i, (name, kw) in enumerate(grid)]


def run(smoke: bool = False) -> Table:
    t = Table("Flash attention on the scan engine: fold schedule x config "
              "(kernel interpret mode)",
              ["config", "schedule", "policy", "max|err| vs dense",
               "Gdot/s", "ms"])
    for name, (q, k, v), kw in _cases(smoke):
        B, Hq, T, D = q.shape
        Hkv = k.shape[1]
        ref = fa_ref.mha_ref(
            q.reshape(B * Hq, T, D), k.reshape(B * Hkv, T, D),
            v.reshape(B * Hkv, T, D), group=Hq // Hkv, **kw,
        ).reshape(q.shape)
        chosen = fa_ops.resolved_attention_schedule(q.shape, T)
        for schedule in SCHEDULES:
            fn = functools.partial(
                fa_ops.flash_attention, q, k, v, schedule=schedule,
                interpret=True, **kw)
            err = float(jnp.max(jnp.abs(fn() - ref)))
            sec = time_fn(fn, iters=3, warmup=1)
            mark = " <- policy" if schedule == chosen else ""
            # logits + weighted-value dot elements per pass
            elems = 2 * B * Hq * T * T * D
            t.add(name, schedule + mark,
                  chosen if schedule == "carry" else "",
                  err, throughput(elems, sec), sec * 1e3)
    return t


def run_bwd(smoke: bool = False) -> Table:
    """Backward sweep: jax.grad through the engine flash (custom_vjp →
    stats forward + dq/dkv folds) vs autodiff of the jnp blockwise
    reference, per schedule and per causal-bound setting — so the
    bound's compute saving and the engine-vs-autodiff gap can both be
    eyeballed on hardware."""
    import jax

    t = Table("Flash attention backward: engine folds vs autodiff "
              "blockwise (kernel interpret mode)",
              ["config", "schedule", "kv bounds", "max|dgrad| vs autodiff",
               "Gdot/s", "ms"])
    for name, (q, k, v), kw in _cases(smoke):
        B, Hq, T, D = q.shape
        Hkv = k.shape[1]

        def ref_loss(q, k, v, kw=kw):
            o = fa_ref.blockwise_ref(
                q.reshape(B * Hq, T, D), k.reshape(B * Hkv, T, D),
                v.reshape(B * Hkv, T, D), group=Hq // Hkv,
                block_k=min(512, T), **kw)
            return jnp.sum(o ** 2)

        want = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
        for schedule in SCHEDULES:
            for bounds in (True, False):
                def loss(q, k, v, schedule=schedule, bounds=bounds, kw=kw):
                    return jnp.sum(fa_ops.flash_attention(
                        q, k, v, schedule=schedule, use_kv_bounds=bounds,
                        interpret=True, **kw) ** 2)

                grad_fn = jax.grad(loss, argnums=(0, 1, 2))
                got = grad_fn(q, k, v)
                err = max(float(jnp.max(jnp.abs(a - b)))
                          for a, b in zip(got, want))
                sec = time_fn(lambda: grad_fn(q, k, v)[0],
                              iters=3, warmup=1)
                # fwd-with-stats + dq + dkv: ~3.5x the forward dots
                elems = 7 * B * Hq * T * T * D
                t.add(name, schedule, "on" if bounds else "off", err,
                      throughput(elems, sec), sec * 1e3)
    return t


def main(argv=None):
    names = list(argv if argv is not None else sys.argv[1:])
    smoke = "--dry-run" in names
    run(smoke).show()
    run_bwd(smoke).show()
    return 0


if __name__ == "__main__":
    sys.exit(main())
