"""Decoupled vs carry-chain kernel schedules across the (B, N) plane.

The paper's Observation 3 says the winning multithreaded organization is
reduce-first two-phase (SIMD2-P); our carry-chain kernel is instead the
fused single-pass with a sequential sequence axis. This table measures
where each wins — long single rows (the serve-engine / SSM decode shape)
versus batched training shapes — plus the library two-pass baseline, and
prints what ``policy.choose_schedule`` would pick so the policy rule can
be eyeballed against measurement.

On the CPU container the kernels run in interpret mode, so wall-clock
mostly reflects algorithmic structure; compiled-HLO bytes (``hlo_bytes``)
show the traffic trade (decoupled reads the data twice).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, hlo_bytes, throughput, time_fn
from repro.core import scan as scanlib
from repro.core.scan import policy
from repro.kernels.scan_blocked import ops as sb_ops

# (B, N) cells: equal element count, batch collapsing toward one long row.
CELLS = [
    (64, 1 << 16),
    (8, 1 << 19),
    (1, 1 << 22),
]


def run() -> Table:
    t = Table("Decoupled vs carry grid schedule (kernel interpret mode)",
              ["B", "N", "schedule", "policy", "Belem/s", "ms"])
    for B, N in CELLS:
        x = jnp.asarray(
            np.random.default_rng(B).standard_normal((B, N)), jnp.float32)
        ref = np.cumsum(np.asarray(x, np.float64), axis=-1)
        chosen = policy.choose_schedule(B, N)
        for schedule in ("carry", "decoupled", "two_pass"):
            if schedule == "two_pass":
                fn = jax.jit(functools.partial(
                    scanlib.scan_two_pass, op="sum",
                    num_partitions=policy.NUM_CORES))
            else:
                fn = functools.partial(
                    sb_ops.cumsum, interpret=True, schedule=schedule)
            got = np.asarray(fn(x), np.float64)
            np.testing.assert_allclose(got, ref, rtol=5e-3, atol=5e-1)
            sec = time_fn(fn, x, iters=3, warmup=1)
            mark = " <- policy" if schedule == chosen else ""
            t.add(B, N, schedule + mark,
                  chosen if schedule == "carry" else "",
                  throughput(B * N, sec), sec * 1e3)
    return t


def run_traffic() -> Table:
    """Compiled-HLO bytes per schedule: the read-2n price of decoupling."""
    t = Table("Schedule HBM-traffic model (compiled bytes, B=1)",
              ["N", "schedule", "bytes", "bytes/elem"])
    for N in (1 << 18, 1 << 20):
        x = jnp.zeros((1, N), jnp.float32)
        for schedule in ("carry", "decoupled"):
            cost = hlo_bytes(functools.partial(
                sb_ops.cumsum, interpret=True, schedule=schedule), x)
            t.add(N, schedule, cost["bytes"], cost["bytes"] / N)
    return t


if __name__ == "__main__":
    run().show()
    run_traffic().show()
