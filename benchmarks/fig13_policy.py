"""Paper Fig. 13 — high-bandwidth memory: when NOT to partition.

On KNL's MCDRAM the paper found partitioning overhead exceeds its payoff
once bandwidth is abundant. Codified in ``core/scan/policy.py``: we show
the policy flipping algorithms as the bandwidth regime changes, and the
roofline arithmetic behind it (bytes moved × bandwidth vs sync overhead)
for the v5e HBM numbers.
"""

from __future__ import annotations

from benchmarks.common import Table
from repro.core.scan.policy import choose
from repro.launch.mesh import HBM_BW


def run() -> Table:
    t = Table("Fig 13 — policy under bandwidth regimes",
              ["n floats", "bandwidth", "algorithm", "block", "reason"])
    for n in (1 << 14, 1 << 22, 1 << 28):
        for abundant in (False, True):
            c = choose(n, itemsize=4, bandwidth_abundant=abundant)
            t.add(n, "abundant" if abundant else "bound", c.algorithm,
                  c.block_size, c.reason[:48])
    return t


def run_traffic_model() -> Table:
    """Bytes-moved model behind Obs 2 (per element, f32):
    unfused two-pass = 4 slow-memory ops/elem (r+w pass1, r+w pass2) for
    v1; partitioned = 2 (r+w once, second pass in cache)."""
    t = Table("Fig 13b — slow-memory traffic model @ v5e HBM",
              ["algorithm", "bytes/elem", "s per Gelem", "note"])
    rows = [
        ("TwoPass v1", 16, "pass1 r+w, pass2 r+w"),
        ("TwoPass v2", 12, "pass1 r, pass2 r+w"),
        ("Blocked(-P)", 8, "one fused pass: r+w"),
        ("Kernel(-P)", 8, "same, explicit VMEM tiles"),
    ]
    for name, bpe, note in rows:
        t.add(name, bpe, bpe * 1e9 / HBM_BW, note)
    return t


if __name__ == "__main__":
    run().show()
    run_traffic_model().show()
