"""Framework-level benchmark: prefix-sum MoE dispatch (paper §1 use case).

Throughput of the scan-offset partitioning step (histogram → exclusive
scan → rank → scatter) vs a sort-based dispatch baseline — the two
standard implementations of MoE routing. The scan-based path is the
paper's radix-partitioning pattern; sort is the comparison the paper's
§1 applications (radix sort/join) replace.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, time_fn
from repro.core.scan.segmented import dispatch_offsets


def _scan_dispatch(ids, E, C):
    plan = dispatch_offsets(ids, E)
    keep = plan.ranks < C
    return jnp.where(keep, ids * C + plan.ranks, E * C)


def _sort_dispatch(ids, E, C):
    T = ids.shape[0]
    order = jnp.argsort(ids)                      # stable radix-ish sort
    sorted_ids = ids[order]
    # rank within expert after sort = position - first occurrence
    first = jnp.searchsorted(sorted_ids, jnp.arange(E))
    rank_sorted = jnp.arange(T) - first[sorted_ids]
    slot_sorted = jnp.where(rank_sorted < C,
                            sorted_ids * C + rank_sorted, E * C)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T))
    return slot_sorted[inv]


def run() -> Table:
    t = Table("MoE dispatch — scan offsets vs sort (tokens/s)",
              ["tokens", "experts", "scan Mtok/s", "sort Mtok/s",
               "agree"])
    for T, E in [(1 << 14, 32), (1 << 16, 128)]:
        C = max(8, int(T * 1.25 / E))
        ids = jnp.asarray(
            np.random.default_rng(0).integers(0, E, T), jnp.int32)
        f_scan = jax.jit(lambda i: _scan_dispatch(i, E, C))
        f_sort = jax.jit(lambda i: _sort_dispatch(i, E, C))
        s_scan = time_fn(f_scan, ids, iters=5)
        s_sort = time_fn(f_sort, ids, iters=5)
        a = np.asarray(f_scan(ids))
        b = np.asarray(f_sort(ids))
        # both must route every kept token to a unique slot
        kept_a = a[a < E * C]
        kept_b = b[b < E * C]
        agree = (len(np.unique(kept_a)) == len(kept_a)
                 and len(np.unique(kept_b)) == len(kept_b)
                 and len(kept_a) == len(kept_b))
        t.add(T, E, T / s_scan / 1e6, T / s_sort / 1e6, agree)
    return t


if __name__ == "__main__":
    run().show()
