"""Paper Fig. 8/9 — in-place vs out-of-place, as XLA buffer donation.

In-place (donated input) lets XLA reuse the input buffer for the output —
the allocation/traffic effect the paper measures across memory banks. We
report wall time and the compiled temp-allocation size with and without
donation, for the blocked scan and the Pallas kernel wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, throughput, time_fn
from repro.core import scan as scanlib

N = 1 << 22


def _temp_bytes(fn, donate: bool, x_spec):
    jf = jax.jit(fn, donate_argnums=(0,) if donate else ())
    comp = jf.lower(x_spec).compile()
    ma = comp.memory_analysis()
    return jf, float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "output_size_in_bytes", 0))


def run() -> Table:
    spec = jax.ShapeDtypeStruct((N,), jnp.float32)
    blocked = functools.partial(scanlib.scan_blocked, op="sum",
                                block_size=128 * 1024)
    t = Table("Fig 8/9 — in-place (donated) vs out-of-place",
              ["variant", "donate", "out+temp bytes/elem", "Belem/s"])
    for name, fn in [("Blocked(-P)", blocked),
                     ("TwoPass v2", functools.partial(
                         scanlib.scan_two_pass, op="sum",
                         num_partitions=8, variant=2))]:
        for donate in (False, True):
            jf, tb = _temp_bytes(fn, donate, spec)
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal(N), jnp.float32)
            if donate:
                sec = time_fn(lambda v: jf(v + 0), x, iters=5)  # fresh buf
            else:
                sec = time_fn(jf, x, iters=5)
            t.add(name, donate, tb / N, throughput(N, sec))
    return t


if __name__ == "__main__":
    run().show()
