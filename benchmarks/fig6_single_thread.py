"""Paper Fig. 6 — single-thread throughput of the scan variants.

Scalar (sequential oracle), SIMD horizontal, SIMD-V1/V2 vertical, SIMD-T
tree, the partitioned/blocked variant, the Pallas kernel (interpret), and
two 'library' baselines (jnp.cumsum = XLA's native, and
jax.lax.associative_scan = the library parallel scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, throughput, time_fn
from repro.core import scan as scanlib

N = 1 << 22  # 4M floats (CPU-sized; the paper uses 32M per thread)


def run() -> Table:
    x = jnp.asarray(np.random.default_rng(0).standard_normal(N), jnp.float32)

    variants = {
        "Scalar(ref)": lambda v: scanlib.scan_ref(v, "sum"),
        "SIMD(horizontal)": lambda v: scanlib.scan_horizontal(v, "sum"),
        "SIMD-V1(vertical)": functools.partial(
            scanlib.scan_vertical, op="sum", variant=1),
        "SIMD-V2(vertical)": functools.partial(
            scanlib.scan_vertical, op="sum", variant=2),
        "SIMD-T(tree)": lambda v: scanlib.scan_tree(v, "sum"),
        "Blocked(-P)": functools.partial(
            scanlib.scan_blocked, op="sum", block_size=128 * 1024),
        "TwoPass(no-P)": functools.partial(
            scanlib.scan_two_pass, op="sum", num_partitions=8),
        "Kernel(interp)": lambda v: scanlib.scan(v, "sum",
                                                 algorithm="kernel",
                                                 interpret=True),
        "lib:jnp.cumsum": lambda v: jnp.cumsum(v),
        "lib:assoc_scan": lambda v: jax.lax.associative_scan(jnp.add, v),
    }

    t = Table("Fig 6 — single-device scan throughput (CPU wall-clock)",
              ["variant", "Belem/s", "ms"])
    ref = np.cumsum(np.asarray(x), dtype=np.float64)
    for name, fn in variants.items():
        jf = jax.jit(fn)
        got = np.asarray(jf(x), np.float64)
        np.testing.assert_allclose(got, ref, rtol=5e-3, atol=5e-1)
        sec = time_fn(jf, x, iters=3 if "interp" in name else 5,
                      warmup=1 if "interp" in name else 2)
        t.add(name, throughput(N, sec), sec * 1e3)
    return t


if __name__ == "__main__":
    run().show()
