"""Distribution layer: logical-axis sharding over the installed mesh."""

from repro.dist import sharding
from repro.dist.sharding import (current_mesh, resolve, sanitize_spec, shard,
                                 shard_map, spec_for_params, use_mesh)

__all__ = [
    "current_mesh", "resolve", "sanitize_spec", "shard", "shard_map",
    "sharding", "spec_for_params", "use_mesh",
]
