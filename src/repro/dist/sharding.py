"""Logical-axis sharding tables + the installed-mesh context.

Model code never names mesh axes directly.  It annotates arrays with
LOGICAL axes ("batch", "heads", "mlp", ...) via ``repro.dist.shard`` and
this module resolves them against the currently installed mesh through a
rules table (logical axis -> tuple of mesh axes).  The production meshes
(launch/mesh.py) use axes ('pod',) 'data', 'model'; tests install small
debug meshes; with no mesh installed every annotation is a no-op — the
same model code runs single-device CPU tests and 512-chip dry-runs.

Rule overrides per launch cell (e.g. long_500k's sequence-over-everything
sharding) are passed to ``use_mesh(mesh, rules)`` and merged over the
defaults for the duration of the context.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# jax moved shard_map out of experimental across the 0.4.x line; export
# one resolved symbol so callers (and test subprocesses) don't chase it.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.37
    from jax.experimental.shard_map import shard_map  # noqa: F401

# Logical axis -> mesh axes (filtered to the installed mesh's axis names).
# The data-parallel axes shard 'batch'; the tensor/expert-parallel axis
# 'model' shards exactly one logical dim per array (GSPMD forbids reuse of
# a mesh axis within one spec — the tables below are arranged so resolved
# specs never repeat an axis).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),               # sequence replicated by default ...
    "seq_shard": ("model",),  # ... except KV/state slots in serve cells
    "embed": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "capacity": (),
    "ssm_inner": ("model",),
    "layers": (),
}


class _State(threading.local):
    def __init__(self):
        self.stack: list[tuple[Mesh, dict[str, tuple[str, ...]]]] = []


_STATE = _State()


def current_mesh() -> Optional[Mesh]:
    """The innermost installed mesh, or None outside any ``use_mesh``."""
    return _STATE.stack[-1][0] if _STATE.stack else None


def current_rules() -> dict[str, tuple[str, ...]]:
    return _STATE.stack[-1][1] if _STATE.stack else dict(DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    """Install ``mesh`` (+ optional logical-rule overrides) for the block."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update({k: tuple(v) if not isinstance(v, str) else (v,)
                       for k, v in rules.items()})
    _STATE.stack.append((mesh, merged))
    try:
        with mesh:
            yield mesh
    finally:
        _STATE.stack.pop()


def resolve(axes) -> P:
    """Logical axis names (or None) per dim -> PartitionSpec.

    Unknown logical names and names whose mesh axes are absent from the
    installed mesh resolve to None (replicated).
    """
    mesh = current_mesh()
    rules = current_rules()
    entries = []
    for ax in axes:
        if ax is None:
            entries.append(None)
            continue
        mesh_axes = rules.get(ax, ())
        if mesh is not None:
            mesh_axes = tuple(a for a in mesh_axes if a in mesh.shape)
        if not mesh_axes:
            entries.append(None)
        elif len(mesh_axes) == 1:
            entries.append(mesh_axes[0])
        else:
            entries.append(mesh_axes)
    return P(*entries)


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec entries that do not divide the dim (or reuse an axis).

    jit in/out shardings require every sharded dim to be divisible by the
    product of its mesh-axis sizes; undivisible entries degrade to
    replicated rather than error (small debug meshes, odd head counts).
    """
    used: set = set()
    entries = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            entries.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        while axes and dim % math.prod(mesh.shape[a] for a in axes):
            axes = axes[:-1]           # shed trailing axes until it fits
        used.update(axes)
        if not axes:
            entries.append(None)
        elif len(axes) == 1:
            entries.append(axes[0])
        else:
            entries.append(axes)
    return P(*entries)


def shard(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = sanitize_spec(resolve(axes), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding (Megatron-style tensor parallelism over 'model').
#
# Keyed on the leaf's dict key; the tuple gives logical axes for the
# TRAILING dims — leading dims (the stacked-layers 'periods' axis) are
# replicated. 3D entries are the MoE per-expert stacks: experts over
# 'model', per-expert matrices replicated (the expert einsum then carries
# (shards@data, E@model) — see models/layers/moe.py).
# ---------------------------------------------------------------------------

PARAM_RULES: dict[str, tuple] = {
    # attention: fan-out sharded on q/k/v, fan-in on the output proj
    "wq": (None, "heads"),
    "wk": (None, "kv_heads"),
    "wv": (None, "kv_heads"),
    "wo": ("heads", None),
    # dense mlp
    "w_up": (None, "mlp"),
    "w_gate": (None, "mlp"),
    "w_down": ("mlp", None),
    # xlstm projections
    "w_q": (None, "ssm_inner"),
    "w_k": (None, "ssm_inner"),
    "w_v": (None, "ssm_inner"),
    "w_out": ("ssm_inner", None),
    # mamba-style ssm
    "in_proj": (None, "ssm_inner"),
    "out_proj": ("ssm_inner", None),
    # embedding / head
    "table": ("vocab", None),
    "lm_head": (None, "vocab"),
    "router": (None, None),
}

_MOE_RULES: dict[str, tuple] = {
    "w_up": ("experts", None, "mlp"),
    "w_gate": ("experts", None, "mlp"),
    "w_down": ("experts", "mlp", None),
}


def _leaf_rule(path, leaf) -> tuple:
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    if name == "w" and parent in PARAM_RULES:   # {"lm_head": {"w": ...}}
        name = parent
    rule = PARAM_RULES.get(name)
    if rule is None:
        return (None,) * leaf.ndim
    moe = _MOE_RULES.get(name)
    # Stacked-layer leaves carry a leading 'periods' dim; MoE leaves carry
    # a leading experts dim on top of the 2D rule — disambiguate by ndim.
    if moe is not None and leaf.ndim >= 3 and leaf.ndim - len(moe) in (0, 1):
        rule = moe
    if len(rule) > leaf.ndim:
        return (None,) * leaf.ndim
    return (None,) * (leaf.ndim - len(rule)) + tuple(rule)


def spec_for_params(params: Pytree) -> Pytree:
    """PartitionSpec tree for a parameter pytree under the installed mesh.

    Call inside ``use_mesh``; unknown leaves replicate. Specs are
    sanitized against leaf shapes, so odd dims degrade gracefully.
    """
    mesh = current_mesh()

    def one(path, leaf):
        spec = resolve(_leaf_rule(path, leaf))
        if mesh is not None:
            spec = sanitize_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)
