"""Training driver.

Two modes:
  * ``--smoke`` (CPU): reduced config, real optimization for N steps with
    checkpointing — the end-to-end path tests/examples use.
  * production (TPU pods): full config on the production mesh; the same
    code path the dry-run lowers, with real data wiring left to the
    deployment (synthetic stream by default so the binary is self-
    contained).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --smoke --steps 50
"""

from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import DataConfig, SyntheticDataset
from repro.dist import sharding as shd
from repro.launch import mesh as meshlib
from repro.optim import adamw_init
from repro.train.step import (TrainStepConfig, init_params, make_train_step,
                              shardings_for)
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.INFO,
        format="%(asctime)s %(name)s %(message)s")

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.is_encdec:
        dcfg_extra = {"frontend_tokens": cfg.frontend_tokens or 16}
    elif cfg.frontend_tokens:
        dcfg_extra = {"frontend_tokens": cfg.frontend_tokens}
    else:
        dcfg_extra = {}
    dcfg = DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed, **dcfg_extra)

    tcfg = TrainStepConfig(
        microbatches=args.microbatches, peak_lr=args.peak_lr,
        total_steps=args.steps)
    step_fn = make_train_step(cfg, tcfg)

    key = jax.random.PRNGKey(args.seed)
    if args.smoke:
        mesh = None
        params = init_params(key, cfg)
        opt = adamw_init(params)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        mesh = meshlib.make_production_mesh(multi_pod=args.multi_pod)
        with shd.use_mesh(mesh):
            params_s, opt_s = jax.eval_shape(
                lambda k: (lambda p: (p, None))(init_params(k, cfg)), key)
            batch_like = dict(SyntheticDataset(dcfg).batch_at(0))
            in_sh, out_sh = shardings_for(mesh, params_s, None, batch_like)
            params = jax.jit(
                lambda k: init_params(k, cfg), out_shardings=in_sh[0])(key)
            opt = adamw_init(params)
            jitted = jax.jit(step_fn, in_shardings=in_sh,
                             out_shardings=out_sh, donate_argnums=(0, 1))

    ds = SyntheticDataset(dcfg, mesh=mesh)
    trainer = Trainer(jitted, ds, TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir, log_every=args.log_every))
    start, params, opt = trainer.maybe_restore(params, opt)
    params, opt = trainer.run(params, opt, start_step=start)
    print(f"done: {len(trainer.history)} steps, "
          f"final loss {trainer.history[-1]['loss']:.4f}"
          if trainer.history else "done (no steps run)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
