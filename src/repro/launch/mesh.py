"""Production mesh builders (functions — importing never touches devices).

Target: TPU v5e. Single pod = 16×16 = 256 chips (data, model); multi-pod
= 2 pods = 512 chips with a leading 'pod' axis. DCN links the pods; ICI
links chips in-pod — the axis order (pod outermost) matches GSPMD's
expectation that the slowest collective axis is outermost.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small debug mesh over however many devices exist (tests)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"asked for {data}x{model} but only {n} devices")
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants (v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-direction)
ICI_LINKS = 4                   # 2D torus in-pod: 4 links per chip
DCN_BW = 25e9                   # bytes/s per host NIC class (pod axis)
