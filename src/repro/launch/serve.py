"""Serving driver: continuous-batching engine over a model checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
        --smoke --requests 8 --max-new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.serve import Engine, EngineConfig, Request
from repro.train.step import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-p", type=float, default=0.95)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.is_encdec:
        raise SystemExit(
            "enc-dec serving goes through repro.serve.steps directly "
            "(needs an encoder memory); see examples/serve_batch.py")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    eng = Engine(params, cfg, EngineConfig(
        max_slots=args.slots, max_len=args.max_len,
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
        top_p=args.top_p, eos_id=-1, seed=args.seed))

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        prompt = rng.integers(
            2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt))
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    ntok = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.output[:10]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
