"""Serving driver: continuous-batching engine over a model checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
        --smoke --requests 8 --max-new-tokens 16

Hardening knobs ride along: ``--fault-seed`` runs the request mix under
the deterministic chaos injector (transient errors / NaN logits /
stalls), ``--deadline-ticks``/``--max-waiting`` exercise admission
control and TTLs, and the run always ends with the ``EngineStats``
health line the chaos tests assert on.

Observability knobs: ``--trace out.json`` captures the run as a Chrome
``trace_event`` file (open in ui.perfetto.dev — tick/prefill/decode
spans, request lifecycle instants, policy decisions); ``--stats-json``
prints one machine-parsable line with the full ``EngineStats.as_dict()``
plus the metrics-registry snapshot (tick-latency histogram included).
"""

from __future__ import annotations

import argparse
import json
import time
import warnings

import jax
import numpy as np

from repro import configs
from repro.obs import Registry, trace
from repro.serve import Engine, EngineConfig, FaultInjector, Request
from repro.train.step import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-p", type=float, default=0.95)
    ap.add_argument("--seed", type=int, default=0)
    # -- hardening / chaos knobs ---------------------------------------
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="run under the deterministic fault injector")
    ap.add_argument("--fault-error-rate", type=float, default=0.05)
    ap.add_argument("--fault-nan-rate", type=float, default=0.05)
    ap.add_argument("--fault-stall-rate", type=float, default=0.02)
    ap.add_argument("--max-waiting", type=int, default=None)
    ap.add_argument("--admission-policy", choices=["reject", "block"],
                    default="reject")
    ap.add_argument("--deadline-ticks", type=int, default=None)
    ap.add_argument("--no-bucket-prompts", action="store_true")
    # -- paged KV cache knobs ------------------------------------------
    ap.add_argument("--cache-layout",
                    choices=["contiguous", "paged", "auto"],
                    default="contiguous")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool size (default: worst case + null)")
    ap.add_argument("--share-prefixes", action="store_true",
                    help="COW prefix sharing across requests (paged only)")
    ap.add_argument("--system-prompt-len", type=int, default=0,
                    help="tokens of common prefix prepended to every "
                         "prompt (makes --share-prefixes observable)")
    ap.add_argument("--attn-impl", choices=["flash"], default=None,
                    help="prefill attention route (default: dense)")
    ap.add_argument("--attn-schedule",
                    choices=["auto", "carry", "decoupled"], default="auto")
    # -- observability knobs -------------------------------------------
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export the run as Chrome trace_event JSON")
    ap.add_argument("--stats-json", action="store_true",
                    help="print stats as one machine-parsable JSON line")
    args = ap.parse_args(argv)

    if args.trace is not None:
        trace.enable()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.is_encdec:
        raise SystemExit(
            "enc-dec serving goes through repro.serve.steps directly "
            "(needs an encoder memory); see examples/serve_batch.py")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    injector = None
    if args.fault_seed is not None:
        injector = FaultInjector.from_seed(
            args.fault_seed, ticks=4 * args.requests * args.max_new_tokens,
            p_error=args.fault_error_rate, p_nan=args.fault_nan_rate,
            p_stall=args.fault_stall_rate)

    metrics = Registry()
    eng = Engine(params, cfg, EngineConfig(
        max_slots=args.slots, max_len=args.max_len,
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
        top_p=args.top_p, eos_id=-1, seed=args.seed,
        max_waiting=args.max_waiting,
        admission_policy=args.admission_policy,
        deadline_ticks=args.deadline_ticks,
        bucket_prompts=not args.no_bucket_prompts,
        attn_impl=args.attn_impl, attn_schedule=args.attn_schedule,
        cache_layout=args.cache_layout, page_size=args.page_size,
        num_pages=args.num_pages, share_prefixes=args.share_prefixes),
        injector=injector, metrics=metrics)

    rng = np.random.default_rng(args.seed)
    system = rng.integers(2, cfg.vocab_size,
                          size=args.system_prompt_len).astype(np.int32)
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        warnings.simplefilter("default")
        for rid in range(args.requests):
            prompt = np.concatenate([system, rng.integers(
                2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)])
            eng.submit(Request(rid=rid, prompt=prompt))
        done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    eng.audit()
    ntok = sum(len(r.output) for r in done)
    ok = sum(r.finish_reason in ("eos", "length_budget") for r in done)
    print(f"served {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s, goodput {ok}/{len(done)})")
    # The human line and the machine line read the SAME counters: the
    # summary string from the dataclass, the JSON from its registry
    # mirror (EngineStats.attach keeps them write-through-identical).
    print(f"stats: {eng.stats.summary()}")
    if args.stats_json:
        print("stats-json: " + json.dumps(
            {"stats": eng.stats.as_dict(), "metrics": metrics.snapshot()},
            sort_keys=True))
    if injector is not None:
        print(f"faults fired: error={injector.fired_count('error')} "
              f"nan={injector.fired_count('nan')} "
              f"stall={injector.fired_count('stall')}")
    for r in done[:3]:
        print(f"  req {r.rid}: [{r.finish_reason}] {r.output[:10]}...")
    if args.trace is not None:
        n = len(trace.export(args.trace)["traceEvents"])
        print(f"trace: {n} events -> {args.trace}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
