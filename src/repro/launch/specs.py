"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs(arch, shape)`` returns the *model inputs* (batch / request
tensors); ``state_specs`` the param/optimizer trees via ``jax.eval_shape``
(no allocation — exact shapes for 235B configs on a CPU container);
``step_bundle`` assembles everything a dry-run lower() needs for the
cell's step kind (train / prefill / decode).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.serve.steps import init_cache_for

Pytree = Any

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch: str
    shape: str
    kind: str                  # train | prefill | decode
    cfg: ModelConfig
    inputs: dict               # name -> ShapeDtypeStruct (model inputs)


def _token_split(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    """(frontend positions, text positions) summing to seq_len."""
    if cfg.is_encdec:
        f = min(cfg.frontend_tokens, seq_len // 2)
        return f, seq_len - f
    if cfg.frontend_tokens:
        f = min(cfg.frontend_tokens, seq_len // 2)
        return f, seq_len - f
    return 0, seq_len


def input_specs(arch: str, shape: str,
                cfg: Optional[ModelConfig] = None) -> dict:
    """Model-input ShapeDtypeStructs for one dry-run cell."""
    cfg = cfg or configs.get_config(arch)
    sp: ShapeSpec = SHAPES[shape]
    B, L = sp.global_batch, sp.seq_len
    F, T = _token_split(cfg, L)
    fdim = 1024  # precomputed patch/frame embedding width (stub frontends)

    if sp.step == "train":
        batch = {
            "tokens": S((B, T), jnp.int32),
            "labels": S((B, T), jnp.int32),
            "mask": S((B, T), jnp.float32),
        }
        if F:
            batch["embeds"] = S((B, F, fdim), jnp.float32)
        return {"batch": batch}

    if sp.step == "prefill":
        out = {"tokens": S((B, T), jnp.int32)}
        if F:
            out["embeds"] = S((B, F, fdim), jnp.float32)
        return out

    # decode: ONE new token against a cache of L slots.
    out = {
        "tokens": S((B, 1), jnp.int32),
        "cache": jax.eval_shape(
            lambda: init_cache_for(cfg, B, L)),
        "cache_len": S((), jnp.int32),
    }
    if cfg.is_encdec:
        # Fixed encoder memory (≈3 min of audio) for the decode shapes.
        out["memory"] = S((B, min(cfg.frontend_tokens, 4096), cfg.d_model),
                          jnp.float32)
    return out


def state_specs(cfg: ModelConfig) -> tuple[Pytree, Pytree]:
    """(params, opt_state) ShapeDtypeStruct trees — no allocation."""
    key = S((2,), jnp.uint32)

    def init(k):
        if cfg.is_encdec:
            return encdec_mod.init_encdec(k, cfg)
        return lm_mod.init_lm(k, cfg)

    params = jax.eval_shape(init, key)
    opt = jax.eval_shape(adamw.adamw_init, params)
    return params, opt


def batch_dims(arch: str, shape: str) -> tuple[int, int]:
    sp = SHAPES[shape]
    return sp.global_batch, sp.seq_len
