import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices build the production meshes; ``jit(...).lower(...)
.compile()`` runs the full GSPMD partitioner; ``memory_analysis()`` proves
the cell fits per-device HBM; ``cost_analysis()`` + the optimized-HLO
collective parse feed the roofline table (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/

Exit code 0 = every requested cell compiled.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, cells
from repro.dist import sharding as shd
from repro.launch import mesh as meshlib
from repro.launch.specs import input_specs, state_specs
from repro.roofline.analyze import analyze_compiled
from repro.serve.steps import (cache_shardings, make_prefill_fn,
                               make_serve_step)
from repro.train.step import TrainStepConfig, make_train_step, shardings_for


def make_mesh(name: str):
    if name == "single":
        devices = jax.devices()[:256]
        return jax.make_mesh((16, 16), ("data", "model"), devices=devices)
    if name == "multi":
        return meshlib.make_production_mesh(multi_pod=True)
    raise ValueError(name)


def rules_for_cell(cfg, shape: str, kind: str):
    """Logical-axis rule overrides per cell (see DESIGN.md §5)."""
    rules = {}
    if kind != "train":
        if SHAPES[shape].global_batch == 1:
            # long_500k: batch of 1 cannot split — shard the sequence over
            # EVERY axis instead (the KV/state sequence dim).
            rules["batch"] = ()
            rules["seq_shard"] = ("data", "model")
    return rules


@dataclasses.dataclass
class PerfKnobs:
    override_layers: int = 0   # >0: reduce depth for cost extrapolation

    """Hillclimb knobs, settable from the CLI (EXPERIMENTS.md §Perf)."""
    microbatches: int = 1
    remat: bool = True
    attn_impl: "str | None" = None
    loss_chunk: int = 512
    donate: bool = True
    # Full layer unroll so cost_analysis sees every layer (XLA counts a
    # while-loop body once). Default ON for analysis; launch/train.py uses
    # the scanned (compact-HLO) form at runtime.
    unroll: bool = True


def lower_cell(arch: str, shape: str, mesh_name: str,
               knobs: PerfKnobs = PerfKnobs()):
    """Returns (lowered, compiled, report) for one cell."""
    cfg = configs.get_config(arch)
    if knobs.override_layers:
        pat = len(cfg.layer_pattern)
        n = max(pat, knobs.override_layers - knobs.override_layers % pat)
        cfg = dataclasses.replace(cfg, num_layers=n)
    sp = SHAPES[shape]
    kind = sp.step
    mesh = make_mesh(mesh_name)
    chips = mesh.devices.size
    rules = rules_for_cell(cfg, shape, kind)
    specs = input_specs(arch, shape, cfg)
    params_s, opt_s = state_specs(cfg)

    with shd.use_mesh(mesh, rules):
        if kind == "train":
            tcfg = TrainStepConfig(
                microbatches=knobs.microbatches, remat=knobs.remat,
                attn_impl=knobs.attn_impl, loss_chunk=knobs.loss_chunk,
                unroll_layers=knobs.unroll)
            step = make_train_step(cfg, tcfg)
            in_sh, out_sh = shardings_for(
                mesh, params_s, opt_s, specs["batch"])
            jitted = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=(0, 1) if knobs.donate else ())
            lowered = jitted.lower(
                params_s, opt_s, specs["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
        elif kind == "prefill":
            fn = make_prefill_fn(cfg, max_len=sp.seq_len,
                                 unroll=knobs.unroll)
            pspec = shd.spec_for_params(params_s)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
            bsh = NamedSharding(mesh, shd.resolve(["batch", None]))
            args = [params_s, specs["tokens"]]
            in_sh = [psh, bsh]
            if "embeds" in specs:
                args.append(specs["embeds"])
                in_sh.append(NamedSharding(
                    mesh, shd.resolve(["batch", None, None])))
            jitted = jax.jit(fn, in_shardings=tuple(in_sh))
            lowered = jitted.lower(*args)
        else:  # decode
            fn = make_serve_step(cfg, unroll=knobs.unroll)
            pspec = shd.spec_for_params(params_s)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
            csh = cache_shardings(specs["cache"], mesh)
            tsh = NamedSharding(mesh, shd.resolve(["batch", None]))
            args = [params_s, specs["tokens"], specs["cache"],
                    specs["cache_len"]]
            in_sh = [psh, tsh, csh, NamedSharding(mesh, P())]
            if "memory" in specs:
                args.append(specs["memory"])
                in_sh.append(NamedSharding(
                    mesh, shd.resolve(["batch", None, None])))
            jitted = jax.jit(
                fn, in_shardings=tuple(in_sh),
                donate_argnums=(2,) if knobs.donate else ())
            lowered = jitted.lower(*args)

        compiled = lowered.compile()

    report = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name, chips=chips,
        cfg=cfg, batch=sp.global_batch, seq=sp.seq_len, kind=kind)
    return lowered, compiled, report


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             knobs: PerfKnobs = PerfKnobs(), tag: str = "") -> dict:
    t0 = time.time()
    try:
        _, compiled, report = lower_cell(arch, shape, mesh_name, knobs)
        mem = compiled.memory_analysis()
        print(f"[{arch} × {shape} × {mesh_name}] COMPILED "
              f"({time.time() - t0:.1f}s)")
        print("  memory_analysis:", mem)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print(f"  flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  collectives={report.collective_bytes}")
        print(f"  terms: compute={report.compute_s:.4f}s "
              f"memory={report.memory_s:.4f}s "
              f"collective={report.collective_s:.4f}s "
              f"dcn={report.dcn_s:.4f}s -> dominant={report.dominant}")
        rec = dataclasses.asdict(report)
        rec.update(status="ok", compile_s=time.time() - t0,
                   memory_analysis=str(mem), knobs=dataclasses.asdict(knobs))
    except Exception as e:  # noqa: BLE001 — cell failures are data
        traceback.print_exc()
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "fail", "error": f"{type(e).__name__}: {e}",
               "compile_s": time.time() - t0,
               "knobs": dataclasses.asdict(knobs)}
        print(f"[{arch} × {shape} × {mesh_name}] FAILED: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{arch}__{shape}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch × shape × mesh) cell")
    ap.add_argument("--meshes", default="single,multi",
                    help="comma list of meshes for --all sweeps")
    ap.add_argument("--out", default="experiments/dryrun")
    # perf knobs
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--loss-chunk", type=int, default=512)
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--no-unroll", action="store_true")
    ap.add_argument("--override-layers", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    knobs = PerfKnobs(
        microbatches=args.microbatches, remat=not args.no_remat,
        attn_impl=args.attn_impl, loss_chunk=args.loss_chunk,
        donate=not args.no_donate, unroll=not args.no_unroll,
        override_layers=args.override_layers)

    todo = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in cells(arch):
                for mesh_name in args.meshes.split(","):
                    todo.append((arch, shape, mesh_name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo.append((args.arch, args.shape, args.mesh))

    failures = 0
    for arch, shape, mesh_name in todo:
        rec = run_cell(arch, shape, mesh_name, args.out, knobs, args.tag)
        failures += rec["status"] != "ok"
    print(f"\n{len(todo) - failures}/{len(todo)} cells compiled")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
