"""Metrics registry: counters, gauges, streaming histograms.

The second observability surface (ISSUE 7): a process-wide registry the
serve engine, trainer, and benchmark harness all write into, so health
state is readable from ONE place — ``Registry.snapshot()`` — instead of
scattered ad-hoc counters. ``serve.stats.EngineStats`` mirrors its
counters here when attached (``EngineStats.attach``), which is what the
chaos-wall parity test asserts.

Histograms are STREAMING: log-spaced buckets (growth ``2**(1/8)`` ≈ 9%
per bucket) accumulate counts only, so p50/p99 come from bucket
interpolation at O(1) memory per series — no sample storage, bounded
error (one bucket width, ~9% relative; asserted against numpy
percentiles in ``tests/test_obs.py``).

Everything is thread-safe (one lock per registry; instruments mutate
only under it). A module-level default registry mirrors the tracer's
singleton pattern; isolated consumers (tests, parallel engines) build
their own ``Registry``.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

# Histogram geometry: log-spaced buckets covering ~[1e-9, 1e12) with
# 2**(1/8) growth — 9% relative quantile error, ~560 buckets worst case
# (allocated lazily per series as a dict).
_GROWTH = 2.0 ** 0.125
_LOG_GROWTH = math.log(_GROWTH)


class Counter:
    """Monotonic counter."""

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Streaming log-bucket histogram with interpolated quantiles."""

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}   # bucket index -> count
        self._underflow = 0                  # values <= 0

    @staticmethod
    def _index(v: float) -> int:
        return int(math.floor(math.log(v) / _LOG_GROWTH))

    def record(self, v: float) -> None:
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if v <= 0.0:
            self._underflow += 1
            return
        i = self._index(v)
        self._buckets[i] = self._buckets.get(i, 0) + 1

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]); NaN when empty."""
        if self.count == 0:
            return math.nan
        rank = q / 100.0 * (self.count - 1)
        if rank <= self._underflow - 1:
            return min(self.min, 0.0)
        seen = self._underflow
        for i in sorted(self._buckets):
            n = self._buckets[i]
            if seen + n > rank:
                lo, hi = _GROWTH ** i, _GROWTH ** (i + 1)
                frac = (rank - seen + 1) / n  # position inside the bucket
                v = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                return float(min(max(v, self.min), self.max))
            seen += n
        return float(self.max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def summary(self) -> dict:
        return {
            "count": self.count, "sum": self.sum,
            "min": self.min if self.count else math.nan,
            "max": self.max if self.count else math.nan,
            "mean": self.mean,
            "p50": self.percentile(50.0), "p99": self.percentile(99.0),
        }


class Registry:
    """Named instruments, created on first use; one lock, snapshot-able."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            return self._histograms.setdefault(name, Histogram())

    def names(self) -> List[str]:
        with self._lock:
            return sorted(set(self._counters) | set(self._gauges)
                          | set(self._histograms))

    def snapshot(self) -> dict:
        """One JSON-safe dict of everything: the operator dashboard /
        ``--stats-json`` surface."""
        with self._lock:
            return {
                "counters": {k: c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {k: g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {k: h.summary()
                               for k, h in sorted(self._histograms.items())},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


_default = Registry()


def default_registry() -> Registry:
    return _default
