"""Host-side tracer: spans, instant events, counters — Perfetto-openable.

The observability layer's first surface (ISSUE 7): a lightweight tracer
every layer of the stack can call unconditionally. The design contract
is that tracing must be FREE when disabled and INVISIBLE when enabled —
it never touches device values, never forces a sync, and never changes
control flow, so serve outputs are bitwise identical with tracing on or
off (asserted by ``tests/test_obs.py``).

  * ``span(name, **args)`` — a context manager recording one Chrome
    ``"X"`` (complete) event with microsecond ``ts``/``dur``. Nesting is
    reconstructed by the viewer from containment per thread track.
  * ``instant(name, **args)`` — a ``"i"`` event: request lifecycle
    transitions, policy decisions, kernel launches.
  * ``counter(name, **series)`` — a ``"C"`` event: queue depth, tokens.

Events land in a thread-safe ring buffer (bounded memory: a long serve
run keeps the most recent ``capacity`` events). ``export()`` writes the
Chrome ``trace_event`` JSON object format — load the file in
``ui.perfetto.dev`` or ``chrome://tracing``.

The module-level singleton is DISABLED by default: ``span`` hands back a
shared no-op context manager and ``instant``/``counter`` return before
touching the clock, so instrumented hot paths (engine ticks, policy
resolution inside a jit trace) pay one attribute check. ``enable()``
swaps in a live ``Tracer``; library code uses the module-level functions
and never holds a tracer reference across an enable/disable.

Note on jitted callers: instrumentation that runs inside ``jax.jit``
tracing (kernel-launch events, policy decisions reached from a jitted
wrapper) fires once per COMPILATION, not per execution — by design: it
records what was launched/decided, with zero runtime overhead.
"""

from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Dict, List, Optional

# Chrome trace_event phases we emit.
_PH_COMPLETE = "X"
_PH_INSTANT = "i"
_PH_COUNTER = "C"


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


def _jsonable(v: Any) -> Any:
    """Coerce event args to JSON-safe values without importing jax."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


class _NoopSpan:
    """Shared, allocation-free context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = _now_us()
        return self

    def __exit__(self, *exc):
        t1 = _now_us()
        self._tracer._record({
            "name": self._name, "ph": _PH_COMPLETE, "ts": self._t0,
            "dur": t1 - self._t0, "pid": 0,
            "tid": threading.get_ident() % 1_000_000,
            "args": _jsonable(self._args),
        })
        return False


class Tracer:
    """Thread-safe ring-buffered event collector (see module doc)."""

    def __init__(self, capacity: int = 1 << 16):
        self.enabled = True
        self._events: "collections.deque[dict]" = collections.deque(
            maxlen=capacity)
        self._lock = threading.Lock()

    # -- recording ------------------------------------------------------
    def _record(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, **args):
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        self._record({
            "name": name, "ph": _PH_INSTANT, "ts": _now_us(), "pid": 0,
            "tid": threading.get_ident() % 1_000_000, "s": "t",
            "args": _jsonable(args),
        })

    def counter(self, name: str, **series) -> None:
        if not self.enabled:
            return
        self._record({
            "name": name, "ph": _PH_COUNTER, "ts": _now_us(), "pid": 0,
            "tid": threading.get_ident() % 1_000_000,
            "args": _jsonable(series),
        })

    # -- inspection / export --------------------------------------------
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def export(self, path: Optional[str] = None) -> dict:
        """Chrome trace_event JSON (object format). Writes ``path`` when
        given; always returns the document."""
        doc = {"traceEvents": self.events(), "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


class _DisabledTracer(Tracer):
    """The default singleton: every entry point is a guaranteed no-op."""

    def __init__(self):
        super().__init__(capacity=1)
        self.enabled = False

    def _record(self, ev: dict) -> None:  # pragma: no cover — guarded
        pass


_DISABLED = _DisabledTracer()
_tracer: Tracer = _DISABLED
_state_lock = threading.Lock()


def get() -> Tracer:
    """The active tracer (the disabled singleton unless ``enable``d)."""
    return _tracer


def enabled() -> bool:
    return _tracer.enabled


def enable(capacity: int = 1 << 16) -> Tracer:
    """Install (and return) a live tracer; idempotent per process state."""
    global _tracer
    with _state_lock:
        if not _tracer.enabled:
            _tracer = Tracer(capacity=capacity)
        return _tracer


def disable() -> None:
    """Swap the disabled singleton back in (recorded events are dropped)."""
    global _tracer
    with _state_lock:
        _tracer = _DISABLED


# Module-level conveniences — what instrumented code actually calls.
def span(name: str, **args):
    return _tracer.span(name, **args)


def instant(name: str, **args) -> None:
    _tracer.instant(name, **args)


def counter(name: str, **series) -> None:
    _tracer.counter(name, **series)


def export(path: Optional[str] = None) -> dict:
    return _tracer.export(path)
