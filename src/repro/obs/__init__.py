"""Observability: tracing, metrics, and the bench trajectory's hooks.

Three surfaces (ISSUE 7 — "make the stack measure itself"):

  * ``obs.trace`` — host-side spans / instant events / counter tracks
    with Chrome ``trace_event`` export (open in ``ui.perfetto.dev``).
    Disabled by default; ``trace.enable()`` turns a serve run or
    benchmark into a timeline. See README "Observability".
  * ``obs.metrics`` — process-wide counters/gauges/streaming histograms;
    ``serve.stats.EngineStats`` mirrors into it when attached.
  * the bench trajectory — ``benchmarks/run.py --json`` +
    ``tools/bench_gate.py`` persist and gate ``BENCH_*.json`` per PR
    (they consume ``obs.metrics`` for the hlo-counter block).
"""

from repro.obs import trace
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               default_registry)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "default_registry",
    "trace",
]
