"""Token sampling. Top-p nucleus filtering is a prefix-sum application:
the nucleus is {tokens whose sorted-prob cumulative sum < p} — computed
with the scan substrate (paper §1's 'parallel filtering' use case)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import scan as scanlib


def finite_rows(logits: jax.Array) -> jax.Array:
    """(B, V) -> (B,) bool: rows safe to sample from. The engine's
    degradation ladder gates on this before any sampling touches the
    logits — NaN rows reaching ``jax.random.categorical`` would emit
    valid-looking but meaningless token ids."""
    return jnp.isfinite(logits).all(axis=-1)


def sample_logits(
    key: jax.Array,
    logits: jax.Array,                  # (B, V) f32
    temperature: float = 1.0,
    top_p: float = 1.0,
) -> jax.Array:
    """Sample token ids (B,) with temperature + nucleus (top-p).

    NaN logits are mapped to -inf so an isolated poisoned entry cannot
    silently win the argmax or leak probability mass into the nucleus
    (all-NaN rows are the engine ladder's job, see :func:`finite_rows`).
    """
    logits = jnp.where(jnp.isnan(logits), -jnp.inf, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        # Exclusive cumulative probability mass before each rank: the
        # nucleus keeps ranks whose preceding mass is < top_p.
        cum = scanlib.cumsum(probs, axis=-1, exclusive=True,
                             algorithm="blocked")
        cutoff_logit = jnp.min(
            jnp.where(cum < top_p, sorted_logits, jnp.inf), axis=-1,
            keepdims=True)
        logits = jnp.where(logits < cutoff_logit, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
