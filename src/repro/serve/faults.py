"""Seeded, deterministic fault injection for the serve engine.

The injector wraps the engine's jitted ``serve_step``/``prefill``
callables and fires synthetic failures on a schedule:

  * ``error`` — raise :class:`InjectedFault` *before* the real call (so
    device buffers are never consumed — the shape a dispatch failure or
    preempted host takes from the engine's point of view);
  * ``nan``   — run the real call, then poison the returned logits with
    NaN (all rows, or just the targeted request's row) — the shape a
    numeric blowup takes;
  * ``stall`` — sleep ``stall_s`` before the real call — the straggler
    shape.

Targeting is by engine tick (``tick`` = first eligible tick), by request
(``rid`` — fires only while that request participates in the call: the
*poison request* the engine's bisection quarantine must isolate), and by
op (``step`` | ``prefill`` | ``any``). ``count`` bounds total firings
(``None`` = unlimited — poison semantics); a spec with ``count=1`` is a
transient fault the engine's retry clears.

Everything is deterministic: explicit spec lists, or
:meth:`FaultInjector.from_seed` which expands a numpy ``default_rng``
stream into a spec list — the chaos wall replays the same schedule into
fault-free and faulted runs and asserts bitwise-identical outputs for
undisturbed requests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


class InjectedFault(RuntimeError):
    """Synthetic failure raised by the injector (never by real code)."""


@dataclasses.dataclass
class FaultSpec:
    kind: str                    # "error" | "nan" | "stall"
    op: str = "step"             # "step" | "prefill" | "any"
    tick: Optional[int] = None   # first engine tick eligible (None = any)
    rid: Optional[int] = None    # fire only while this rid participates
    count: Optional[int] = 1     # firing budget (None = unlimited)
    stall_s: float = 0.0         # sleep for "stall" faults

    def __post_init__(self):
        if self.kind not in ("error", "nan", "stall"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.op not in ("step", "prefill", "any"):
            raise ValueError(f"unknown fault op {self.op!r}")


@dataclasses.dataclass
class StepContext:
    """What the engine tells the injector about the call it is making."""

    tick: int
    rids: Tuple[int, ...]
    op: str                                  # "step" | "prefill"
    rows: Optional[Dict[int, int]] = None    # rid -> batch row (step calls)


class FaultInjector:
    def __init__(self, specs: Sequence[FaultSpec]):
        self.specs = list(specs)
        self._fired = [0] * len(self.specs)
        #: (tick, op, rids, kind, spec_index) per firing — audit trail.
        self.log: list[tuple] = []
        self._ctx: Optional[StepContext] = None
        self._calls = 0

    # -- schedule construction -----------------------------------------
    @classmethod
    def from_seed(
        cls,
        seed: int,
        ticks: int = 64,
        p_error: float = 0.05,
        p_nan: float = 0.05,
        p_stall: float = 0.0,
        stall_s: float = 0.005,
        poison_rids: Sequence[int] = (),
    ) -> "FaultInjector":
        """Deterministic random plan: at most one transient fault per
        tick, plus persistent poison specs for ``poison_rids``."""
        rng = np.random.default_rng(seed)
        specs: list[FaultSpec] = []
        for t in range(ticks):
            r = float(rng.random())
            if r < p_error:
                specs.append(FaultSpec("error", op="any", tick=t, count=1))
            elif r < p_error + p_nan:
                specs.append(FaultSpec("nan", op="step", tick=t, count=1))
            elif r < p_error + p_nan + p_stall:
                specs.append(FaultSpec("stall", op="any", tick=t, count=1,
                                       stall_s=stall_s))
        for rid in poison_rids:
            specs.append(FaultSpec("error", op="step", rid=int(rid),
                                   count=None))
        return cls(specs)

    # -- engine protocol ------------------------------------------------
    def begin(self, ctx: StepContext) -> None:
        """Set the context for the next wrapped call (engine-side)."""
        self._ctx = ctx

    def fired_count(self, kind: Optional[str] = None) -> int:
        return sum(
            n for n, s in zip(self._fired, self.specs)
            if kind is None or s.kind == kind
        )

    # -- matching -------------------------------------------------------
    def _take(self, ctx: StepContext, kind: str) -> list[FaultSpec]:
        hits = []
        for i, spec in enumerate(self.specs):
            if spec.kind != kind:
                continue
            if spec.op != "any" and spec.op != ctx.op:
                continue
            if spec.tick is not None and ctx.tick < spec.tick:
                continue
            if spec.count is not None and self._fired[i] >= spec.count:
                continue
            if spec.rid is not None and spec.rid not in ctx.rids:
                continue
            self._fired[i] += 1
            self.log.append((ctx.tick, ctx.op, ctx.rids, spec.kind, i))
            hits.append(spec)
        return hits

    def _resolve_ctx(self, op: str) -> StepContext:
        ctx = self._ctx
        if ctx is None:  # standalone use: count wrapped calls as ticks
            ctx = StepContext(tick=self._calls, rids=(), op=op)
        self._ctx = None
        self._calls += 1
        return ctx

    def _pre(self, ctx: StepContext) -> None:
        for spec in self._take(ctx, "stall"):
            if spec.stall_s > 0:
                time.sleep(spec.stall_s)
        errors = self._take(ctx, "error")
        if errors:
            raise InjectedFault(
                f"injected {ctx.op} error at tick {ctx.tick} "
                f"(rids={ctx.rids})")

    def _post(self, ctx: StepContext, logits):
        for spec in self._take(ctx, "nan"):
            if spec.rid is not None and ctx.rows and spec.rid in ctx.rows:
                row = ctx.rows[spec.rid]
                logits = logits.at[row].set(jnp.nan)
            else:
                logits = jnp.full_like(logits, jnp.nan)
        return logits

    # -- wrappers -------------------------------------------------------
    def wrap_step(self, fn):
        """Wrap ``(params, tokens, cache, cache_len) -> (logits, cache)``."""

        def wrapped(params, tokens, cache, cache_len):
            ctx = self._resolve_ctx("step")
            self._pre(ctx)
            logits, new_cache = fn(params, tokens, cache, cache_len)
            return self._post(ctx, logits), new_cache

        return wrapped

    def wrap_prefill(self, fn):
        """Wrap ``(params, tokens, *rest) -> (logits, cache, ...)``."""

        def wrapped(params, tokens, *rest):
            ctx = self._resolve_ctx("prefill")
            self._pre(ctx)
            out = fn(params, tokens, *rest)
            return (self._post(ctx, out[0]),) + tuple(out[1:])

        return wrapped
