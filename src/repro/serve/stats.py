"""Engine health surface: counters for the serve request lifecycle.

``EngineStats`` is the single place the hardened engine records what
happened to traffic — admissions, rejections, finishes by reason,
step retries, bisection probes, quarantines, numeric degradations,
skipped (rolled-back) ticks, prefill compiles — so operators (and the
chaos tests) can assert liveness invariants without scraping logs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

#: The request terminal states. Every submitted request ends with exactly
#: one of these on ``Request.finish_reason`` (the chaos wall's invariant).
FINISH_REASONS = (
    "eos",            # sampled the eos token
    "length_budget",  # generated its max_new_tokens budget
    "cache_full",     # ran out of KV-cache slots before its budget (warned)
    "deadline",       # tick TTL expired (per-request or run_to_completion)
    "rejected",       # failed admission (cannot fit / queue full)
    "error",          # quarantined by step-failure recovery, or prefill died
    "cancelled",      # host-side cancel(rid)
)


@dataclasses.dataclass
class EngineStats:
    """Monotonic counters plus current queue gauges."""

    # -- traffic -------------------------------------------------------
    ticks: int = 0                 # engine steps attempted
    submitted: int = 0             # submit() calls (incl. rejected)
    admitted: int = 0              # prefills attempted into a slot
    tokens_generated: int = 0      # sampled tokens appended to outputs
    finished: Dict[str, int] = dataclasses.field(default_factory=dict)

    # -- queue ---------------------------------------------------------
    queue_depth: int = 0           # waiting requests right now
    peak_queue_depth: int = 0

    # -- failure recovery ----------------------------------------------
    step_retries: int = 0          # failed decode-step attempts retried
    prefill_retries: int = 0       # failed prefill attempts retried
    probes: int = 0                # bisection probe calls
    quarantined: int = 0           # requests finished "error" by bisection

    # -- numeric degradation ladder -------------------------------------
    nonfinite_ticks: int = 0       # ticks whose logits came back non-finite
    degradations: int = 0          # re-runs on the degraded (reference) route
    skipped_ticks: int = 0         # ticks rolled back without advancing

    # -- perf / compile hygiene -----------------------------------------
    prefill_compiles: int = 0      # distinct prefill variants jitted
    prefill_cache_evictions: int = 0
    slow_ticks: int = 0            # wall time above EngineConfig.slow_tick_s

    def record_finish(self, reason: str) -> None:
        if reason not in FINISH_REASONS:
            raise ValueError(f"unknown finish reason {reason!r}; "
                             f"one of {FINISH_REASONS}")
        self.finished[reason] = self.finished.get(reason, 0) + 1

    def observe_queue(self, depth: int) -> None:
        self.queue_depth = depth
        self.peak_queue_depth = max(self.peak_queue_depth, depth)

    @property
    def total_finished(self) -> int:
        return sum(self.finished.values())

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_finished"] = self.total_finished
        return d

    def summary(self) -> str:
        fin = " ".join(f"{k}={v}" for k, v in sorted(self.finished.items()))
        return (
            f"ticks={self.ticks} submitted={self.submitted} "
            f"admitted={self.admitted} tokens={self.tokens_generated} "
            f"finished[{fin}] retries={self.step_retries} "
            f"probes={self.probes} quarantined={self.quarantined} "
            f"degradations={self.degradations} "
            f"skipped={self.skipped_ticks} "
            f"prefill_compiles={self.prefill_compiles} "
            f"peak_queue={self.peak_queue_depth}"
        )
