"""Engine health surface: counters for the serve request lifecycle.

``EngineStats`` is the single place the hardened engine records what
happened to traffic — admissions, rejections, finishes by reason,
step retries, bisection probes, quarantines, numeric degradations,
skipped (rolled-back) ticks, prefill compiles — so operators (and the
chaos tests) can assert liveness invariants without scraping logs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.obs.metrics import Registry

#: The request terminal states. Every submitted request ends with exactly
#: one of these on ``Request.finish_reason`` (the chaos wall's invariant).
FINISH_REASONS = (
    "eos",            # sampled the eos token
    "length_budget",  # generated its max_new_tokens budget
    "cache_full",     # ran out of KV-cache slots before its budget (warned)
    "deadline",       # tick TTL expired (per-request or run_to_completion)
    "rejected",       # failed admission (cannot fit / queue full)
    "error",          # quarantined by step-failure recovery, or prefill died
    "cancelled",      # host-side cancel(rid)
)


@dataclasses.dataclass
class EngineStats:
    """Monotonic counters plus current queue gauges.

    Optionally MIRRORED into an ``obs.metrics.Registry``
    (:meth:`attach`): every counter write is reflected as a
    ``serve.stats.<name>`` gauge and every finish as a
    ``serve.finished.<reason>`` counter, so operator dashboards, the
    ``--stats-json`` surface, and the chaos-wall invariants all read one
    registry instead of scraping this dataclass. Mirroring is write-
    through (not snapshot): the registry is live mid-run.
    """

    # -- traffic -------------------------------------------------------
    ticks: int = 0                 # engine steps attempted
    submitted: int = 0             # submit() calls (incl. rejected)
    admitted: int = 0              # prefills attempted into a slot
    tokens_generated: int = 0      # sampled tokens appended to outputs
    finished: Dict[str, int] = dataclasses.field(default_factory=dict)

    # -- queue ---------------------------------------------------------
    queue_depth: int = 0           # waiting requests right now
    peak_queue_depth: int = 0

    # -- failure recovery ----------------------------------------------
    step_retries: int = 0          # failed decode-step attempts retried
    prefill_retries: int = 0       # failed prefill attempts retried
    probes: int = 0                # bisection probe calls
    quarantined: int = 0           # requests finished "error" by bisection

    # -- numeric degradation ladder -------------------------------------
    nonfinite_ticks: int = 0       # ticks whose logits came back non-finite
    degradations: int = 0          # re-runs on the degraded (reference) route
    skipped_ticks: int = 0         # ticks rolled back without advancing

    # -- perf / compile hygiene -----------------------------------------
    prefill_compiles: int = 0      # distinct prefill variants jitted
    prefill_cache_evictions: int = 0
    slow_ticks: int = 0            # wall time above EngineConfig.slow_tick_s

    # -- paged KV cache (serve/paging.py) --------------------------------
    page_allocs: int = 0           # pages handed out by the allocator
    page_frees: int = 0            # pages returned to the free pool
    page_alloc_failures: int = 0   # allocation attempts the pool refused
    prefill_chunks: int = 0        # chunked-prefill chunks executed
    defrags: int = 0               # pool compactions (partition by liveness)
    auto_defrags: int = 0          # defrags triggered by policy.choose_defrag

    # -- copy-on-write prefix sharing ------------------------------------
    prefix_hits: int = 0           # admissions that mapped registry pages
    shared_page_maps: int = 0      # pages mapped from the registry (not alloc'd)
    refcount_copies: int = 0       # COW copies (write into a refcount>1 page)

    # -- metrics mirroring ----------------------------------------------
    # ``_registry`` is deliberately NOT a dataclass field: asdict()/
    # equality stay counter-only and attachment survives neither copy
    # nor pickling (a mirror is a live wire, not state).
    def attach(self, registry: Optional[Registry]) -> "EngineStats":
        """Mirror counters into ``registry`` (write-through from now on;
        current values are published immediately). ``None`` detaches."""
        object.__setattr__(self, "_registry", registry)
        if registry is not None:
            for k, v in self.as_dict().items():
                if isinstance(v, int):
                    registry.gauge(f"serve.stats.{k}").set(v)
            for reason, nn in self.finished.items():
                registry.counter(f"serve.finished.{reason}").value = nn
        return self

    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        reg = getattr(self, "_registry", None)
        if reg is not None and isinstance(value, int):
            reg.gauge(f"serve.stats.{name}").set(value)
            if name != "total_finished":
                reg.gauge("serve.stats.total_finished").set(
                    self.total_finished)

    def record_finish(self, reason: str) -> None:
        if reason not in FINISH_REASONS:
            raise ValueError(f"unknown finish reason {reason!r}; "
                             f"one of {FINISH_REASONS}")
        self.finished[reason] = self.finished.get(reason, 0) + 1
        reg = getattr(self, "_registry", None)
        if reg is not None:
            reg.counter(f"serve.finished.{reason}").inc()
            reg.gauge("serve.stats.total_finished").set(self.total_finished)

    def observe_queue(self, depth: int) -> None:
        self.queue_depth = depth
        self.peak_queue_depth = max(self.peak_queue_depth, depth)

    @property
    def total_finished(self) -> int:
        return sum(self.finished.values())

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_finished"] = self.total_finished
        return d

    def summary(self) -> str:
        """One operator line. Every monotonic counter appears (the
        regression test walks ``as_dict`` and asserts nothing counted is
        silently dropped here — ``prefill_retries`` / ``nonfinite_ticks``
        / ``slow_ticks`` / ``prefill_cache_evictions`` were once counted
        but never printed); ``as_dict`` stays the superset (it adds the
        ``queue_depth`` gauge and the raw ``finished`` map)."""
        fin = " ".join(f"{k}={v}" for k, v in sorted(self.finished.items()))
        return (
            f"ticks={self.ticks} submitted={self.submitted} "
            f"admitted={self.admitted} tokens={self.tokens_generated} "
            f"finished[{fin}] retries={self.step_retries} "
            f"prefill_retries={self.prefill_retries} "
            f"probes={self.probes} quarantined={self.quarantined} "
            f"nonfinite={self.nonfinite_ticks} "
            f"degradations={self.degradations} "
            f"skipped={self.skipped_ticks} "
            f"slow_ticks={self.slow_ticks} "
            f"prefill_compiles={self.prefill_compiles} "
            f"prefill_evictions={self.prefill_cache_evictions} "
            f"pages[allocs={self.page_allocs} frees={self.page_frees} "
            f"failures={self.page_alloc_failures} defrags={self.defrags} "
            f"auto_defrags={self.auto_defrags}] "
            f"sharing[prefix_hits={self.prefix_hits} "
            f"shared_page_maps={self.shared_page_maps} "
            f"refcount_copies={self.refcount_copies}] "
            f"prefill_chunks={self.prefill_chunks} "
            f"peak_queue={self.peak_queue_depth}"
        )
