"""Paged KV cache: page table + prefix-sum page allocator.

The serve engine's contiguous layout reserves one padded ``max_len`` K/V
buffer per slot, so HBM scales with worst-case length and concurrency
dies long before memory does. This module replaces the slot buffer with
a POOL of fixed-size pages and a per-sequence page-index vector — the
vLLM organization — with every allocator decision running as a
relational plan on the scan substrate (the paper's DB framing):

  * free-page discovery is stream compaction over the free bitmap
    (``relational.compact.filter_compact`` — one mask scan packs the
    free page ids to the front);
  * batched multi-sequence allocation slices that packed free list at
    offsets from an EXCLUSIVE prefix sum of the per-sequence page
    counts (``core.scan.cumsum(exclusive=True)``);
  * ``defrag`` is a stable ``relational.partition`` of the physical
    pages by liveness — live pages compact to the front, the table is
    remapped through the permutation, and decode output is unchanged
    (the gathered view is invariant under page renaming).

Physical page 0 is the NULL page: never allocated, and every
unassigned page-table entry points at it. Decode writes for inactive
pool rows (``cache_len == 0``) and gathers past a sequence's allocated
extent land there harmlessly — the zeroed-probability masking
convention turns those positions into exact-zero softmax contributions,
which is what keeps paged decode BITWISE identical to the contiguous
layout (see ``models/layers/attention.py``).

Observability: the allocator publishes ``serve.pages.in_use`` /
``serve.pages.free`` / ``serve.pages.fragmentation`` gauges plus
``serve.pages.alloc`` / ``serve.pages.free_op`` / ``serve.pages.defrag``
trace instants, and bumps the engine's ``EngineStats`` page counters.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import scan as scanlib
from repro.obs import trace
from repro.obs.metrics import Registry
from repro.relational import compact as rel_compact
from repro.relational import partition as rel_partition

#: Block kinds whose KV cache is paged. Local (sliding-window) layers
#: keep their O(window) ring buffers — paging a ring that is already
#: small would only add indirection — and recurrent kinds (mamba/xlstm)
#: carry O(1) state per slot, nothing to page.
PAGED_KINDS = ("global", "moe", "shared_attn")


def paged_layer_names(cfg) -> tuple:
    """Stacked-block names (``p{pos}_{kind}``) whose KV leaves page."""
    return tuple(f"p{pos}_{kind}"
                 for pos, kind in enumerate(cfg.layer_pattern)
                 if kind in PAGED_KINDS)


def pages_for(length: int, page_size: int) -> int:
    """Pages needed to hold positions ``[0, length)`` plus the slot the
    NEXT decode write lands in (position ``length``)."""
    return length // page_size + 1


class PageTable:
    """Per-slot page-index vectors (host bookkeeping + device view).

    ``table[slot, j]`` is the physical page backing logical page ``j``
    of the sequence in ``slot``; unassigned entries are 0 (the null
    page). ``device()`` returns the (slots, pages_per_seq) int32 array
    the jitted paged step gathers through.
    """

    def __init__(self, num_slots: int, pages_per_seq: int):
        self.table = np.zeros((num_slots, pages_per_seq), np.int32)
        self.counts = np.zeros(num_slots, np.int64)

    def assign(self, slot: int, pages: np.ndarray) -> None:
        n = int(self.counts[slot])
        pages = np.asarray(pages, np.int32)
        if n + pages.size > self.table.shape[1]:
            raise ValueError(
                f"slot {slot}: {n} + {pages.size} pages exceed "
                f"pages_per_seq={self.table.shape[1]}")
        self.table[slot, n:n + pages.size] = pages
        self.counts[slot] = n + pages.size

    def pages_of(self, slot: int) -> np.ndarray:
        return self.table[slot, : int(self.counts[slot])].copy()

    def release(self, slot: int) -> np.ndarray:
        pages = self.pages_of(slot)
        self.table[slot] = 0
        self.counts[slot] = 0
        return pages

    def remap(self, new_of_old: np.ndarray) -> None:
        """Rewrite every live entry through an old->new page permutation
        (defrag). Null entries stay null (``new_of_old[0] == 0``)."""
        self.table = np.asarray(new_of_old, np.int32)[self.table]

    def device(self) -> jnp.ndarray:
        return jnp.asarray(self.table)


class PageAllocator:
    """Free-page bookkeeping whose alloc/free paths are relational plans.

    Page 0 is reserved as the null page at construction and never
    handed out. ``stats`` (an ``EngineStats``) and ``metrics`` (an obs
    ``Registry``) are both optional write-through mirrors.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 stats=None, metrics: Optional[Registry] = None):
        if num_pages < 2:
            raise ValueError(f"num_pages={num_pages} leaves no allocatable "
                             f"page after the null page")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.free = np.ones(num_pages, bool)
        self.free[0] = False                     # null page: pinned live
        self.stats = stats
        self.metrics = metrics
        self._publish()

    # -- introspection ---------------------------------------------------
    @property
    def free_count(self) -> int:
        return int(self.free.sum())

    @property
    def in_use(self) -> int:
        return self.num_pages - 1 - self.free_count   # excl. null page

    def fragmentation(self) -> float:
        """1 - (largest contiguous free run / free pages): 0 when all
        free memory is one extent, approaching 1 as it shatters."""
        idx = np.flatnonzero(self.free)
        if idx.size == 0:
            return 0.0
        runs = np.split(idx, np.flatnonzero(np.diff(idx) > 1) + 1)
        return 1.0 - max(len(r) for r in runs) / idx.size

    def _publish(self) -> None:
        if self.metrics is not None:
            self.metrics.gauge("serve.pages.in_use").set(self.in_use)
            self.metrics.gauge("serve.pages.free").set(self.free_count)
            self.metrics.gauge("serve.pages.fragmentation").set(
                self.fragmentation())

    # -- alloc / free (the relational plans) -----------------------------
    def alloc(self, counts: Sequence[int]) -> "list[np.ndarray] | None":
        """Batched multi-sequence allocation: ``counts[i]`` pages for
        sequence ``i``. Returns per-sequence physical page-id vectors,
        or None (and counts a failure) when the pool cannot satisfy the
        whole batch — allocation is all-or-nothing."""
        counts = [int(c) for c in counts]
        total = sum(counts)
        if any(c < 0 for c in counts) or total == 0:
            raise ValueError(f"bad page counts {counts}")
        if total > self.free_count:
            if self.stats is not None:
                self.stats.page_alloc_failures += 1
            trace.instant("serve.pages.alloc", ok=False, want=total,
                          free=self.free_count)
            return None
        # Free-page discovery: stream compaction over the free bitmap —
        # one mask scan packs the free page ids to the front.
        ids, n = rel_compact.filter_compact(
            jnp.arange(self.num_pages, dtype=jnp.int32),
            jnp.asarray(self.free))
        ids = np.asarray(ids)[: int(n)]
        # Batched gather offsets: the exclusive prefix sum of the
        # per-sequence counts slices the packed free list (paper §1 —
        # "new index values" from a histogram scan).
        offs = np.asarray(scanlib.cumsum(
            jnp.asarray(counts, jnp.int32), exclusive=True))
        out = [ids[int(o): int(o) + c] for o, c in zip(offs, counts)]
        for pages in out:
            assert self.free[pages].all(), "double allocation"
            self.free[pages] = False
        if self.stats is not None:
            self.stats.page_allocs += total
        self._publish()
        trace.instant("serve.pages.alloc", ok=True, pages=total,
                      seqs=len(counts), free=self.free_count)
        return out

    def release(self, pages: np.ndarray) -> None:
        pages = np.asarray(pages, np.int64)
        if pages.size == 0:
            return
        if (pages == 0).any():
            raise ValueError("cannot free the null page")
        if self.free[pages].any():
            raise ValueError(f"double free: {pages[self.free[pages]]}")
        self.free[pages] = True
        if self.stats is not None:
            self.stats.page_frees += int(pages.size)
        self._publish()
        trace.instant("serve.pages.free_op", pages=int(pages.size),
                      free=self.free_count)

    # -- defrag (partition by liveness) ----------------------------------
    def defrag_plan(self) -> np.ndarray:
        """Old->new physical page permutation compacting live pages to
        the front: a stable ``relational.partition`` of the page ids by
        liveness (bucket 0 = live, bucket 1 = free). Stability keeps the
        null page at index 0 and preserves live-page relative order."""
        bucket = jnp.asarray(self.free, jnp.int32)      # live=0, free=1
        plan = rel_partition.partition_plan(bucket, 2)
        return np.asarray(plan.dest)

    def apply_defrag(self, new_of_old: np.ndarray) -> int:
        """Commit a defrag plan to the bitmap. Returns live pages moved.
        (The caller is responsible for permuting the pools and remapping
        its page tables through the same plan.)"""
        new_of_old = np.asarray(new_of_old)
        moved = int(((new_of_old != np.arange(self.num_pages))
                     & ~self.free).sum())
        live = self.in_use + 1                          # + null page
        self.free[:] = True
        self.free[:live] = False
        if self.stats is not None:
            self.stats.defrags += 1
        self._publish()
        trace.instant("serve.pages.defrag", moved=moved,
                      live=live - 1, free=self.free_count)
        return moved


# ---------------------------------------------------------------------------
# device-side pool views (used by the paged step / engine admission)
# ---------------------------------------------------------------------------


def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(P, Hkv, ps, hd) pool × (B, n_log) table -> (B, Hkv, n_log·ps, hd)
    contiguous per-row view — the shape the existing cached attention
    path consumes, so paged decode reuses it bit-for-bit."""
    P, Hkv, ps, hd = pool.shape
    B, n_log = page_table.shape
    g = jnp.moveaxis(pool[page_table], 2, 1)       # (B, Hkv, n_log, ps, hd)
    return g.reshape(B, Hkv, n_log * ps, hd)


def scatter_token(pool: jnp.ndarray, values: jnp.ndarray,
                  page_table: jnp.ndarray, write_at: jnp.ndarray
                  ) -> jnp.ndarray:
    """Write one token row per sequence back into the pool.

    pool (P, Hkv, ps, hd); values (B, Hkv, hd) — the K or V vector each
    row just appended; write_at (B,) absolute positions. Rows whose
    logical page is unassigned (inactive slots at position 0) hit the
    null page.
    """
    ps = pool.shape[2]
    phys = jnp.take_along_axis(page_table, (write_at // ps)[:, None],
                               axis=1)[:, 0]                     # (B,)
    off = write_at % ps
    # Advanced indices (phys, off) straddle the Hkv slice, so they
    # broadcast to the front: target view is (B, Hkv, hd).
    return pool.at[phys, :, off, :].set(values.astype(pool.dtype))


def scatter_prefix(pool: jnp.ndarray, row: jnp.ndarray,
                   pages: np.ndarray) -> jnp.ndarray:
    """Copy a prefilled contiguous cache row into freshly-allocated
    pages. pool (per, P, Hkv, ps, hd); row (per, 1, Hkv, L, hd) with
    L >= len(pages)·ps; pages (n,) physical ids."""
    per, P, Hkv, ps, hd = pool.shape
    n = int(np.asarray(pages).size)
    seg = row[:, 0, :, : n * ps].reshape(per, Hkv, n, ps, hd)
    seg = jnp.moveaxis(seg, 2, 1)                  # (per, n, Hkv, ps, hd)
    return pool.at[:, jnp.asarray(np.asarray(pages, np.int32))].set(
        seg.astype(pool.dtype))
