"""Paged KV cache: page table + prefix-sum page allocator.

The serve engine's contiguous layout reserves one padded ``max_len`` K/V
buffer per slot, so HBM scales with worst-case length and concurrency
dies long before memory does. This module replaces the slot buffer with
a POOL of fixed-size pages and a per-sequence page-index vector — the
vLLM organization — with every allocator decision running as a
relational plan on the scan substrate (the paper's DB framing):

  * free-page discovery is stream compaction over the free bitmap
    (``relational.compact.filter_compact`` — one mask scan packs the
    free page ids to the front);
  * batched multi-sequence allocation slices that packed free list at
    offsets from an EXCLUSIVE prefix sum of the per-sequence page
    counts (``core.scan.cumsum(exclusive=True)``);
  * ``defrag`` is a stable ``relational.partition`` of the physical
    pages by liveness — live pages compact to the front, the table is
    remapped through the permutation, and decode output is unchanged
    (the gathered view is invariant under page renaming).

Physical page 0 is the NULL page: never allocated, and every
unassigned page-table entry points at it. Decode writes for inactive
pool rows (``cache_len == 0``) and gathers past a sequence's allocated
extent land there harmlessly — the zeroed-probability masking
convention turns those positions into exact-zero softmax contributions,
which is what keeps paged decode BITWISE identical to the contiguous
layout (see ``models/layers/attention.py``).

Copy-on-write sharing rides on a per-page REFCOUNT: ``alloc`` hands a
page out at refcount 1, ``retain`` adds table references (a new slot
mapping a shared prefix page), and ``release`` decrements and returns
the page to the free pool only at zero. A decode write into a page with
refcount > 1 is preceded by copy-one-page-then-write in the engine, so
sharers never observe each other. The ``PrefixRegistry`` below is the
engine-level index from prompt-prefix chunks to physical pages.

Observability: the allocator publishes ``serve.pages.in_use`` /
``serve.pages.free`` / ``serve.pages.fragmentation`` gauges plus
``serve.pages.alloc`` / ``serve.pages.free_op`` / ``serve.pages.defrag``
trace instants, and bumps the engine's ``EngineStats`` page counters.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scanlib
from repro.obs import trace
from repro.obs.metrics import Registry
from repro.relational import compact as rel_compact
from repro.relational import partition as rel_partition

#: Block kinds whose KV cache is paged. Local (sliding-window) layers
#: page their O(window) ring: the ring rides the first
#: ``window // page_size`` entries of the (shared) page-table row, so
#: gemma2/gemma3-style hybrids page every attention layer. Recurrent
#: kinds (mamba/xlstm) carry O(1) state per slot — nothing to page.
PAGED_KINDS = ("global", "local", "moe", "shared_attn")


def paged_layer_names(cfg) -> tuple:
    """Stacked-block names (``p{pos}_{kind}``) whose KV leaves page."""
    return tuple(f"p{pos}_{kind}"
                 for pos, kind in enumerate(cfg.layer_pattern)
                 if kind in PAGED_KINDS)


def pages_for(length: int, page_size: int) -> int:
    """Pages needed to hold positions ``[0, length)`` plus the slot the
    NEXT decode write lands in (position ``length``)."""
    return length // page_size + 1


def validate_paged_support(cfg, max_len: int, page_size: int) -> None:
    """Construction-time guard for the paged layout.

    Unsupported layer/geometry combinations fail HERE — with the
    offending ``p{pos}_{kind}`` layer name in the message — instead of
    raising mid-jit-trace from ``attention.py`` with no context. The
    trace-time raises that remain in the attention path guard genuinely
    impossible states (e.g. a multi-token paged decode step, which the
    engine never emits).
    """
    if getattr(cfg, "is_encdec", False):
        raise ValueError("paged cache layout supports decoder-only models")
    if max_len % page_size:
        raise ValueError(
            f"max_len={max_len} not a multiple of page_size={page_size}")
    bad = []
    for pos, kind in enumerate(cfg.layer_pattern):
        if kind != "local":
            continue
        w = getattr(cfg, "sliding_window", None)
        if not w:
            bad.append(f"p{pos}_local (sliding_window unset)")
        elif min(int(w), int(max_len)) % page_size:
            bad.append(
                f"p{pos}_local (ring extent min(window={w}, "
                f"max_len={max_len}) not a multiple of "
                f"page_size={page_size})")
    if bad:
        raise ValueError(
            "paged cache layout cannot host: " + "; ".join(bad))
    if not paged_layer_names(cfg):
        raise ValueError(
            f"paged cache layout needs at least one attention layer; "
            f"pattern {cfg.layer_pattern} has none")


class PageTable:
    """Per-slot page-index vectors (host bookkeeping + device view).

    ``table[slot, j]`` is the physical page backing logical page ``j``
    of the sequence in ``slot``; unassigned entries are 0 (the null
    page). ``device()`` returns the (slots, pages_per_seq) int32 array
    the jitted paged step gathers through.
    """

    def __init__(self, num_slots: int, pages_per_seq: int):
        self.table = np.zeros((num_slots, pages_per_seq), np.int32)
        self.counts = np.zeros(num_slots, np.int64)

    def assign(self, slot: int, pages: np.ndarray) -> None:
        n = int(self.counts[slot])
        pages = np.asarray(pages, np.int32)
        if n + pages.size > self.table.shape[1]:
            raise ValueError(
                f"slot {slot}: {n} + {pages.size} pages exceed "
                f"pages_per_seq={self.table.shape[1]}")
        self.table[slot, n:n + pages.size] = pages
        self.counts[slot] = n + pages.size

    def pages_of(self, slot: int) -> np.ndarray:
        return self.table[slot, : int(self.counts[slot])].copy()

    def release(self, slot: int) -> np.ndarray:
        pages = self.pages_of(slot)
        self.table[slot] = 0
        self.counts[slot] = 0
        return pages

    def remap(self, new_of_old: np.ndarray) -> None:
        """Rewrite every live entry through an old->new page permutation
        (defrag). Null entries stay null (``new_of_old[0] == 0``)."""
        self.table = np.asarray(new_of_old, np.int32)[self.table]

    def device(self) -> jnp.ndarray:
        return jnp.asarray(self.table)


class PageAllocator:
    """Free-page bookkeeping whose alloc/free paths are relational plans.

    Page 0 is reserved as the null page at construction and never
    handed out. Every live page carries a refcount (the Pibiri–Venturini
    auxiliary-summary regime: incremental bookkeeping maintained under
    mixed query/update traffic): ``alloc`` hands pages out at refcount
    1, ``retain`` adds copy-on-write sharers, ``release`` decrements and
    frees only at zero. ``epoch[p]`` counts free->live transitions of
    page ``p`` so weak references (the prefix registry's partial-page
    entries) can detect reuse. ``stats`` (an ``EngineStats``) and
    ``metrics`` (an obs ``Registry``) are both optional write-through
    mirrors.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 stats=None, metrics: Optional[Registry] = None):
        if num_pages < 2:
            raise ValueError(f"num_pages={num_pages} leaves no allocatable "
                             f"page after the null page")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.free = np.ones(num_pages, bool)
        self.free[0] = False                     # null page: pinned live
        self.refcount = np.zeros(num_pages, np.int64)
        self.refcount[0] = 1                     # null page: pinned ref
        self.epoch = np.zeros(num_pages, np.int64)
        self.stats = stats
        self.metrics = metrics
        self._publish()

    # -- introspection ---------------------------------------------------
    @property
    def free_count(self) -> int:
        return int(self.free.sum())

    @property
    def in_use(self) -> int:
        return self.num_pages - 1 - self.free_count   # excl. null page

    def longest_free_run(self) -> int:
        """Length of the largest contiguous free extent (0 when full)."""
        idx = np.flatnonzero(self.free)
        if idx.size == 0:
            return 0
        runs = np.split(idx, np.flatnonzero(np.diff(idx) > 1) + 1)
        return max(len(r) for r in runs)

    def fragmentation(self) -> float:
        """1 - (largest contiguous free run / free pages): 0 when all
        free memory is one extent, approaching 1 as it shatters. At full
        occupancy there is NO free extent at all, so the gauge pins to
        1.0 — the pool is maximally tight exactly then, and the old 0.0
        ("perfectly compact") reading would suppress the auto-defrag
        trigger at the worst possible moment."""
        n_free = self.free_count
        if n_free == 0:
            return 1.0
        return 1.0 - self.longest_free_run() / n_free

    def _publish(self) -> None:
        if self.metrics is not None:
            self.metrics.gauge("serve.pages.in_use").set(self.in_use)
            self.metrics.gauge("serve.pages.free").set(self.free_count)
            self.metrics.gauge("serve.pages.fragmentation").set(
                self.fragmentation())

    # -- alloc / free (the relational plans) -----------------------------
    def alloc(self, counts: Sequence[int]) -> "list[np.ndarray] | None":
        """Batched multi-sequence allocation: ``counts[i]`` pages for
        sequence ``i``. Returns per-sequence physical page-id vectors,
        or None (and counts a failure) when the pool cannot satisfy the
        whole batch — allocation is all-or-nothing."""
        counts = [int(c) for c in counts]
        total = sum(counts)
        if any(c < 0 for c in counts):
            raise ValueError(f"bad page counts {counts}")
        if total == 0:
            # A growth tick where no live row crosses a page boundary is
            # a legal no-op, not an error.
            return [np.empty(0, np.int64) for _ in counts]
        if total > self.free_count:
            if self.stats is not None:
                self.stats.page_alloc_failures += 1
            trace.instant("serve.pages.alloc", ok=False, want=total,
                          free=self.free_count)
            return None
        # Free-page discovery: stream compaction over the free bitmap —
        # one mask scan packs the free page ids to the front.
        ids, n = rel_compact.filter_compact(
            jnp.arange(self.num_pages, dtype=jnp.int32),
            jnp.asarray(self.free))
        ids = np.asarray(ids)[: int(n)]
        # Batched gather offsets: the exclusive prefix sum of the
        # per-sequence counts slices the packed free list (paper §1 —
        # "new index values" from a histogram scan).
        offs = np.asarray(scanlib.cumsum(
            jnp.asarray(counts, jnp.int32), exclusive=True))
        out = [ids[int(o): int(o) + c].astype(np.int64)
               for o, c in zip(offs, counts)]
        flat = np.concatenate(out)
        # A real exception, not an assert: asserts vanish under
        # ``python -O`` and handing out a live page corrupts every
        # sharer of it.
        if not self.free[flat].all() or (self.refcount[flat] != 0).any():
            raise RuntimeError(
                f"double allocation: pages "
                f"{flat[~self.free[flat] | (self.refcount[flat] != 0)]} "
                f"are already live")
        self.free[flat] = False
        self.refcount[flat] = 1
        self.epoch[flat] += 1
        if self.stats is not None:
            self.stats.page_allocs += total
        self._publish()
        trace.instant("serve.pages.alloc", ok=True, pages=total,
                      seqs=len(counts), free=self.free_count)
        return out

    def retain(self, pages: np.ndarray) -> None:
        """Add one reference per page — a new slot mapping shared
        (copy-on-write) pages, or the prefix registry pinning a prompt
        page beyond its donor's lifetime."""
        pages = np.asarray(pages, np.int64)
        if pages.size == 0:
            return
        if (pages == 0).any():
            raise ValueError("cannot retain the null page")
        if (self.refcount[pages] <= 0).any():
            raise ValueError(
                f"retain of free pages {pages[self.refcount[pages] <= 0]}")
        np.add.at(self.refcount, pages, 1)

    def release(self, pages: np.ndarray) -> None:
        """Drop one reference per page; pages reaching refcount 0 return
        to the free pool (``stats.page_frees`` counts only those)."""
        pages = np.asarray(pages, np.int64)
        if pages.size == 0:
            return
        if (pages == 0).any():
            raise ValueError("cannot free the null page")
        dec = np.bincount(pages, minlength=self.num_pages)
        over = dec > self.refcount
        if over.any():
            raise ValueError(f"double free: {np.flatnonzero(over)}")
        self.refcount -= dec
        freed = (self.refcount == 0) & (dec > 0)
        n_freed = int(freed.sum())
        self.free |= freed
        if self.stats is not None:
            self.stats.page_frees += n_freed
        self._publish()
        trace.instant("serve.pages.free_op", pages=int(pages.size),
                      freed=n_freed, free=self.free_count)

    # -- defrag (partition by liveness) ----------------------------------
    def defrag_plan(self) -> np.ndarray:
        """Old->new physical page permutation compacting live pages to
        the front: a stable ``relational.partition`` of the page ids by
        liveness (bucket 0 = live, bucket 1 = free). Stability keeps the
        null page at index 0 and preserves live-page relative order."""
        bucket = jnp.asarray(self.free, jnp.int32)      # live=0, free=1
        plan = rel_partition.partition_plan(bucket, 2)
        return np.asarray(plan.dest)

    def apply_defrag(self, new_of_old: np.ndarray) -> int:
        """Commit a defrag plan: permute refcounts/epochs through the
        old->new mapping and rebuild the free bitmap as refcount == 0.
        Returns live pages moved. (The caller is responsible for
        permuting the pools and remapping its page tables and prefix
        registry through the same plan.)"""
        new_of_old = np.asarray(new_of_old)
        moved = int(((new_of_old != np.arange(self.num_pages))
                     & ~self.free).sum())
        rc = np.zeros_like(self.refcount)
        rc[new_of_old] = self.refcount
        self.refcount = rc
        ep = np.zeros_like(self.epoch)
        ep[new_of_old] = self.epoch
        self.epoch = ep
        self.free = self.refcount == 0
        self.free[0] = False                            # null page pinned
        if self.stats is not None:
            self.stats.defrags += 1
        self._publish()
        trace.instant("serve.pages.defrag", moved=moved,
                      live=self.in_use, free=self.free_count)
        return moved


class PrefixRegistry:
    """Engine-level prompt-prefix -> physical-page cache (COW sharing).

    Keys are the raw prompt-token bytes up to each page boundary — a
    CHAIN key: matching page ``j`` implies pages ``[0, j)`` matched the
    same prompt too, so prefix-chain consistency is structural, not
    checked. Two entry strengths:

      * FULL prompt pages register STRONG — the registry holds one
        allocator reference, so a common system prompt's pages survive
        their donor request and keep serving hits. Their content is
        immutable: every position in a full prompt page is below every
        holder's length, and decode only ever writes at the length.
      * The PARTIAL tail page (prompt ends mid-page) registers WEAK —
        no reference, validated against the allocator's page ``epoch``
        at match time so a freed-and-reused page can never leak into a
        new request. Weak entries are what make copy-on-write live: a
        consumer mapping one retains it, and the first decode write by
        either sharer into the now-refcount>1 page copies first.

    ``capacity`` is an LRU entry cap; evicting a strong entry releases
    its reference. ``remap`` follows a defrag permutation.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.allocator = allocator
        self.page_size = int(page_size)
        self.capacity = int(capacity)
        # key bytes -> (physical page, strong, epoch at registration)
        self._entries: "OrderedDict[bytes, tuple]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def _lookup(self, key: bytes):
        ent = self._entries.get(key)
        if ent is None:
            return None
        page, strong, epoch = ent
        if not strong and (self.allocator.refcount[page] <= 0
                           or self.allocator.epoch[page] != epoch):
            del self._entries[key]              # stale weak entry
            return None
        self._entries.move_to_end(key)
        return page

    def match(self, prompt: np.ndarray) -> "list[int]":
        """Longest chain of registered pages covering ``prompt``: full
        page-sized chunks first, then (only on a complete full-page
        match) the exact partial tail. Returns physical page ids; the
        caller retains them when it maps them into a table row."""
        ps = self.page_size
        prompt = np.ascontiguousarray(prompt, np.int32)
        pages = []
        full = int(prompt.size) // ps
        for j in range(full):
            page = self._lookup(prompt[: (j + 1) * ps].tobytes())
            if page is None:
                return pages
            pages.append(int(page))
        if prompt.size % ps:
            page = self._lookup(prompt.tobytes())
            if page is not None:
                pages.append(int(page))
        return pages

    def register(self, prompt: np.ndarray, pages: np.ndarray) -> int:
        """Register a just-installed prompt's prefix chunks against the
        physical pages now holding them. Returns new entries added."""
        ps = self.page_size
        prompt = np.ascontiguousarray(prompt, np.int32)
        S = int(prompt.size)
        chunks = [((j + 1) * ps, int(pages[j]), True)
                  for j in range(S // ps)]
        if S % ps:
            chunks.append((S, int(pages[S // ps]), False))
        added = 0
        for extent, page, strong in chunks:
            key = prompt[:extent].tobytes()
            if self._lookup(key) is not None:
                continue                         # live entry already serves
            if strong:
                self.allocator.retain(np.array([page]))
            self._entries[key] = (page, strong,
                                  int(self.allocator.epoch[page]))
            added += 1
            while len(self._entries) > self.capacity:
                _, (p0, s0, _) = self._entries.popitem(last=False)
                if s0:
                    self.allocator.release(np.array([p0]))
        return added

    def remap(self, new_of_old: np.ndarray) -> None:
        """Rewrite entry page ids through a defrag permutation (epochs
        ride along inside the allocator's own permuted array)."""
        new_of_old = np.asarray(new_of_old)
        self._entries = OrderedDict(
            (k, (int(new_of_old[p]), s, e))
            for k, (p, s, e) in self._entries.items())

    def strong_pages(self) -> "list[int]":
        """Pages the registry itself holds a reference on (audit)."""
        return [p for p, s, _ in self._entries.values() if s]

    def clear(self) -> None:
        """Drop every entry, releasing strong references."""
        strong = self.strong_pages()
        self._entries.clear()
        if strong:
            self.allocator.release(np.asarray(strong, np.int64))


# ---------------------------------------------------------------------------
# device-side pool views (used by the paged step / engine admission)
# ---------------------------------------------------------------------------


def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(P, Hkv, ps, hd) pool × (B, n_log) table -> (B, Hkv, n_log·ps, hd)
    contiguous per-row view — the shape the existing cached attention
    path consumes, so paged decode reuses it bit-for-bit."""
    P, Hkv, ps, hd = pool.shape
    B, n_log = page_table.shape
    g = jnp.moveaxis(pool[page_table], 2, 1)       # (B, Hkv, n_log, ps, hd)
    return g.reshape(B, Hkv, n_log * ps, hd)


def scatter_token(pool: jnp.ndarray, values: jnp.ndarray,
                  page_table: jnp.ndarray, write_at: jnp.ndarray
                  ) -> jnp.ndarray:
    """Write one token row per sequence back into the pool.

    pool (P, Hkv, ps, hd); values (B, Hkv, hd) — the K or V vector each
    row just appended; write_at (B,) absolute positions. Rows whose
    logical page is unassigned (inactive slots at position 0) hit the
    null page.
    """
    ps = pool.shape[2]
    phys = jnp.take_along_axis(page_table, (write_at // ps)[:, None],
                               axis=1)[:, 0]                     # (B,)
    off = write_at % ps
    # Advanced indices (phys, off) straddle the Hkv slice, so they
    # broadcast to the front: target view is (B, Hkv, hd).
    return pool.at[phys, :, off, :].set(values.astype(pool.dtype))


def scatter_prefix(pool: jnp.ndarray, row: jnp.ndarray,
                   pages: np.ndarray, start_page: int = 0) -> jnp.ndarray:
    """Copy a prefilled contiguous cache row into freshly-allocated
    pages. pool (per, P, Hkv, ps, hd); row (per, 1, Hkv, L, hd) with
    L >= (start_page + len(pages))·ps; pages (n,) physical ids backing
    logical pages [start_page, start_page + n) — a nonzero start skips
    the logical pages a prefix-sharing install mapped from the registry
    instead of recomputing."""
    per, P, Hkv, ps, hd = pool.shape
    n = int(np.asarray(pages).size)
    if n == 0:
        return pool
    lo = int(start_page) * ps
    seg = row[:, 0, :, lo: lo + n * ps].reshape(per, Hkv, n, ps, hd)
    seg = jnp.moveaxis(seg, 2, 1)                  # (per, n, Hkv, ps, hd)
    return pool.at[:, jnp.asarray(np.asarray(pages, np.int32))].set(
        seg.astype(pool.dtype))


def gather_prefix(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(per, P, Hkv, ps, hd) pool × (B, n_log) table ->
    (per, B, Hkv, n_log·ps, hd): the contiguous staging-cache view of a
    table row (inverse of ``scatter_prefix``), used to seed a shared
    prefix before the suffix-only prefill."""
    return jax.vmap(gather_pages, in_axes=(0, None))(pool, page_table)
