from repro.serve.engine import (AdmissionError, Engine, EngineConfig,
                                EngineDeadlineError, EngineStepError,
                                Request)
from repro.serve.faults import (FaultInjector, FaultSpec, InjectedFault,
                                StepContext)
from repro.serve.sampling import finite_rows, sample_logits
from repro.serve.stats import FINISH_REASONS, EngineStats
from repro.serve.steps import (bucket_len, bucketable,
                               make_bucketed_prefill_fn, make_prefill_fn,
                               make_serve_step)

__all__ = [
    "AdmissionError", "Engine", "EngineConfig", "EngineDeadlineError",
    "EngineStats", "EngineStepError", "FaultInjector", "FaultSpec",
    "FINISH_REASONS", "InjectedFault", "Request", "StepContext",
    "bucket_len", "bucketable", "finite_rows", "make_bucketed_prefill_fn",
    "make_prefill_fn", "make_serve_step", "sample_logits",
]
