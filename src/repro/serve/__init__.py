from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.sampling import sample_logits
from repro.serve.steps import make_prefill_fn, make_serve_step

__all__ = ["Engine", "EngineConfig", "Request", "make_prefill_fn",
           "make_serve_step", "sample_logits"]
