from repro.serve.engine import (AdmissionError, Engine, EngineConfig,
                                EngineDeadlineError, EngineStepError,
                                Request)
from repro.serve.faults import (FaultInjector, FaultSpec, InjectedFault,
                                StepContext)
from repro.serve.paging import (PageAllocator, PageTable, PrefixRegistry,
                                gather_pages, gather_prefix,
                                paged_layer_names, pages_for, scatter_prefix,
                                scatter_token, validate_paged_support)
from repro.serve.sampling import finite_rows, sample_logits
from repro.serve.stats import FINISH_REASONS, EngineStats
from repro.serve.steps import (bucket_len, bucketable,
                               init_paged_cache_for,
                               make_bucketed_prefill_fn,
                               make_chunked_prefill_fn,
                               make_paged_serve_step, make_prefill_fn,
                               make_serve_step)

__all__ = [
    "AdmissionError", "Engine", "EngineConfig", "EngineDeadlineError",
    "EngineStats", "EngineStepError", "FaultInjector", "FaultSpec",
    "FINISH_REASONS", "InjectedFault", "PageAllocator", "PageTable",
    "PrefixRegistry", "Request", "StepContext",
    "bucket_len", "bucketable", "finite_rows", "gather_pages",
    "gather_prefix", "init_paged_cache_for", "make_bucketed_prefill_fn",
    "make_chunked_prefill_fn", "make_paged_serve_step", "make_prefill_fn",
    "make_serve_step", "paged_layer_names", "pages_for", "sample_logits",
    "scatter_prefix", "scatter_token", "validate_paged_support",
]
