"""Jittable serving step functions (these are what the dry-run lowers).

``serve_step``: ONE new token for every sequence in the batch against a KV
cache of ``max_len`` slots (the decode_32k / long_500k shapes).
``prefill``: the full-prompt pass that fills the cache (prefill_32k).

Shardings: batch over ('pod','data'); cache heads over 'model' — the KV
cache is a pytree whose leaves follow PARAM-style logical rules resolved
in ``cache_shardings``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.config import ModelConfig
from repro.obs import trace

Pytree = Any


def make_serve_step(cfg: ModelConfig, unroll: bool = False,
                    ssm_impl: Optional[str] = None):
    """(params, tokens (B,1), cache, cache_len) -> (logits, new_cache).

    ``cache_len`` may be a scalar or a per-row (B,) vector (heterogeneous
    pool). ``ssm_impl`` pins the SSM scan route — the engine's
    degradation ladder builds a second step with ``ssm_impl="chunked"``
    (the jnp reference) as the safe route.
    """

    if cfg.is_encdec:
        def step(params, tokens, cache, cache_len, memory):
            return encdec_mod.serve_step(
                params, tokens, memory, cache, cache_len, cfg,
                unroll=unroll)
        return step

    def step(params, tokens, cache, cache_len):
        return lm_mod.decode_step(params, tokens, cache, cache_len, cfg,
                                  ssm_impl=ssm_impl, unroll=unroll)

    return step


def make_prefill_fn(cfg: ModelConfig, max_len: int, unroll: bool = False,
                    attn_impl: Optional[str] = None,
                    attn_schedule: str = "auto",
                    ssm_impl: Optional[str] = None):
    """``attn_impl="flash"`` routes decoder-only prefill attention through
    the engine-backed flash fold (KV cache may be longer than the prompt
    — the padded-cache case); ``attn_schedule`` picks its grid
    organization (carry | decoupled | auto, see
    ``core/scan/policy.choose_attention_schedule``)."""
    if cfg.is_encdec:
        def fn(params, tokens, embeds):
            memory = encdec_mod.encode(params, embeds, cfg, unroll=unroll)
            cache = encdec_mod.init_dec_cache(cfg, tokens.shape[0], max_len)
            hidden, cache = encdec_mod.decode_forward(
                params, tokens, memory, cfg, cache=cache,
                cache_len=jnp.zeros((), jnp.int32), unroll=unroll)
            from repro.models.layers.embedding import lm_logits
            return lm_logits(params, hidden[:, -1:], cfg)[:, 0], cache, memory
        return fn

    def fn(params, tokens, embeds=None):
        # Fires once per jit COMPILATION (this fn is traced, not run, by
        # the engine's jit) — one event per prefill variant, mirroring
        # the prefill_compiles counter.
        trace.instant("serve.prefill.variant", batch=tokens.shape[0],
                      prompt_len=tokens.shape[1], bucketed=False,
                      attn_impl=attn_impl or "dense",
                      attn_schedule=attn_schedule)
        logits, cache = lm_mod.prefill(
            params, tokens, cfg, max_len, embeds=embeds,
            attn_impl=attn_impl, attn_schedule=attn_schedule,
            ssm_impl=ssm_impl, unroll=unroll)
        return logits, cache

    return fn


def bucketable(cfg: ModelConfig) -> bool:
    """True when prompt padding is semantics-free for this architecture.

    Bucketing pads prompts to a power-of-two length. Trailing pads are
    harmless only for pure global-attention stacks (pad keys land past
    the causal frontier of every real token and the logits are read at
    the true last position). Recurrent layers (ssm/xlstm) would fold the
    pads into their state, MoE would burn expert capacity on them, and
    local layers would push real keys out of the ring buffer.
    """
    return (not cfg.is_encdec
            and not cfg.frontend_tokens
            and all(k == "global" for k in cfg.layer_pattern))


def bucket_len(S: int, max_len: int, floor: int = 8) -> int:
    """Next power-of-two prompt bucket: jit variants grow as log2(max_len)
    rather than one per distinct prompt length."""
    b = floor
    while b < S:
        b *= 2
    return min(b, max_len)


def make_bucketed_prefill_fn(cfg: ModelConfig, max_len: int,
                             unroll: bool = False,
                             attn_impl: Optional[str] = None,
                             attn_schedule: str = "auto",
                             ssm_impl: Optional[str] = None):
    """``(params, tokens (B, bucket), true_len ()) -> (logits, cache)``.

    Like ``make_prefill_fn`` but tokens arrive padded to a bucket length
    and ``true_len`` (traced scalar) marks the real prompt extent: last-
    token logits are sliced at ``true_len - 1`` and the returned
    engine-side cache length must be ``true_len``, not the bucket. Only
    valid when ``bucketable(cfg)`` — the caller gates on that.
    """
    if not bucketable(cfg):
        raise ValueError(
            f"bucketed prefill requires a pure global-attention decoder; "
            f"got pattern {cfg.layer_pattern!r}")

    def fn(params, tokens, true_len):
        B, S = tokens.shape
        # Once per compiled bucket variant (see make_prefill_fn).
        trace.instant("serve.prefill.variant", batch=B, bucket=S,
                      bucketed=True, attn_impl=attn_impl or "dense",
                      attn_schedule=attn_schedule)
        cache = lm_mod.init_cache(cfg, B, max_len)
        hidden, _, cache = lm_mod.forward(
            params, tokens, cfg, cache=cache,
            cache_len=jnp.zeros((), jnp.int32), attn_impl=attn_impl,
            attn_schedule=attn_schedule, ssm_impl=ssm_impl, unroll=unroll)
        last = jax.lax.dynamic_slice_in_dim(hidden, true_len - 1, 1, axis=1)
        from repro.models.layers.embedding import lm_logits
        return lm_logits(params, last, cfg)[:, 0], cache

    return fn


def init_cache_for(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    if cfg.is_encdec:
        return encdec_mod.init_dec_cache(cfg, batch, max_len)
    return lm_mod.init_cache(cfg, batch, max_len)


# ---------------------------------------------------------------------------
# paged layout (serve/paging.py): pools + page table instead of slot rows
# ---------------------------------------------------------------------------


def init_paged_cache_for(cfg: ModelConfig, batch: int, max_len: int,
                         page_size: int, num_pages: int) -> Pytree:
    """Paged decode cache: ``{"layers": ..., "page_table": ...}``.

    Attention KV leaves — global AND local (sliding-window) — become
    page POOLS of shape ``(periods, num_pages, Hkv, page_size, hd)``
    shared by all slots; a local layer's O(window) ring rides the first
    ``window // page_size`` entries of its table row (the attention path
    clamps its gather there). Recurrent (ssm/xlstm) state keeps its
    slot-indexed layout unchanged. The page table is one
    ``(batch, max_len // page_size)`` int32 array shared across layers
    (vLLM-style); entry 0 is the null page.
    """
    from repro.serve.paging import paged_layer_names, validate_paged_support
    validate_paged_support(cfg, max_len, page_size)
    layers = lm_mod.init_cache(cfg, batch, max_len)
    for name in paged_layer_names(cfg):
        kv = layers[name]["kv"]
        per = kv["k"].shape[0]
        dt = kv["k"].dtype
        shape = (per, num_pages, cfg.num_kv_heads, page_size, cfg.head_dim)
        layers[name] = {"kv": {"k_pages": jnp.zeros(shape, dt),
                               "v_pages": jnp.zeros(shape, dt)}}
    return {"layers": layers,
            "page_table": jnp.zeros((batch, max_len // page_size),
                                    jnp.int32)}


def make_paged_serve_step(cfg: ModelConfig, unroll: bool = False,
                          ssm_impl: Optional[str] = None):
    """(params, tokens (B,1), paged_cache, cache_len) -> (logits, cache).

    The paged cache bundles the page table INTO the pytree so the step
    signature matches ``make_serve_step`` exactly — retries, probes,
    donation and the degradation ladder all work unchanged. Inside the
    jit the table is broadcast to each paged layer; the attention cached
    path gathers/scatters through it (see ``models/layers/attention``).
    """
    if cfg.is_encdec:
        raise ValueError("paged cache layout is decoder-only")
    from repro.serve.paging import paged_layer_names
    names = paged_layer_names(cfg)

    def step(params, tokens, cache, cache_len):
        pt = cache["page_table"]
        layers = dict(cache["layers"])
        for name in names:
            kv = dict(layers[name]["kv"])
            per = kv["k_pages"].shape[0]
            kv["pt"] = jnp.broadcast_to(pt[None], (per,) + pt.shape)
            layers[name] = {"kv": kv}
        logits, new_layers = lm_mod.decode_step(
            params, tokens, layers, cache_len, cfg, ssm_impl=ssm_impl,
            unroll=unroll)
        out_layers = {}
        for name, c in new_layers.items():
            if name in names:
                c = {"kv": {k: v for k, v in c["kv"].items() if k != "pt"}}
            out_layers[name] = c
        return logits, {"layers": out_layers, "page_table": pt}

    return step


def make_chunked_prefill_fn(cfg: ModelConfig, max_len: int,
                            unroll: bool = False,
                            attn_impl: Optional[str] = None,
                            attn_schedule: str = "auto"):
    """``(params, tokens (1, C), cache, cache_len (), true_len ()) ->
    (logits (1, V), cache)`` — ONE prompt chunk against a staging cache.

    The engine advances a long prompt one chunk per tick so decode for
    resident sequences interleaves instead of stalling behind a
    monolithic prefill. The cached attention path already handles
    mid-stream writes (``cache_len > 0`` keeps the dense cached route;
    its ``lax.cond`` guard was built for exactly this call), and with
    trailing pads in the LAST chunk masked off by ``true_len`` the
    causal mask makes chunked prefill bit-identical to one-shot dense
    prefill. Same gate as bucketing: pure global-attention stacks only
    (recurrent layers would fold pads into state).
    """
    if not bucketable(cfg):
        raise ValueError(
            f"chunked prefill requires a pure global-attention decoder; "
            f"got pattern {cfg.layer_pattern!r}")

    def fn(params, tokens, cache, cache_len, true_len):
        B, C = tokens.shape
        # Once per compiled chunk variant (see make_prefill_fn).
        trace.instant("serve.prefill.variant", batch=B, chunk=C,
                      bucketed=False, chunked=True,
                      attn_impl=attn_impl or "dense",
                      attn_schedule=attn_schedule)
        hidden, _, cache = lm_mod.forward(
            params, tokens, cfg, cache=cache, cache_len=cache_len,
            attn_impl=attn_impl, attn_schedule=attn_schedule,
            unroll=unroll)
        last = jax.lax.dynamic_slice_in_dim(hidden, true_len - 1, 1, axis=1)
        from repro.models.layers.embedding import lm_logits
        return lm_logits(params, last, cfg)[:, 0], cache

    return fn


_CACHE_AXES = {
    # leaf name fragment -> logical axes (cache leaves, by convention).
    # KV caches shard the SEQUENCE over 'model' (seq_shard) — kv_heads are
    # as low as 4 (qwen3) so head-sharding caps at 4-way; seq-sharding
    # always gives the full 16-way split and the softmax combine across
    # shards is the distributed online-softmax scan (DESIGN.md §3).
    "k": ("layers", "batch", None, "seq_shard", None),
    "v": ("layers", "batch", None, "seq_shard", None),
    "conv": ("layers", "batch", None, "ssm_inner"),
    # ssm: h (L,B,heads,hd,state); mlstm: S (L,B,H,dh,dh), n (L,B,H,dh);
    # slstm: h/c/n/m (L,B,H,dh)
    "h": ("layers", "batch", "heads", None, None),
    "S": ("layers", "batch", "heads", None, None),
    "c": ("layers", "batch", "heads", None),
    "n": ("layers", "batch", "heads", None),
    "m": ("layers", "batch", "heads", None),
}


def cache_shardings(cache: Pytree, mesh: Mesh) -> Pytree:
    """NamedSharding tree for a decode cache under ``mesh``."""

    def one(path_entries, leaf):
        name = str(getattr(path_entries[-1], "key", path_entries[-1]))
        axes = _CACHE_AXES.get(name)
        if axes is not None and len(axes) != leaf.ndim and leaf.ndim >= 3:
            axes = ("layers", "batch", "heads") + (None,) * (leaf.ndim - 3)
        if axes is None or len(axes) != leaf.ndim:
            axes = ("layers", "batch") + (None,) * (leaf.ndim - 2)
        if shd.current_mesh() is None:
            with shd.use_mesh(mesh):
                spec = shd.resolve(axes)
        else:  # inherit the caller's rule overrides (e.g. long_500k)
            spec = shd.resolve(axes)
        spec = shd.sanitize_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache)
