"""Serving engine: continuous batching over a fixed slot pool, hardened.

vLLM-style scheduling reduced to its JAX-native core: a fixed decode batch
of ``max_slots`` sequences; finished sequences free their slot; waiting
requests are admitted by prefilling into the freed slot. Slot bookkeeping
(free-slot compaction) routes through ``repro.relational.compact`` — an
exclusive prefix sum over the free bitmap packs the free slot ids to the
front, the paper's stream-compaction use case running the engine.

The decode step is ONE jitted call for the whole pool (padded, masked);
prefill is a second jitted call per admitted request batch. ``cache_len``
is threaded as a per-row (B,) vector so each slot gets its own RoPE
positions and masking extent — a row's output never depends on who else
occupies the pool, which is what lets the chaos wall demand bitwise
identity for undisturbed requests.

Request lifecycle (this file's contract — see README "Serving under
failure"): every submitted request terminates with exactly ONE
``finish_reason`` from :data:`repro.serve.stats.FINISH_REASONS`; none is
lost or duplicated. The hardening layers:

  * admission control — bounded waiting queue with a reject-vs-block
    policy; prompts that cannot fit (``S + budget > max_len`` under
    ``strict_admission``) are failed fast as ``rejected`` instead of
    silently corrupting the cache;
  * deadlines — per-request tick TTLs finish overdue requests with
    ``deadline``; host-side :meth:`Engine.cancel` finishes ``cancelled``;
  * step-failure recovery — bookkeeping is only committed after a
    successful tick; exceptions from the jitted step are retried with
    backoff, then the active set is bisected with probe calls to
    quarantine the poison request (finished ``error``) so one bad
    sequence never takes down the pool;
  * numeric degradation ladder — non-finite logits on any ACTIVE row
    roll the tick back and re-run it once on the safe route (dense
    attention, ``chunked`` reference scan); persistent non-finite ticks
    are skipped (trainer NaN-guard parity) and eventually quarantined.

By default the decode cache is NOT donated (``donate_cache=False``): the
pre-tick cache stays alive so a rolled-back tick is a no-op. Donation
(``donate_cache=True``) restores the zero-copy fast path but narrows
recovery — when the pre-tick buffers are gone the engine adopts the
written cache and skips the advance, which self-heals attention caches
(next tick overwrites the same positions) but is documented lossy for
recurrent (ssm/xlstm) state.

Fault injection (``serve/faults.py``) hooks the two jitted entry points;
the safe route is deliberately un-wrapped so the ladder escapes the
injector the way a real fallback kernel escapes a broken primary one.

Cache layouts (``EngineConfig.cache_layout``): the default
``"contiguous"`` layout reserves one padded ``max_len`` KV row per slot;
``"paged"`` replaces the rows with a shared page pool plus per-slot page
tables (``serve/paging.py``) so HBM scales with ACTUAL sequence length
— the same cache-memory budget admits strictly more concurrent
sequences. Under paging ``finish_reason="cache_full"`` means the
ALLOCATOR is exhausted (pool empty), and admission applies backpressure
(the request waits) instead of reserving worst-case rows up front.
Decode under either layout is bitwise identical at equal configs.
``prefill_chunk`` additionally stages long prompts one chunk per tick
so resident decodes interleave instead of stalling behind a monolithic
prefill.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scan import policy as scan_policy
from repro.models.config import ModelConfig
from repro.obs import trace
from repro.obs.metrics import Registry
from repro.relational import compact as rel_compact
from repro.serve import paging
from repro.serve.faults import StepContext
from repro.serve.sampling import sample_logits
from repro.serve.stats import FINISH_REASONS, EngineStats
from repro.serve.steps import (bucket_len, bucketable, init_cache_for,
                               init_paged_cache_for,
                               make_bucketed_prefill_fn,
                               make_chunked_prefill_fn, make_paged_serve_step,
                               make_prefill_fn, make_serve_step)

Pytree = Any


class AdmissionError(RuntimeError):
    """Raised by ``submit(..., strict=True)`` when a request is rejected."""


class EngineStepError(RuntimeError):
    """A decode step failed unrecoverably (ambient / non-isolatable)."""


class EngineDeadlineError(TimeoutError):
    """``run_to_completion`` exhausted ``max_ticks`` under ``strict``."""


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0       # greedy default
    top_p: float = 1.0
    eos_id: int = 1
    seed: int = 0
    # Prefill attention route: ``attn_impl="flash"`` runs prompt attention
    # on the engine-backed flash fold, ``attn_schedule`` its grid
    # organization (carry | decoupled | auto — policy decides; the long-KV
    # class lands on the split-KV decoupled form).
    attn_impl: Optional[str] = None
    attn_schedule: str = "auto"
    # SSM decode route ("auto" | "chunked" | "kernel"); the degradation
    # ladder's safe route always pins "chunked".
    ssm_impl: str = "auto"

    # -- admission ------------------------------------------------------
    max_waiting: Optional[int] = None   # bound on the waiting queue
    admission_policy: str = "reject"    # "reject" | "block" on full queue
    strict_admission: bool = True       # reject S + budget > max_len
    # -- deadlines ------------------------------------------------------
    deadline_ticks: Optional[int] = None  # default per-request tick TTL
    strict_deadlines: bool = False        # run_to_completion raises
    # -- failure recovery ----------------------------------------------
    max_step_retries: int = 2
    retry_backoff_s: float = 0.0
    # -- numeric ladder -------------------------------------------------
    degrade_on_nonfinite: bool = True
    max_consecutive_nan_ticks: int = 3
    # -- cache / compile hygiene ----------------------------------------
    donate_cache: bool = False          # True = fast path, narrower recovery
    bucket_prompts: bool = True         # pad prompts to pow2 buckets
    max_prefill_variants: int = 8       # LRU cap on jitted prefill shapes
    slow_tick_s: Optional[float] = None  # wall-clock SLO; over -> slow_ticks
    # -- paged KV cache (serve/paging.py) -------------------------------
    cache_layout: str = "contiguous"    # "contiguous" | "paged" | "auto"
    page_size: int = 16                 # tokens per KV page
    num_pages: Optional[int] = None     # pool size; None = worst case + null
    prefill_chunk: Optional[int] = None  # stage long prompts N tokens/tick
    # -- copy-on-write prefix sharing (paged only) ----------------------
    share_prefixes: bool = False        # map common prompt prefixes via COW
    prefix_cache_pages: int = 32        # LRU entry cap on the registry
    # -- auto-defrag policy (paged only) --------------------------------
    auto_defrag: bool = True            # policy.choose_defrag on the tick
    defrag_threshold: float = 0.5       # fragmentation gauge trigger
    defrag_cooldown: int = 8            # min ticks between auto defrags

    def __post_init__(self):
        if self.admission_policy not in ("reject", "block"):
            raise ValueError(
                f"admission_policy must be 'reject' or 'block', "
                f"got {self.admission_policy!r}")
        if self.cache_layout not in ("contiguous", "paged", "auto"):
            raise ValueError(
                f"cache_layout must be 'contiguous', 'paged' or 'auto', "
                f"got {self.cache_layout!r}")
        if self.page_size < 1:
            raise ValueError(f"page_size={self.page_size} < 1")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk={self.prefill_chunk} < 1")
        if self.prefix_cache_pages < 1:
            raise ValueError(
                f"prefix_cache_pages={self.prefix_cache_pages} < 1")
        if self.defrag_cooldown < 1:
            raise ValueError(f"defrag_cooldown={self.defrag_cooldown} < 1")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: Optional[int] = None
    deadline_ticks: Optional[int] = None  # overrides EngineConfig TTL
    # filled by the engine:
    output: Optional[list] = None
    finish_reason: Optional[str] = None   # one of FINISH_REASONS when done
    error: Optional[str] = None           # detail for error/rejected
    submit_tick: int = -1
    finish_tick: int = -1
    degraded: bool = False                # served (partly) on the safe route

    @property
    def done(self) -> bool:
        return self.finish_reason is not None


# Step fns are memoized globally: ``ModelConfig`` is a frozen (hashable)
# dataclass, so engines sharing an architecture share ONE jitted step and
# its traced executables instead of recompiling per Engine (the chaos
# wall builds a dozen engines over the same tiny model).
_STEP_JIT: Dict[tuple, Any] = {}


def _jit_step(cfg: ModelConfig, ssm_impl: Optional[str], donate: bool,
              paged: bool = False):
    key = (cfg, ssm_impl, donate, paged)
    if key not in _STEP_JIT:
        fn = (make_paged_serve_step(cfg, ssm_impl=ssm_impl) if paged
              else make_serve_step(cfg, ssm_impl=ssm_impl))
        _STEP_JIT[key] = (jax.jit(fn, donate_argnums=(2,)) if donate
                          else jax.jit(fn))
    return _STEP_JIT[key]


class Engine:
    def __init__(self, params: Pytree, cfg: ModelConfig, ecfg: EngineConfig,
                 injector: Any = None,
                 metrics: Optional[Registry] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.injector = injector
        # ``metrics`` mirrors EngineStats into an obs registry (one
        # surface for dashboards + chaos invariants); None = stats only.
        self.stats = EngineStats().attach(metrics)
        self.metrics = metrics
        self.key = jax.random.PRNGKey(ecfg.seed)

        # Cache layout: "auto" asks the policy layer (budget below the
        # worst case, or typical lengths far under max_len -> paged).
        layout = ecfg.cache_layout
        if layout == "auto":
            layout = scan_policy.choose_cache_layout(
                ecfg.max_slots, ecfg.max_len, ecfg.page_size,
                num_pages=ecfg.num_pages)
        self.cache_layout = layout
        self._paged = layout == "paged"

        ssm_primary = None if ecfg.ssm_impl == "auto" else ecfg.ssm_impl
        self._step = _jit_step(cfg, ssm_primary, donate=ecfg.donate_cache,
                               paged=self._paged)
        self._step_nodonate = _jit_step(cfg, ssm_primary, donate=False,
                                        paged=self._paged)
        # The SAFE route: dense attention (decode is dense already) and
        # the jnp reference scan for SSM layers; never injector-wrapped.
        self._step_safe = _jit_step(cfg, "chunked", donate=False,
                                    paged=self._paged)
        self._wstep = (injector.wrap_step(self._step) if injector
                       else self._step)
        self._wstep_probe = (injector.wrap_step(self._step_nodonate)
                             if injector else self._step_nodonate)
        # Whether the safe route changes numerics vs the primary one.
        has_recurrent = any(k in ("mamba", "mlstm", "slstm")
                            for k in cfg.layer_pattern)
        self._prefill_safe_differs = ecfg.attn_impl is not None or (
            has_recurrent and ecfg.ssm_impl == "kernel")
        self._decode_safe_differs = (
            has_recurrent and ecfg.ssm_impl == "kernel")

        self._bucketed = (ecfg.bucket_prompts and bucketable(cfg))
        self._prefill_cache: "OrderedDict[tuple, Any]" = OrderedDict()
        self._tick = 0
        self._nan_streak = 0

        # Chunked prefill shares bucketing's gate (pads in the staging
        # cache must be inert: pure global-attention stacks only).
        self._chunk_ok = ecfg.prefill_chunk is not None and bucketable(cfg)
        self._chunk_job: Optional[dict] = None

        B, L = ecfg.max_slots, ecfg.max_len
        if self._paged:
            # Geometry/layer-support problems (incl. sliding-window ring
            # extents vs page_size) fail HERE with the offending layer
            # named, not mid-jit-trace.
            paging.validate_paged_support(cfg, L, ecfg.page_size)
            self._paged_names = paging.paged_layer_names(cfg)
            self._local_names = frozenset(
                n for n in self._paged_names if n.endswith("_local"))
            self._ring_pages = (
                min(int(cfg.sliding_window), L) // ecfg.page_size
                if self._local_names else 0)
            n_pages = (ecfg.num_pages if ecfg.num_pages is not None
                       else B * (L // ecfg.page_size) + 1)
            self.allocator: Optional[paging.PageAllocator] = \
                paging.PageAllocator(n_pages, ecfg.page_size,
                                     stats=self.stats, metrics=metrics)
            self.ptable: Optional[paging.PageTable] = \
                paging.PageTable(B, L // ecfg.page_size)
            self.cache = init_paged_cache_for(cfg, B, L, ecfg.page_size,
                                              n_pages)
        else:
            self._paged_names = ()
            self._local_names = frozenset()
            self.allocator = None
            self.ptable = None
            self.cache = init_cache_for(cfg, B, L)
        # Copy-on-write prefix sharing: the registry maps prompt-prefix
        # chunks to live physical pages. Gated on ``bucketable`` (pure
        # global-attention stacks) for the same reason bucketing and
        # chunked prefill are: the suffix-only prefill stages through a
        # contiguous cache whose pads must be inert, and a local ring
        # that has wrapped is no longer prefix-pristine.
        self.registry: Optional[paging.PrefixRegistry] = None
        if self._paged and ecfg.share_prefixes:
            if not bucketable(cfg):
                raise ValueError(
                    "share_prefixes requires a pure global-attention "
                    f"decoder (bucketable); pattern {cfg.layer_pattern!r} "
                    "is not")
            self.registry = paging.PrefixRegistry(
                self.allocator, ecfg.page_size,
                capacity=ecfg.prefix_cache_pages)
        self._last_defrag = -(10 ** 9)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = np.zeros(B, np.int64)          # per-slot position
        self.budgets = np.zeros(B, np.int64)          # remaining new tokens
        self.slot_req: list[Optional[Request]] = [None] * B
        self.waiting: list[Request] = []
        self.finished: list[Request] = []

    # -- slot bookkeeping (scan-based compaction) -----------------------
    def _free_slots(self) -> np.ndarray:
        # A staging chunked-prefill job holds its destination slot so
        # admission cannot hand it out before the job finalizes.
        held = self._chunk_job["slot"] if self._chunk_job is not None else -1
        free = np.array([r is None and i != held
                         for i, r in enumerate(self.slot_req)], np.int32)
        # Stream compaction over the free bitmap (paper §1: "new offsets
        # during a partitioning step"): ONE mask scan inside
        # filter_compact packs the free slot ids to the front. The
        # per-slot ranks are part of the bookkeeping contract (see
        # test_free_slot_compaction_ranks); the host cumsum avoids a
        # second device scan for them.
        slots, count = rel_compact.filter_compact(
            jnp.arange(free.size, dtype=jnp.int32),
            jnp.asarray(free, bool))
        ranks = np.cumsum(free) - free
        return np.asarray(slots)[: int(count)], ranks

    # -- lifecycle ------------------------------------------------------
    def _finish(self, req: Request, reason: str,
                error: Optional[str] = None) -> None:
        """The ONLY way a request terminates: exactly one finish reason."""
        assert req.finish_reason is None, (
            f"request {req.rid} finished twice: "
            f"{req.finish_reason!r} then {reason!r}")
        assert reason in FINISH_REASONS
        req.finish_reason = reason
        req.error = error
        req.finish_tick = self._tick
        self.stats.record_finish(reason)
        self.finished.append(req)
        trace.instant("serve.request.finish", rid=req.rid, reason=reason,
                      tick=self._tick, tokens=len(req.output or ()),
                      degraded=req.degraded, error=error)

    def _budget_of(self, req: Request) -> int:
        return (req.max_new_tokens if req.max_new_tokens is not None
                else self.ecfg.max_new_tokens)

    # -- admission ------------------------------------------------------
    def submit(self, req: Request, strict: bool = False) -> bool:
        """Queue a request. Returns False (or raises under ``strict``)
        when admission control rejects it — the request is then already
        finished with ``finish_reason="rejected"``."""
        self.stats.submitted += 1
        req.output = []
        req.submit_tick = self._tick
        trace.instant("serve.request.submit", rid=req.rid,
                      prompt_len=int(np.asarray(req.prompt).size),
                      tick=self._tick)
        reason = self._validate(req)
        if reason is None and self.ecfg.max_waiting is not None:
            if self.ecfg.admission_policy == "block":
                # Drive the engine until the queue drains below the bound
                # (single-threaded stand-in for a blocking producer).
                guard = 0
                while (len(self.waiting) >= self.ecfg.max_waiting
                       and guard < 100_000):
                    if self.step() == 0 and not self.waiting:
                        break
                    guard += 1
            if len(self.waiting) >= self.ecfg.max_waiting:
                reason = (f"waiting queue full "
                          f"({len(self.waiting)}/{self.ecfg.max_waiting})")
        if reason is not None:
            self._finish(req, "rejected", error=reason)
            if strict:
                raise AdmissionError(f"request {req.rid}: {reason}")
            return False
        self.waiting.append(req)
        self.stats.observe_queue(len(self.waiting))
        return True

    def _validate(self, req: Request) -> Optional[str]:
        S = int(np.asarray(req.prompt).shape[0])
        budget = self._budget_of(req)
        if S < 1:
            return "empty prompt"
        if budget < 1:
            return f"max_new_tokens={budget} < 1"
        if S + 1 > self.ecfg.max_len:
            return (f"prompt length {S} cannot fit max_len="
                    f"{self.ecfg.max_len}")
        if self.ecfg.strict_admission and S + budget > self.ecfg.max_len:
            return (f"prompt {S} + budget {budget} > max_len="
                    f"{self.ecfg.max_len} cannot complete")
        return None

    def cancel(self, rid: int) -> bool:
        """Host-side cancel: finishes the request with ``cancelled``."""
        for i, req in enumerate(self.waiting):
            if req.rid == rid:
                self.waiting.pop(i)
                self._finish(req, "cancelled")
                self.stats.observe_queue(len(self.waiting))
                return True
        if (self._chunk_job is not None
                and self._chunk_job["req"].rid == rid):
            req = self._chunk_job["req"]
            self._chunk_job = None
            self._finish(req, "cancelled")
            return True
        for slot, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                self._release(slot)
                self._finish(req, "cancelled")
                return True
        return False

    def _release(self, slot: int) -> None:
        if self._paged and int(self.ptable.counts[slot]):
            # Host bookkeeping only; the device page table is refreshed
            # once per tick (in _ensure_pages) before the decode step
            # reads it, so a freed-then-reallocated page is never
            # reachable through a stale table row.
            self.allocator.release(self.ptable.release(slot))
        self.slot_req[slot] = None
        self.lengths[slot] = 0
        self.budgets[slot] = 0

    def _admit(self) -> None:
        self._advance_chunk_job()
        free_idx, _ = self._free_slots()
        free_list = [int(i) for i in free_idx]
        while self.waiting and free_list:
            req = self.waiting[0]
            S = int(np.asarray(req.prompt).shape[0])
            shared = (self.registry.match(np.asarray(req.prompt))
                      if self.registry is not None else [])
            if self._paged:
                need = paging.pages_for(S, self.ecfg.page_size) - len(shared)
                if need > self.allocator.free_count:
                    # Allocator exhausted: admission BACKPRESSURE. The
                    # request stays queued (FIFO order preserved) until
                    # decode finishes free pages — the paged analogue of
                    # waiting for a free slot, replacing the contiguous
                    # layout's up-front worst-case reservation.
                    trace.instant("serve.admit.backpressure", rid=req.rid,
                                  want=need,
                                  free=self.allocator.free_count)
                    break
            self.waiting.pop(0)
            self.stats.observe_queue(len(self.waiting))
            self.stats.admitted += 1
            out = None
            if shared:
                out = self._prefill_shared(req, shared)
                if out is None:
                    shared = []               # fall back to a full prefill
            if out is None and self._chunkable(req, S):
                self._chunk_job = {
                    "req": req, "slot": free_list.pop(0), "pos": 0,
                    "cache": init_cache_for(self.cfg, 1, self.ecfg.max_len),
                }
                trace.instant("serve.prefill.chunk_start", rid=req.rid,
                              prompt_len=S, chunk=self.ecfg.prefill_chunk)
                continue
            if out is None:
                out = self._prefill_request(req)
            if out is None:
                continue                      # finished "error" inside
            logits, cache1 = out
            self._install(free_list.pop(0), req, logits, cache1,
                          shared=shared)

    def _install(self, slot: int, req: Request, logits, cache1,
                 shared=()) -> None:
        """Commit a completed prefill into ``slot``: copy/page its cache
        row into the pool, sample the first token, and apply the
        admission-time finish checks. Shared by one-shot admission,
        chunked-prefill finalize and the prefix-sharing path (``shared``
        = registry pages already holding the matched prompt prefix; only
        the remainder is freshly allocated and scattered)."""
        S = int(np.asarray(req.prompt).shape[0])
        if self._paged:
            shared_arr = np.asarray(shared, np.int64)
            m = int(shared_arr.size)
            total = paging.pages_for(S, self.ecfg.page_size)
            got = self.allocator.alloc([total - m])
            if got is None:
                # Pages vanished between precheck and install (decode
                # growth during a chunked prefill): backpressure — back
                # to the head of the queue with the staging work
                # discarded. Nothing was retained yet.
                self.waiting.insert(0, req)
                self.stats.observe_queue(len(self.waiting))
                return
            fresh = got[0]
            if m:
                self.allocator.retain(shared_arr)
                self.stats.prefix_hits += 1
                self.stats.shared_page_maps += m
                trace.instant("serve.pages.prefix_hit", rid=req.rid,
                              shared=m, fresh=int(fresh.size))
            pages = np.concatenate([shared_arr, fresh])
            self.ptable.assign(slot, pages)
            layers = {}
            for name, leaf in self.cache["layers"].items():
                if name in self._paged_names:
                    kv, one = leaf["kv"], cache1[name]["kv"]
                    if name in self._local_names:
                        # Local (sliding-window) layer: the staging row
                        # is the ring buffer itself; ring slot s lives
                        # in logical page s // ps, so the ring maps onto
                        # the row's first ring_pages entries. (Sharing
                        # is gated off for hybrid patterns: m == 0.)
                        lp = pages[: min(self._ring_pages, pages.size)]
                        start = 0
                    else:
                        lp, start = fresh, m
                    layers[name] = {"kv": {
                        "k_pages": paging.scatter_prefix(
                            kv["k_pages"], one["k"], lp, start),
                        "v_pages": paging.scatter_prefix(
                            kv["v_pages"], one["v"], lp, start),
                    }}
                else:
                    layers[name] = jax.tree.map(
                        lambda pool, one_: _scatter_row(
                            pool, one_.astype(pool.dtype), slot),
                        leaf, cache1[name])
            self.cache = {"layers": layers,
                          "page_table": self.cache["page_table"]}
            if self.registry is not None:
                self.registry.register(np.asarray(req.prompt), pages)
        else:
            # Copy the single-row prefill cache into the pool at `slot`
            # (cache leaves are (layers, batch, ...); prefill batch = 1).
            self.cache = jax.tree.map(
                lambda pool, one: _scatter_row(pool, one.astype(pool.dtype),
                                               slot),
                self.cache, cache1)
        first = self._sample(logits)[0]
        req.output.append(int(first))
        self.stats.tokens_generated += 1
        budget = self._budget_of(req) - 1
        if int(first) == self.ecfg.eos_id:
            reason = "eos"
        elif budget <= 0:
            reason = "length_budget"
        elif S + 1 >= self.ecfg.max_len:
            self._warn_cache_full(req)
            reason = "cache_full"
        else:
            self.tokens = self.tokens.at[slot, 0].set(first)
            self.lengths[slot] = S
            self.budgets[slot] = budget
            self.slot_req[slot] = req
            return
        if self._paged:
            self._release(slot)               # returns the fresh pages
        self._finish(req, reason)

    # -- chunked prefill (one chunk per tick) ---------------------------
    def _chunkable(self, req: Request, S: int) -> bool:
        C = self.ecfg.prefill_chunk
        return (self._chunk_ok and self._chunk_job is None
                and C is not None and S > C
                and not getattr(req, "_no_chunk", False))

    def _advance_chunk_job(self) -> None:
        """Run ONE chunk of the staged long-prompt prefill, so decode
        ticks for resident slots interleave with the long prompt instead
        of stalling behind a monolithic prefill. The staging cache is
        contiguous (single row); pages are only claimed at finalize."""
        job = self._chunk_job
        if job is None:
            return
        req = job["req"]
        C = int(self.ecfg.prefill_chunk)
        prompt = np.asarray(req.prompt)
        S = int(prompt.size)
        lo = int(job["pos"])
        hi = min(lo + C, S)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, : hi - lo] = prompt[lo:hi]
        fn = self._chunk_prefill_fn()
        if self.injector is not None:
            self.injector.begin(StepContext(
                tick=self._tick, rids=(req.rid,), op="prefill"))
        try:
            with trace.span("serve.prefill.chunk", rid=req.rid,
                            lo=lo, hi=hi, tick=self._tick):
                logits, cache = fn(self.params, jnp.asarray(chunk),
                                   job["cache"], jnp.asarray(lo, jnp.int32),
                                   jnp.asarray(hi - lo, jnp.int32))
            self.stats.prefill_chunks += 1
        except Exception as e:                # noqa: BLE001 — jitted call
            # The chunk route carries no retry ladder of its own: fall
            # back to the one-shot path, which has retry + degrade.
            self.stats.prefill_retries += 1
            req._no_chunk = True
            self._chunk_job = None
            self.waiting.insert(0, req)
            self.stats.observe_queue(len(self.waiting))
            trace.instant("serve.prefill.chunk_abort", rid=req.rid,
                          error=repr(e))
            return
        job["cache"], job["pos"] = cache, hi
        if hi < S:
            return
        self._chunk_job = None
        if not np.isfinite(np.asarray(logits)).all():
            self.stats.nonfinite_ticks += 1
            req._no_chunk = True
            self.waiting.insert(0, req)
            self.stats.observe_queue(len(self.waiting))
            trace.instant("serve.prefill.chunk_abort", rid=req.rid,
                          error="non-finite logits")
            return
        self._install(job["slot"], req, logits, cache)

    def _chunk_prefill_fn(self, width: Optional[int] = None):
        """Jitted mid-stream prefill at chunk ``width`` (default: the
        configured ``prefill_chunk``). One LRU-cached variant per width
        — the prefix-sharing suffix path reuses the same cache, so a
        suffix whose bucket matches the chunk width shares the
        executable."""
        key = ("chunk", int(width or self.ecfg.prefill_chunk))
        if key in self._prefill_cache:
            self._prefill_cache.move_to_end(key)
            return self._prefill_cache[key]
        fn = jax.jit(make_chunked_prefill_fn(
            self.cfg, self.ecfg.max_len,
            attn_impl=self.ecfg.attn_impl,
            attn_schedule=self.ecfg.attn_schedule))
        if self.injector is not None:
            fn = self.injector.wrap_prefill(fn)
        self._prefill_cache[key] = fn
        self.stats.prefill_compiles += 1
        while len(self._prefill_cache) > self.ecfg.max_prefill_variants:
            self._prefill_cache.popitem(last=False)
            self.stats.prefill_cache_evictions += 1
        return self._prefill_cache[key]

    # -- copy-on-write prefix sharing ------------------------------------
    def _prefill_shared(self, req: Request, shared):
        """Prefill only the UNMATCHED suffix of a prompt whose prefix
        already lives in registry pages.

        The matched pages are gathered into a single-row contiguous
        staging cache (positions [0, T) hold the donor's KV bitwise),
        then the suffix runs through the chunked-prefill fn with
        ``cache_len = start`` — the same machinery whose chunked-vs-one-
        shot bitwise parity landed in PR 8, sharing its jit LRU cache.
        At least one token is always recomputed (sampling needs the
        last-token logits), and ``_install`` scatters only the fresh
        pages back — recomputed KV inside matched pages is bitwise equal
        and discarded. Returns ``(logits, staging_cache)`` or None to
        fall back to a full prefill.
        """
        ps = self.ecfg.page_size
        L = self.ecfg.max_len
        prompt = np.asarray(req.prompt)
        S = int(prompt.size)
        T = min(len(shared) * ps, S)       # prompt tokens the pages cover
        start = min(T, S - 1)              # always recompute >= 1 token
        n_suf = S - start
        C = min(bucket_len(n_suf, L) if self._bucketed else n_suf,
                L - start)                  # keep the cache write in-bounds
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n_suf] = prompt[start:]
        pt_row = np.zeros((1, L // ps), np.int32)
        pt_row[0, : len(shared)] = shared
        pt_dev = jnp.asarray(pt_row)
        staged = init_cache_for(self.cfg, 1, L)
        for name in self._paged_names:
            pool = self.cache["layers"][name]["kv"]
            staged[name] = {"kv": {
                "k": paging.gather_prefix(pool["k_pages"], pt_dev),
                "v": paging.gather_prefix(pool["v_pages"], pt_dev),
            }}
        fn = self._chunk_prefill_fn(C)
        if self.injector is not None:
            self.injector.begin(StepContext(
                tick=self._tick, rids=(req.rid,), op="prefill"))
        try:
            with trace.span("serve.prefill.shared", rid=req.rid,
                            matched=T, suffix=n_suf, tick=self._tick):
                logits, cache1 = fn(
                    self.params, jnp.asarray(chunk), staged,
                    jnp.asarray(start, jnp.int32),
                    jnp.asarray(n_suf, jnp.int32))
            if not np.isfinite(np.asarray(logits)).all():
                raise FloatingPointError("non-finite shared-prefill logits")
        except Exception as e:            # noqa: BLE001 — jitted call
            # No retry ladder of its own: drop the sharing attempt and
            # let the one-shot path (retry + degrade) take over.
            self.stats.prefill_retries += 1
            trace.instant("serve.prefill.shared_abort", rid=req.rid,
                          error=repr(e))
            return None
        return logits, cache1

    def _cow_writes(self) -> None:
        """Copy-on-write, the host half: BEFORE the decode step, any
        active row whose next write lands in a page with refcount > 1
        gets a private copy of that page (device copy, table repoint,
        reference drop on the original). Sequential per slot, so two
        sharers hitting the same page in one tick each get their own
        copy. Refcounts only exceed 1 via the prefix registry, so this
        scan is skipped entirely when sharing is off."""
        if self.registry is None:
            return
        ps = self.ecfg.page_size
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            entry = int(self.lengths[slot]) // ps
            page = int(self.ptable.table[slot, entry])
            if page == 0 or int(self.allocator.refcount[page]) <= 1:
                continue
            got = self.allocator.alloc([1])
            if got is None:
                # Pool exhausted at the copy point: same terminal state
                # as growth exhaustion.
                self._warn_cache_full(req)
                self._release(slot)
                self._finish(req, "cache_full")
                continue
            new = int(got[0][0])
            layers = {}
            for name, leaf in self.cache["layers"].items():
                if name in self._paged_names:
                    kv = leaf["kv"]
                    layers[name] = {"kv": {
                        "k_pages": kv["k_pages"].at[:, new].set(
                            kv["k_pages"][:, page]),
                        "v_pages": kv["v_pages"].at[:, new].set(
                            kv["v_pages"][:, page]),
                    }}
                else:
                    layers[name] = leaf
            self.cache = {"layers": layers,
                          "page_table": self.cache["page_table"]}
            self.ptable.table[slot, entry] = new
            self.allocator.release(np.array([page]))   # drop our reference
            self.stats.refcount_copies += 1
            trace.instant("serve.pages.cow_copy", rid=req.rid, slot=slot,
                          src=page, dst=new)

    # -- paged bookkeeping ----------------------------------------------
    def _sync_page_table(self) -> None:
        self.cache = {"layers": self.cache["layers"],
                      "page_table": self.ptable.device()}

    def _ensure_pages(self) -> None:
        """Grow each active slot's page list to cover its next decode
        write; allocator exhaustion MID-decode finishes the victim with
        ``cache_full`` (the paged meaning: pool empty, not row full).
        Ends by refreshing the device page table — the single sync point
        per tick, before the decode step reads it."""
        if not self._paged:
            return
        ps = self.ecfg.page_size
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            need = paging.pages_for(int(self.lengths[slot]), ps)
            have = int(self.ptable.counts[slot])
            if need <= have:
                continue
            got = self.allocator.alloc([need - have])
            if got is None:
                self._warn_cache_full(req)
                self._release(slot)
                self._finish(req, "cache_full")
                continue
            self.ptable.assign(slot, got[0])
        self._cow_writes()
        self._sync_page_table()

    def defrag(self) -> int:
        """Compact live pages to the front of the pool: one stable
        partition-by-liveness permutation (``PageAllocator.defrag_plan``)
        applied to the pools, the page table, and the free bitmap.
        Decode output is unchanged — the gathered view is invariant
        under page renaming. Returns the number of live pages moved."""
        if not self._paged:
            raise ValueError("defrag() requires cache_layout='paged'")
        dest = self.allocator.defrag_plan()
        d = jnp.asarray(dest, jnp.int32)
        layers = {}
        for name, leaf in self.cache["layers"].items():
            if name in self._paged_names:
                kv = leaf["kv"]
                layers[name] = {"kv": {
                    "k_pages": jnp.zeros_like(kv["k_pages"])
                               .at[:, d].set(kv["k_pages"]),
                    "v_pages": jnp.zeros_like(kv["v_pages"])
                               .at[:, d].set(kv["v_pages"]),
                }}
            else:
                layers[name] = leaf
        self.cache = {"layers": layers,
                      "page_table": self.cache["page_table"]}
        self.ptable.remap(dest)
        if self.registry is not None:
            self.registry.remap(dest)
        moved = self.allocator.apply_defrag(dest)
        self._sync_page_table()
        return moved

    def _maybe_defrag(self) -> None:
        """Auto-defrag: ask ``policy.choose_defrag`` (fragmentation
        gauge + free-run length) once per cooldown window and compact
        when it says so — fragmentation self-heals instead of waiting
        for a host call to ``defrag()``. Bitwise-free: the gathered view
        is invariant under page renaming."""
        if (not self._paged or not self.ecfg.auto_defrag
                or self._tick - self._last_defrag
                < self.ecfg.defrag_cooldown):
            return
        if not scan_policy.choose_defrag(
                self.allocator.fragmentation(),
                self.allocator.free_count,
                self.allocator.longest_free_run(),
                threshold=self.ecfg.defrag_threshold):
            return
        self._last_defrag = self._tick
        self.stats.auto_defrags += 1
        self.defrag()

    def _prefill_request(self, req: Request):
        """Run prefill for one request with retry + degrade. Returns
        ``(logits, cache)`` or None after finishing the request."""
        with trace.span("serve.prefill", rid=req.rid, tick=self._tick,
                        prompt_len=int(np.asarray(req.prompt).size)):
            return self._prefill_request_inner(req)

    def _prefill_request_inner(self, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        S = prompt.shape[1]
        fn, padded, extra = self._prefill_call(prompt, int(S))
        last_err: Optional[BaseException] = None
        for attempt in range(self.ecfg.max_step_retries + 1):
            if self.injector is not None:
                self.injector.begin(StepContext(
                    tick=self._tick, rids=(req.rid,), op="prefill"))
            try:
                out = fn(self.params, padded, *extra)
                logits = out[0]
                if np.isfinite(np.asarray(logits)).all():
                    return logits, out[1]
                self.stats.nonfinite_ticks += 1
                last_err = FloatingPointError("non-finite prefill logits")
            except Exception as e:            # noqa: BLE001 — jitted call
                last_err = e
            if attempt < self.ecfg.max_step_retries:
                self.stats.prefill_retries += 1
                if self.ecfg.retry_backoff_s:
                    time.sleep(self.ecfg.retry_backoff_s * (attempt + 1))
        # Primary route exhausted -> safe route (un-wrapped, reference
        # impls). Mark the request degraded only if the numerics differ.
        if self.ecfg.degrade_on_nonfinite or not isinstance(
                last_err, FloatingPointError):
            try:
                sfn = self._prefill_for(int(S), safe=True)
                logits, cache1 = sfn(self.params, prompt)
                if np.isfinite(np.asarray(logits)).all():
                    self.stats.degradations += 1
                    if self._prefill_safe_differs or self._bucketed:
                        req.degraded = True
                    return logits, cache1
                last_err = FloatingPointError(
                    "non-finite prefill logits on safe route")
            except Exception as e:            # noqa: BLE001
                last_err = e
        self._finish(req, "error", error=f"prefill failed: {last_err!r}")
        return None

    def _prefill_call(self, prompt: jax.Array, S: int):
        """Pick the primary prefill callable + its padded inputs."""
        if self._bucketed:
            Sb = bucket_len(S, self.ecfg.max_len)
            fn = self._prefill_for(Sb, bucketed=True)
            padded = jnp.pad(prompt, ((0, 0), (0, Sb - S)))
            return fn, padded, (jnp.asarray(S, jnp.int32),)
        return self._prefill_for(S), prompt, ()

    def _prefill_for(self, S: int, bucketed: bool = False,
                     safe: bool = False):
        """LRU-capped per-shape jitted prefill. With bucketing, distinct
        shapes grow as log2(max_len) instead of one per prompt length;
        the LRU cap bounds live executables either way."""
        key = (S, bucketed, safe)
        if key in self._prefill_cache:
            self._prefill_cache.move_to_end(key)
            return self._prefill_cache[key]
        if safe:
            fn = jax.jit(make_prefill_fn(
                self.cfg, self.ecfg.max_len, attn_impl=None,
                ssm_impl="chunked"))
        elif bucketed:
            fn = jax.jit(make_bucketed_prefill_fn(
                self.cfg, self.ecfg.max_len,
                attn_impl=self.ecfg.attn_impl,
                attn_schedule=self.ecfg.attn_schedule))
        else:
            fn = jax.jit(make_prefill_fn(
                self.cfg, self.ecfg.max_len,
                attn_impl=self.ecfg.attn_impl,
                attn_schedule=self.ecfg.attn_schedule))
        if self.injector is not None and not safe:
            # injector.begin() is issued per-attempt in _prefill_request;
            # wrapping here keeps one wrapper per cached variant.
            fn = self.injector.wrap_prefill(fn)
        self._prefill_cache[key] = fn
        self.stats.prefill_compiles += 1
        while len(self._prefill_cache) > self.ecfg.max_prefill_variants:
            self._prefill_cache.popitem(last=False)
            self.stats.prefill_cache_evictions += 1
        return self._prefill_cache[key]

    def _sample(self, logits: jax.Array) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sample_logits(sub, logits, self.ecfg.temperature,
                             self.ecfg.top_p)

    # -- decode ---------------------------------------------------------
    def _active(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _ctx(self, active: List[int], op: str = "step"):
        rows = {self.slot_req[i].rid: i for i in active}
        return StepContext(tick=self._tick, rids=tuple(rows), op=op,
                           rows=rows)

    def _expire_deadlines(self) -> None:
        ttl_default = self.ecfg.deadline_ticks
        for req in list(self.waiting):
            ttl = (req.deadline_ticks if req.deadline_ticks is not None
                   else ttl_default)
            if ttl is not None and self._tick - req.submit_tick >= ttl:
                self.waiting.remove(req)
                self._finish(req, "deadline")
        self.stats.observe_queue(len(self.waiting))
        job = self._chunk_job
        if job is not None:
            req = job["req"]
            ttl = (req.deadline_ticks if req.deadline_ticks is not None
                   else ttl_default)
            if ttl is not None and self._tick - req.submit_tick >= ttl:
                self._chunk_job = None
                self._finish(req, "deadline")
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            ttl = (req.deadline_ticks if req.deadline_ticks is not None
                   else ttl_default)
            if ttl is not None and self._tick - req.submit_tick >= ttl:
                self._release(slot)
                self._finish(req, "deadline")

    def step(self) -> int:
        """One engine tick: expire deadlines, admit waiting, decode one
        token for every active slot. Returns the number of active slots
        the tick operated on. Bookkeeping commits only on success — a
        failed or non-finite tick leaves the pool exactly as it was."""
        t0 = time.perf_counter()
        with trace.span("serve.tick", tick=self._tick + 1):
            n = self._step_inner()
        if self.metrics is not None:
            self.metrics.histogram("serve.tick_s").record(
                time.perf_counter() - t0)
        trace.counter("serve.pool", waiting=len(self.waiting), active=n)
        return n

    def _step_inner(self) -> int:
        t0 = time.perf_counter()
        self._tick += 1
        self.stats.ticks += 1
        self._expire_deadlines()
        self._maybe_defrag()
        self._admit()
        self._ensure_pages()
        active = self._active()
        if not active:
            return 0
        result = self._robust_step(active)
        if result is None:                     # pool emptied by quarantine
            return 0
        logits, new_cache, active = result

        # -- numeric degradation ladder --------------------------------
        logits_np = np.asarray(logits)
        if not np.isfinite(logits_np[active]).all():
            self.stats.nonfinite_ticks += 1
            handled = False
            if self.ecfg.degrade_on_nonfinite and not self._pre_cache_gone():
                # One rung down: re-run THIS tick on the safe route
                # (never injector-wrapped). For pure-attention decode the
                # math is identical, so the rerun is bitwise lossless.
                self.stats.degradations += 1
                s_logits, s_cache = self._step_safe(
                    self.params, self.tokens, self.cache,
                    self._cache_len_vec())
                s_np = np.asarray(s_logits)
                if np.isfinite(s_np[active]).all():
                    logits, new_cache, logits_np = s_logits, s_cache, s_np
                    if self._decode_safe_differs:
                        for i in active:
                            self.slot_req[i].degraded = True
                    handled = True
            if not handled:
                return self._skip_tick(active, logits_np, new_cache, t0)
        self._nan_streak = 0

        # -- commit ----------------------------------------------------
        self.cache = new_cache
        nxt = self._sample(logits)
        nxt_np = np.asarray(nxt)
        new_tokens = self.tokens
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt_np[i])
            req.output.append(tok)
            self.stats.tokens_generated += 1
            self.lengths[i] += 1
            self.budgets[i] -= 1
            if tok == self.ecfg.eos_id:
                reason = "eos"
            elif self.budgets[i] <= 0:
                reason = "length_budget"
            elif self.lengths[i] + 1 >= self.ecfg.max_len:
                reason = "cache_full"
                self._warn_cache_full(req)
            else:
                new_tokens = new_tokens.at[i, 0].set(tok)
                continue
            self._release(i)
            self._finish(req, reason)
        self.tokens = new_tokens
        if (self.ecfg.slow_tick_s is not None
                and time.perf_counter() - t0 > self.ecfg.slow_tick_s):
            self.stats.slow_ticks += 1
        return len(active)

    def _cache_len_vec(self) -> jax.Array:
        """Per-row cache lengths: inactive rows are 0 (fully masked under
        the zeroed-probability convention, so they never emit NaN)."""
        return jnp.asarray(self.lengths, jnp.int32)

    def _pre_cache_gone(self) -> bool:
        """Under donation the pre-tick cache buffers may be consumed."""
        if not self.ecfg.donate_cache:
            return False
        leaf = jax.tree.leaves(self.cache)[0]
        return getattr(leaf, "is_deleted", lambda: False)()

    def _skip_tick(self, active, logits_np, new_cache, t0) -> int:
        """Roll the tick back (trainer NaN-guard parity): nothing
        advances. Persistent non-finite ticks quarantine the offending
        rows so the pool stays live."""
        trace.instant("serve.rollback", tick=self._tick,
                      rids=[self.slot_req[i].rid for i in active],
                      nan_streak=self._nan_streak + 1)
        self.stats.skipped_ticks += 1
        self._nan_streak += 1
        if self._pre_cache_gone():
            # Donated fast path: pre-tick cache is gone, adopt the
            # written one. Attention caches self-heal (next tick rewrites
            # the same positions); recurrent state is documented lossy.
            self.cache = new_cache
        if self._nan_streak > self.ecfg.max_consecutive_nan_ticks:
            bad = [i for i in active
                   if not np.isfinite(logits_np[i]).all()]
            for i in bad:
                req = self.slot_req[i]
                self._release(i)
                self._finish(req, "error",
                             error=f"non-finite logits for "
                                   f"{self._nan_streak} consecutive ticks")
                self.stats.quarantined += 1
            self._nan_streak = 0
        if (self.ecfg.slow_tick_s is not None
                and time.perf_counter() - t0 > self.ecfg.slow_tick_s):
            self.stats.slow_ticks += 1
        return len(active)

    def _warn_cache_full(self, req: Request) -> None:
        warnings.warn(
            f"request {req.rid} ran out of KV cache (max_len="
            f"{self.ecfg.max_len}) before its token budget; finishing "
            f"with finish_reason='cache_full'", RuntimeWarning,
            stacklevel=3)

    # -- step-failure recovery -----------------------------------------
    def _robust_step(self, active: List[int]):
        """Run the wrapped decode step with retries; on persistent
        failure bisect the active set and quarantine the poison request.
        Returns ``(logits, new_cache, active)`` or None if the pool
        emptied."""
        attempts = 0
        transient_resets = 0
        last_err: Optional[BaseException] = None
        for _ in range(4 * self.ecfg.max_slots + 8):
            clv = self._cache_len_vec()   # fresh: quarantine edits lengths
            if self.injector is not None:
                self.injector.begin(self._ctx(active))
            try:
                with trace.span("serve.decode", tick=self._tick,
                                rids=[self.slot_req[i].rid for i in active],
                                attempt=attempts):
                    logits, new_cache = self._wstep(
                        self.params, self.tokens, self.cache, clv)
                return logits, new_cache, active
            except Exception as e:            # noqa: BLE001 — jitted call
                last_err = e
            attempts += 1
            if attempts <= self.ecfg.max_step_retries:
                self.stats.step_retries += 1
                if self.ecfg.retry_backoff_s:
                    time.sleep(self.ecfg.retry_backoff_s * attempts)
                continue
            poison = self._bisect(active, clv)
            if poison is None:
                raise EngineStepError(
                    f"decode step failing with no active request "
                    f"implicated (ambient fault): {last_err!r}"
                ) from last_err
            if not poison:
                # Not reproducible in probes: transient that outlived the
                # retry budget. Allow one fresh retry round, then give up.
                transient_resets += 1
                if transient_resets > 1:
                    raise EngineStepError(
                        f"decode step failed after retries and probes "
                        f"could not reproduce it: {last_err!r}"
                    ) from last_err
                attempts = 0
                continue
            for slot in poison:
                req = self.slot_req[slot]
                self._release(slot)
                self._finish(req, "error",
                             error=f"quarantined by step-failure "
                                   f"bisection: {last_err!r}")
                self.stats.quarantined += 1
            active = self._active()
            if not active:
                return None
            attempts = 0
        raise EngineStepError(
            f"decode step recovery did not converge: {last_err!r}"
        ) from last_err

    def _probe(self, subset: List[int], clv) -> bool:
        """Re-issue the step as if only ``subset`` participated (the
        injector keys poison faults on participating rids). Non-donating,
        results discarded: a successful probe has no side effects."""
        self.stats.probes += 1
        if self.injector is not None:
            self.injector.begin(self._ctx(subset))
        try:
            with trace.span("serve.probe", tick=self._tick,
                            rids=[self.slot_req[i].rid for i in subset]):
                self._wstep_probe(self.params, self.tokens, self.cache, clv)
            return True
        except Exception:                      # noqa: BLE001
            return False

    def _bisect(self, active: List[int], clv) -> Optional[List[int]]:
        """Binary-search the failing subset. Returns the poison slots,
        [] when the failure won't reproduce (transient), or None when it
        reproduces with NO requests implicated (ambient)."""
        if not self._probe([], clv):
            return None
        cands = list(active)
        while len(cands) > 1:
            mid = len(cands) // 2
            lo, hi = cands[:mid], cands[mid:]
            if not self._probe(lo, clv):
                cands = lo
            elif not self._probe(hi, clv):
                cands = hi
            else:
                # Only the combination fails: not separable — quarantine
                # the whole candidate set rather than deadlock the pool.
                return cands
        if not self._probe(cands, clv):        # confirm the singleton
            return cands
        return []

    # -- driving --------------------------------------------------------
    def run_to_completion(self, max_ticks: int = 10_000,
                          strict: Optional[bool] = None) -> list[Request]:
        """Drive ticks until every request finished. Exhausting
        ``max_ticks`` with work still pending raises under ``strict``
        (default ``EngineConfig.strict_deadlines``) or finishes the
        survivors with ``finish_reason="deadline"``."""
        strict = self.ecfg.strict_deadlines if strict is None else strict
        for _ in range(max_ticks):
            if (not self.waiting and self._chunk_job is None
                    and all(r is None for r in self.slot_req)):
                break
            self.step()
        else:
            survivors = (len(self.waiting)
                         + (self._chunk_job is not None)
                         + sum(r is not None for r in self.slot_req))
            if survivors:
                if strict:
                    raise EngineDeadlineError(
                        f"run_to_completion exhausted max_ticks="
                        f"{max_ticks} with {survivors} request(s) "
                        f"unfinished")
                for req in list(self.waiting):
                    self.waiting.remove(req)
                    self._finish(req, "deadline")
                if self._chunk_job is not None:
                    req = self._chunk_job["req"]
                    self._chunk_job = None
                    self._finish(req, "deadline")
                for slot, req in enumerate(self.slot_req):
                    if req is not None:
                        self._release(slot)
                        self._finish(req, "deadline")
        return self.finished

    # -- invariants -----------------------------------------------------
    def audit(self) -> dict:
        """Lifecycle invariants the chaos wall asserts. Raises
        AssertionError on violation; returns a summary dict."""
        fin = [r.rid for r in self.finished]
        assert len(fin) == len(set(fin)), f"duplicate finished rids: {fin}"
        for req in self.finished:
            assert req.finish_reason in FINISH_REASONS, (
                f"request {req.rid} finished with invalid reason "
                f"{req.finish_reason!r}")
        live = ([r.rid for r in self.waiting]
                + ([self._chunk_job["req"].rid] if self._chunk_job else [])
                + [r.rid for r in self.slot_req if r is not None])
        assert not (set(fin) & set(live)), (
            f"rids both finished and live: {set(fin) & set(live)}")
        for req in self.waiting:
            assert req.finish_reason is None
        assert self.stats.total_finished == len(self.finished)
        if self._paged:
            # Refcount invariant: every page's count equals its live
            # table references plus the prefix registry's strong pins
            # (weak partial entries hold no reference), and the free
            # bitmap is exactly refcount == 0.
            refs = np.zeros(self.allocator.num_pages, np.int64)
            for slot in range(len(self.slot_req)):
                pages = self.ptable.pages_of(slot)
                if pages.size:
                    np.add.at(refs, pages, 1)
            if self.registry is not None:
                strong = self.registry.strong_pages()
                if strong:
                    np.add.at(refs, np.asarray(strong, np.int64), 1)
            refs[0] = 1                        # null page pin
            assert (refs == self.allocator.refcount).all(), (
                f"refcount drift: expected {refs.tolist()}, "
                f"allocator has {self.allocator.refcount.tolist()}")
            free_expect = self.allocator.refcount == 0
            free_expect[0] = False
            assert (free_expect == self.allocator.free).all(), (
                "free bitmap out of sync with refcounts")
        return {"finished": len(fin), "live": len(live),
                "stats": self.stats.as_dict()}


def _scatter_row(pool: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Write prefill cache row(s) into the pool slot.

    pool: (L, B, ...) stacked cache; one: (L, 1, ...) single-row cache.
    """
    return jax.lax.dynamic_update_slice_in_dim(pool, one, slot, axis=1)
