"""Serving engine: continuous batching over a fixed slot pool.

vLLM-style scheduling reduced to its JAX-native core: a fixed decode batch
of ``max_slots`` sequences; finished sequences free their slot; waiting
requests are admitted by prefilling into the freed slot. Slot bookkeeping
(free-slot compaction) routes through ``repro.relational.compact`` — an
exclusive prefix sum over the free bitmap packs the free slot ids to the
front, the paper's stream-compaction use case running the engine.

The decode step is ONE jitted call for the whole pool (padded, masked);
prefill is a second jitted call per admitted request batch. Caches are
donated across decode steps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.relational import compact as rel_compact
from repro.serve.sampling import sample_logits
from repro.serve.steps import init_cache_for, make_prefill_fn, make_serve_step

Pytree = Any


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0       # greedy default
    top_p: float = 1.0
    eos_id: int = 1
    seed: int = 0
    # Prefill attention route: ``attn_impl="flash"`` runs prompt attention
    # on the engine-backed flash fold, ``attn_schedule`` its grid
    # organization (carry | decoupled | auto — policy decides; the long-KV
    # class lands on the split-KV decoupled form).
    attn_impl: Optional[str] = None
    attn_schedule: str = "auto"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: Optional[int] = None
    # filled by the engine:
    output: Optional[list] = None
    done: bool = False


class Engine:
    def __init__(self, params: Pytree, cfg: ModelConfig, ecfg: EngineConfig):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
        self._prefill_cache = {}
        self.key = jax.random.PRNGKey(ecfg.seed)

        B, L = ecfg.max_slots, ecfg.max_len
        self.cache = init_cache_for(cfg, B, L)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = np.zeros(B, np.int64)          # per-slot position
        self.budgets = np.zeros(B, np.int64)          # remaining new tokens
        self.slot_req: list[Optional[Request]] = [None] * B
        self.waiting: list[Request] = []
        self.finished: list[Request] = []

    # -- slot bookkeeping (scan-based compaction) -----------------------
    def _free_slots(self) -> np.ndarray:
        free = np.array([r is None for r in self.slot_req], np.int32)
        # Stream compaction over the free bitmap (paper §1: "new offsets
        # during a partitioning step"): ONE mask scan inside
        # filter_compact packs the free slot ids to the front. The
        # per-slot ranks are part of the bookkeeping contract (see
        # test_free_slot_compaction_ranks); the host cumsum avoids a
        # second device scan for them.
        slots, count = rel_compact.filter_compact(
            jnp.arange(free.size, dtype=jnp.int32),
            jnp.asarray(free, bool))
        ranks = np.cumsum(free) - free
        return np.asarray(slots)[: int(count)], ranks

    # -- admission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.output = []
        self.waiting.append(req)

    def _admit(self) -> None:
        free_idx, _ = self._free_slots()
        while self.waiting and len(free_idx):
            slot = int(free_idx[0])
            free_idx = free_idx[1:]
            req = self.waiting.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            S = prompt.shape[1]
            pf = self._prefill_for(S)
            logits, cache1 = pf(self.params, prompt)
            # Copy the single-row prefill cache into the pool at `slot`
            # (cache leaves are (layers, batch, ...); prefill batch = 1).
            self.cache = jax.tree.map(
                lambda pool, one: _scatter_row(pool, one.astype(pool.dtype),
                                               slot),
                self.cache, cache1)
            first = self._sample(logits)[0]
            req.output.append(int(first))
            budget = (req.max_new_tokens or self.ecfg.max_new_tokens) - 1
            if budget <= 0 or int(first) == self.ecfg.eos_id:
                req.done = True          # prefill token exhausted the budget
                self.finished.append(req)
                continue
            self.tokens = self.tokens.at[slot, 0].set(first)
            self.lengths[slot] = S
            self.budgets[slot] = budget
            self.slot_req[slot] = req

    def _prefill_for(self, S: int):
        if S not in self._prefill_cache:
            self._prefill_cache[S] = jax.jit(
                make_prefill_fn(self.cfg, self.ecfg.max_len,
                                attn_impl=self.ecfg.attn_impl,
                                attn_schedule=self.ecfg.attn_schedule))
        return self._prefill_cache[S]

    def _sample(self, logits: jax.Array) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sample_logits(sub, logits, self.ecfg.temperature,
                             self.ecfg.top_p)

    # -- decode ---------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit waiting, decode one token for all active.
        Returns the number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        cache_len = jnp.asarray(int(max(self.lengths[i] for i in active)),
                                jnp.int32)
        logits, self.cache = self.serve_step(
            self.params, self.tokens, self.cache, cache_len)
        nxt = self._sample(logits)
        nxt_np = np.asarray(nxt)
        new_tokens = self.tokens
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt_np[i])
            req.output.append(tok)
            self.lengths[i] += 1
            self.budgets[i] -= 1
            hit_eos = tok == self.ecfg.eos_id
            out_of_budget = self.budgets[i] <= 0
            out_of_cache = self.lengths[i] + 1 >= self.ecfg.max_len
            if hit_eos or out_of_budget or out_of_cache:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
            else:
                new_tokens = new_tokens.at[i, 0].set(tok)
        self.tokens = new_tokens
        return len(active)

    def run_to_completion(self, max_ticks: int = 10_000) -> list[Request]:
        for _ in range(max_ticks):
            if not self.waiting and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished


def _scatter_row(pool: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Write prefill cache row(s) into the pool slot.

    pool: (L, B, ...) stacked cache; one: (L, 1, ...) single-row cache.
    """
    return jax.lax.dynamic_update_slice_in_dim(pool, one, slot, axis=1)
