"""Stream compaction (filter): predicate -> mask cumsum -> gather.

The paper's §1 filter use case as a library operator: every surviving
element's new index is the exclusive prefix sum of the keep-mask — a
scan over ``repro.core.scan`` (reference path) or the fused Pallas
kernel in ``repro.kernels.compact`` (the scan engine's mask-monoid
registration: predicate select fused into the writeback, running under
whichever grid schedule the policy picks).

Outputs are fixed-size (jit-friendly): ``filter_compact`` returns a
``size``-length buffer plus the live count, with dropped positions
holding ``fill_value``. The serve engine's slot admission runs on these
primitives (``serve/engine.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import scan as scanlib

_ALGORITHMS = ("auto", "ref", "kernel")


def _resolve(algorithm: str) -> str:
    if algorithm not in _ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; one of {_ALGORITHMS}")
    if algorithm == "auto":
        # The fused kernel wins on TPU; off-TPU it would run the Pallas
        # interpreter, so the library scan is the sane default.
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return algorithm


def mask_ranks(mask: jax.Array, *, algorithm: str = "auto",
               interpret: "bool | None" = None) -> jax.Array:
    """Exclusive prefix sum of a (T,) keep-mask: each position's compacted
    rank (defined for dropped positions too — the running survivor count).
    """
    m = (jnp.asarray(mask) != 0).astype(jnp.int32)
    if m.shape[0] == 0:
        return m
    if _resolve(algorithm) == "kernel":
        from repro.kernels.scan_blocked import ops as sb_ops
        # schedule="auto": the policy's three-way grid rule (a single
        # long mask row lands on the parallel-sequence schedules).
        return sb_ops.cumsum(m, exclusive=True, interpret=interpret)
    return scanlib.cumsum(m, exclusive=True, algorithm="blocked")


def compact_indices(mask: jax.Array, *, algorithm: str = "auto",
                    interpret: "bool | None" = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Scatter destinations for a (T,) keep-mask.

    Returns ``(dest, count)``: ``dest[i]`` is the compacted write index
    where ``mask[i]`` holds and the sentinel ``T`` where it doesn't;
    ``count`` is the number of survivors. Both come from one mask scan.
    """
    m = (jnp.asarray(mask) != 0)
    T = m.shape[0]
    if T == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32)
    if _resolve(algorithm) == "kernel":
        from repro.kernels.compact import ops as kc_ops
        return kc_ops.mask_compact(m, interpret=interpret)
    ranks = mask_ranks(m, algorithm="ref")
    count = ranks[-1] + m[-1].astype(jnp.int32)
    return jnp.where(m, ranks, T).astype(jnp.int32), count


def filter_compact(values: jax.Array, mask: jax.Array, *,
                   size: "int | None" = None, fill_value=0,
                   algorithm: str = "auto",
                   interpret: "bool | None" = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Keep ``values`` rows where ``mask`` holds, packed to the front.

    ``values`` is (T, ...) with a (T,) ``mask``. Returns ``(out, count)``
    where ``out`` has leading length ``size`` (default T): the first
    ``count`` rows are the survivors in input order (bit-identical to
    ``values[mask]``), the rest hold ``fill_value``. Survivors ranked
    beyond ``size`` are dropped (``count`` still reports the true total).
    """
    values = jnp.asarray(values)
    mask = jnp.asarray(mask)
    if values.shape[:1] != mask.shape:
        raise ValueError(
            f"values leading axis {values.shape[:1]} != mask {mask.shape}")
    T = mask.shape[0]
    cap = T if size is None else int(size)
    dest, count = compact_indices(mask, algorithm=algorithm,
                                  interpret=interpret)
    # Park dropped elements (sentinel T) and over-capacity survivors at
    # index `cap` — min(cap, T) catches the sentinel when cap > T too.
    dest = jnp.where(dest >= min(cap, T), cap, dest)
    buf = jnp.full((cap + 1,) + values.shape[1:], fill_value, values.dtype)
    buf = buf.at[dest].set(values)
    return buf[:cap], count
