"""LSD radix sort composed from stable prefix-sum partition passes.

Each pass partitions by one radix digit of a sortable bit-transform of
the keys (the classic Satish et al. GPU radix sort the paper cites as a
prefix-sum consumer); stability of ``relational.partition`` makes the
multi-pass composition correct. Supports bool, signed/unsigned ints and
IEEE floats (half types sort through their exact float32 embedding).
NaN placement differs from ``jnp.sort``: positive-sign NaNs sort after
+inf, negative-sign NaNs before -inf (total order over the bit
patterns), whereas ``jnp.sort`` moves every NaN to the end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.relational.partition import apply_plan, partition_plan


def _sortable_bits(keys: jax.Array) -> tuple[jax.Array, int]:
    """Monotone embedding of ``keys`` into unsigned bits: u(a) < u(b)
    iff a sorts before b. Returns (uint array, significant bit count)."""
    dt = keys.dtype
    if dt == jnp.bool_:
        return keys.astype(jnp.uint32), 1
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        bits = dt.itemsize * 8
        return (keys if bits > 32 else keys.astype(jnp.uint32)), bits
    if jnp.issubdtype(dt, jnp.integer):
        bits = dt.itemsize * 8
        if bits <= 16:  # bias into [0, 2^bits) — cheaper than a bitcast
            lo = int(jnp.iinfo(dt).min)
            return (keys.astype(jnp.int32) - lo).astype(jnp.uint32), bits
        ut = jnp.uint32 if bits == 32 else jnp.uint64
        u = jax.lax.bitcast_convert_type(keys, ut)
        return u ^ ut(1 << (bits - 1)), bits  # flip the sign bit
    if jnp.issubdtype(dt, jnp.floating):
        if dt.itemsize < 4:
            keys = keys.astype(jnp.float32)  # exact, monotone embedding
            dt = keys.dtype
        bits = dt.itemsize * 8
        ut = jnp.uint32 if bits == 32 else jnp.uint64
        b = jax.lax.bitcast_convert_type(keys, ut)
        sign = (b >> (bits - 1)) != 0
        # IEEE trick: negatives flip entirely (reverses their order),
        # non-negatives just set the sign bit (shift above negatives).
        return jnp.where(sign, ~b, b | ut(1 << (bits - 1))), bits
    raise TypeError(f"radix_sort: unsupported key dtype {dt}")


def radix_sort(keys: jax.Array, *payload: jax.Array, radix_bits: int = 8):
    """Stable ascending sort of (T,) ``keys``; ``payload`` arrays (T, ...)
    are reordered alongside. Returns sorted keys, or the
    ``(keys, *payload)`` tuple when payload is given.
    """
    keys = jnp.asarray(keys)
    if keys.ndim != 1:
        raise ValueError(f"radix_sort expects 1D keys, got {keys.shape}")
    payload = tuple(map(jnp.asarray, payload))
    arrays = (keys,) + payload
    if keys.shape[0] > 1:
        u, bits = _sortable_bits(keys)
        for shift in range(0, bits, radix_bits):
            nb = 1 << min(radix_bits, bits - shift)
            digit = ((u >> shift) & (nb - 1)).astype(jnp.int32)
            plan = partition_plan(digit, nb)
            (u,) = apply_plan(plan, u)
            arrays = apply_plan(plan, *arrays)
    return arrays[0] if not payload else arrays


def argsort(keys: jax.Array, radix_bits: int = 8) -> jax.Array:
    """Stable permutation sorting ``keys`` (ties keep input order)."""
    keys = jnp.asarray(keys)
    perm = jnp.arange(keys.shape[0], dtype=jnp.int32)
    if keys.shape[0] <= 1:
        return perm
    return radix_sort(keys, perm, radix_bits=radix_bits)[1]
