"""Relational operators where every data movement is a prefix sum.

The source paper motivates prefix sums as "a building block of many
important operators including join, sort and filter queries"; this
package is that claim as a library, layered on ``repro.core.scan``:

  compact.py    filter / stream compaction — mask cumsum -> gather
                (fused Pallas kernel in ``repro.kernels.compact``)
  partition.py  stable radix partition — histogram + exclusive-cumsum
                offsets (the MoE dispatch machinery, generalized)
  sort.py       LSD radix sort — composed partition passes
  groupby.py    group-by aggregate — partition/sort + segmented scan
  join.py       partitioned equi-join — scan-built build/probe offsets

Load-bearing consumers: ``models/layers/moe.py`` (expert dispatch via
``partition``) and ``serve/engine.py`` (slot compaction via ``compact``).
"""

from repro.relational.compact import (compact_indices, filter_compact,
                                      mask_ranks)
from repro.relational.groupby import group_by, group_by_sorted
from repro.relational.join import (JoinResult, estimate_max_matches,
                                   hash_join)
from repro.relational.partition import (PartitionPlan, partition_plan,
                                        radix_partition)
from repro.relational.sort import argsort, radix_sort

__all__ = [
    "JoinResult", "PartitionPlan", "argsort", "compact_indices",
    "estimate_max_matches",
    "filter_compact", "group_by", "group_by_sorted", "hash_join",
    "mask_ranks", "partition_plan", "radix_partition", "radix_sort",
]
