"""Group-by aggregation over the segmented-scan substrate.

Two shapes of the classic sort-or-partition group-by:

  * ``group_by``        — group ids already dense in [0, G): one stable
    prefix-sum partition brings each group contiguous, segment start
    flags come from the partition offsets, a segmented scan
    (``core.scan.segmented``) folds each run, and the run's last element
    is the aggregate. Matches ``jax.ops.segment_sum`` semantics
    (identity for empty groups).
  * ``group_by_sorted`` — keys pre-sorted but arbitrary-valued: segment
    boundaries are key changes, aggregates sit at segment ends, and the
    (unique key, aggregate) pairs are packed with ``filter_compact`` —
    compaction and group-by from the same scan toolbox.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import assoc
from repro.core.scan import policy
from repro.core.scan import segmented as _segmented
from repro.relational.compact import filter_compact
from repro.relational.partition import partition_plan

_AGGS = ("sum", "prod", "max", "min", "count", "mean")
_ALGORITHMS = ("auto", "ref", "kernel")


def _seg_algorithm(algorithm: str, op: str, n: int, itemsize: int) -> str:
    """Resolve the segmented-scan backend for a length-``n`` run.

    ``auto`` routes long runs onto the Pallas segscan kernel — gated by
    the SAME policy threshold that picks the kernel algorithm for plain
    scans (``policy.choose``: bandwidth-bound sizes that overflow the
    VMEM block budget) — and only on TPU, where the fused kernel wins;
    off-TPU it would run the Pallas interpreter, so the library scan is
    the sane default. The kernel path covers the sum monoid (which
    ``mean``/``count`` reduce to); other aggregates stay on the library
    scan.
    """
    if algorithm not in _ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; one of {_ALGORITHMS}")
    if algorithm != "auto":
        return algorithm
    if op != "sum" or jax.default_backend() != "tpu":
        return "ref"
    choice = policy.choose(n, itemsize, kernel_available=True)
    return "kernel" if choice.algorithm == "kernel" else "ref"


def _identity_result(agg: str, shape, dtype):
    if agg == "count":
        return jnp.zeros(shape, jnp.int32)
    base = jnp.zeros(shape, dtype)
    if agg in ("sum", "mean"):
        return base
    return assoc.get(agg).identity_like(base)


def group_by(group_ids: jax.Array, values: jax.Array, num_groups: int,
             agg: str = "sum", algorithm: str = "auto") -> jax.Array:
    """Per-group aggregate of (T, ...) ``values`` by (T,) dense ids.

    Returns a (num_groups, ...) array; empty groups hold the aggregate's
    identity (0 for sum/mean/count, the monoid identity otherwise) —
    ``group_by(ids, v, G, "sum")`` equals ``jax.ops.segment_sum(v, ids,
    num_segments=G)`` bit-exactly for integer values.

    ``algorithm`` picks the segmented-scan backend: ``"ref"`` (library
    scan), ``"kernel"`` (Pallas segscan), or ``"auto"`` — kernel for long
    runs past the policy's bandwidth-bound threshold on TPU (see
    ``_seg_algorithm``).
    """
    if agg not in _AGGS:
        raise ValueError(f"unknown agg {agg!r}; one of {_AGGS}")
    group_ids = jnp.asarray(group_ids)
    values = jnp.asarray(values)
    T = group_ids.shape[0]
    if agg == "count":  # (num_groups,) regardless of value dims
        if T == 0:
            return jnp.zeros((num_groups,), jnp.int32)
        return partition_plan(group_ids, num_groups).counts.astype(jnp.int32)
    out_shape = (num_groups,) + values.shape[1:]
    if T == 0:
        return _identity_result(agg, out_shape, values.dtype)

    plan = partition_plan(group_ids, num_groups)

    sv = jnp.zeros_like(values).at[plan.dest].set(values)
    # Segment start flags from the partition offsets: every non-empty
    # group's base offset begins a run (empty groups collapse onto the
    # next group's offset — `set` keeps the flag at 1, no phantom runs).
    flags = jnp.zeros((T + 1,), jnp.int32).at[plan.offsets].set(1)[:T]
    op = "sum" if agg == "mean" else agg
    algo = _seg_algorithm(algorithm, op, T, values.dtype.itemsize)
    if algo == "kernel":
        # Broadcast the (T,) flags over trailing value dims: the kernel
        # wrapper flattens leading axes into rows of the (rows, T) grid.
        kflags = jnp.broadcast_to(
            flags.reshape((T,) + (1,) * (sv.ndim - 1)), sv.shape)
        seg = _segmented.segmented_scan(sv, kflags, op=op, axis=0,
                                        algorithm="kernel")
    else:
        seg = _segmented.segmented_scan(sv, flags, op=op, axis=0)
    ends = jnp.clip(plan.offsets + plan.counts - 1, 0, T - 1)
    gathered = seg[ends]  # (G, ...) — last element of each run
    nonempty = (plan.counts > 0).reshape(
        (num_groups,) + (1,) * (gathered.ndim - 1))
    ident = _identity_result(agg, out_shape, values.dtype)
    out = jnp.where(nonempty, gathered, ident)
    if agg == "mean":
        denom = jnp.maximum(plan.counts, 1).reshape(nonempty.shape)
        rdt = (out.dtype if jnp.issubdtype(out.dtype, jnp.floating)
               else jnp.float32)
        out = out.astype(rdt) / denom.astype(rdt)
    return out


def group_by_sorted(keys: jax.Array, values: jax.Array, agg: str = "sum"
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Aggregate runs of equal ``keys`` (pre-sorted, any values).

    Returns ``(unique_keys, aggregates, num_groups)`` — fixed-size (T,)
    buffers whose first ``num_groups`` rows are live, packed via
    ``filter_compact`` on the segment-end mask.
    """
    if agg not in _AGGS:
        raise ValueError(f"unknown agg {agg!r}; one of {_AGGS}")
    keys = jnp.asarray(keys)
    values = jnp.asarray(values)
    T = keys.shape[0]
    if T == 0:
        return keys, values, jnp.zeros((), jnp.int32)

    starts = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (keys[1:] != keys[:-1]).astype(jnp.int32)])
    ends_mask = jnp.concatenate(
        [starts[1:] != 0, jnp.ones((1,), bool)])
    if agg == "count":
        seg = _segmented.segmented_scan(
            jnp.ones((T,), jnp.int32), starts, op="sum", axis=0)
    elif agg == "mean":
        seg = _segmented.segmented_scan(values, starts, op="sum", axis=0)
        cnt = _segmented.segmented_scan(
            jnp.ones((T,), jnp.int32), starts, op="sum", axis=0)
        seg = seg / cnt.astype(seg.dtype)
    else:
        seg = _segmented.segmented_scan(values, starts, op=agg, axis=0)
    uniq, count = filter_compact(keys, ends_mask)
    aggs, _ = filter_compact(seg, ends_mask)
    return uniq, aggs, count
