"""Partitioned equi-join with prefix-sum build and probe offsets.

The radix-join structure (Manegold/Boncz; Satish et al. are the paper's
citation for the same prefix-sum pattern on GPUs):

  build  the right (build) side is brought to bucket-contiguous order by
         LSD radix passes — each pass a stable prefix-sum partition
         (``relational.sort`` over ``relational.partition``), exactly the
         ``dispatch_offsets`` histogram + exclusive-cumsum machinery.
  probe  each left row binary-searches its key's run in the partitioned
         build side; its match COUNT feeds an exclusive prefix sum that
         assigns every (left, right) output pair a unique slot — the
         paper's "new index values" once more, now over the result set.

Output is fixed-size and jit-friendly: index pairs padded with -1 plus
the live pair count. ``max_matches=None`` sizes the output exactly by
materializing the count (eager only); under ``jit`` pass a static cap.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scanlib
from repro.relational.sort import _sortable_bits, radix_sort


class JoinResult(NamedTuple):
    """Matching row-index pairs of an inner equi-join.

    Attributes:
      left_index: (M,) int32 row into the left table, -1 past ``count``.
      right_index: (M,) int32 row into the right table, -1 past ``count``.
      count: () integer number of live pairs (may exceed M if the cap
        was too small; pairs beyond the cap are dropped). int32, or
        int64 under x64.
    """

    left_index: jax.Array
    right_index: jax.Array
    count: jax.Array


def hash_join(left_keys: jax.Array, right_keys: jax.Array, *,
              max_matches: "int | None" = None) -> JoinResult:
    """Inner equi-join of two (L,) / (R,) key columns.

    Pairs are emitted grouped by left row (left rows in input order;
    within a row, right matches in build-side sorted order).
    """
    left_keys = jnp.asarray(left_keys)
    right_keys = jnp.asarray(right_keys)
    if left_keys.dtype != right_keys.dtype:
        raise TypeError(
            f"hash_join key dtypes must match: {left_keys.dtype} vs "
            f"{right_keys.dtype}")
    L, R = left_keys.shape[0], right_keys.shape[0]
    if L == 0 or R == 0:
        M = 0 if max_matches is None else int(max_matches)
        pad = jnp.full((M,), -1, jnp.int32)
        return JoinResult(pad, pad, jnp.zeros((), jnp.int32))

    lnan = None
    if jnp.issubdtype(left_keys.dtype, jnp.floating):
        # Join floats in the monotone bit domain: a TOTAL order, so the
        # binary search stays valid even with NaN build keys (raw floats
        # are not sorted under < once a negative-sign NaN lands before
        # -inf). Signed zeros collapse (-0.0 == +0.0 must match); NaN
        # probe rows match nothing (NaN != NaN), enforced below.
        lnan = jnp.isnan(left_keys)
        rnan = jnp.isnan(right_keys)
        lc = jnp.where(left_keys == 0, jnp.zeros_like(left_keys), left_keys)
        rc = jnp.where(right_keys == 0, jnp.zeros_like(right_keys),
                       right_keys)
        left_keys, _ = _sortable_bits(lc)
        right_keys, _ = _sortable_bits(rc)
        # distinct build-NaN payloads could alias a probe bit pattern
        # only if the probe is NaN too — suppressed via lnan; park build
        # NaNs at the domain top so they cluster past every real key
        top = jnp.iinfo(right_keys.dtype).max  # no non-NaN key maps here
        right_keys = jnp.where(rnan, jnp.full_like(right_keys, top),
                               right_keys)

    # Build: partition the right side to sorted order (radix passes).
    rk, rperm = radix_sort(right_keys, jnp.arange(R, dtype=jnp.int32))
    lo = jnp.searchsorted(rk, left_keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(rk, left_keys, side="right").astype(jnp.int32)
    if lnan is not None:
        hi = jnp.where(lnan, lo, hi)  # NaN probes match nothing

    # Probe offsets: exclusive prefix sum of per-row match counts —
    # accumulated in int64 under x64 (see segmented._offsets_dtype);
    # in int32 mode an overflowing eager join raises instead of wrapping.
    acc_dt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    m = (hi - lo).astype(acc_dt)
    off = scanlib.cumsum(m, exclusive=True, algorithm="blocked")
    total = off[-1] + m[-1]

    if max_matches is None:
        # Exact host-side recount: int32 accumulation wraps mod 2^32, so
        # both negative AND positive-wrapped totals are caught.
        exact = int(np.sum(np.asarray(m), dtype=np.int64))
        if exact != int(total):
            raise OverflowError(
                "join result exceeds int32 pair offsets; enable "
                "jax_enable_x64 for int64 accumulation")
        M = exact
    else:
        M = int(max_matches)
    if M == 0:
        pad = jnp.zeros((0,), jnp.int32)
        return JoinResult(pad, pad, total)

    # Expand: output slot p belongs to the last left row whose offset is
    # <= p (right-bisect skips rows with zero matches), at match number
    # p - off[row] within that row's [lo, hi) run.
    p = jnp.arange(M, dtype=jnp.int32)
    li = jnp.clip(
        jnp.searchsorted(off, p, side="right").astype(jnp.int32) - 1,
        0, L - 1)
    j = p - off[li]
    rs = jnp.clip(lo[li] + j, 0, R - 1)
    valid = p < total
    lidx = jnp.where(valid, li, -1)
    ridx = jnp.where(valid, rperm[rs], -1)
    return JoinResult(lidx, ridx, total)
