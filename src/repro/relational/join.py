"""Partitioned equi-join with prefix-sum build and probe offsets.

The radix-join structure (Manegold/Boncz; Satish et al. are the paper's
citation for the same prefix-sum pattern on GPUs):

  build  the right (build) side is brought to bucket-contiguous order by
         LSD radix passes — each pass a stable prefix-sum partition
         (``relational.sort`` over ``relational.partition``), exactly the
         ``dispatch_offsets`` histogram + exclusive-cumsum machinery.
  probe  each left row binary-searches its key's run in the partitioned
         build side; its match COUNT feeds an exclusive prefix sum that
         assigns every (left, right) output pair a unique slot — the
         paper's "new index values" once more, now over the result set.

Output is fixed-size and jit-friendly: index pairs padded with -1 plus
the live pair count. Capacity policy (``max_matches``):

  * ``"auto"`` (default) — SPILL-SAFE: size the output to the histogram
    product upper bound Σ_b |L_b|·|R_b| over radix buckets of the key
    domain (``estimate_max_matches``). The bound dominates the true match
    count for every key distribution, so no pair is ever dropped, and it
    collapses to ~the exact count when buckets are fine enough. Eager
    only (the capacity is a shape).
  * ``None`` — exact: materialize the true count (eager only).
  * ``int`` — static cap for ``jit``; pairs beyond the cap are dropped
    but ``count`` still reports the true total.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scanlib
from repro.relational.sort import _sortable_bits, radix_sort


class JoinResult(NamedTuple):
    """Matching row-index pairs of an inner equi-join.

    Attributes:
      left_index: (M,) int32 row into the left table, -1 past ``count``.
      right_index: (M,) int32 row into the right table, -1 past ``count``.
      count: () integer number of live pairs (may exceed M if the cap
        was too small; pairs beyond the cap are dropped). int32, or
        int64 under x64.
    """

    left_index: jax.Array
    right_index: jax.Array
    count: jax.Array


def _radix_buckets(keys: jax.Array, bits: int) -> jax.Array:
    """``bits``-wide histogram bucket of each key.

    Equal keys land in the same bucket by construction — the only
    property the upper bound needs. Two care points: signed zeros are
    canonicalized EXACTLY like the match path (-0.0 must share +0.0's
    bucket, or the bound undercounts and drops pairs), and the key goes
    through a Fibonacci multiplicative hash before the bucket is taken,
    so stride-aligned key families (hash/pointer-like ids that collide
    modulo 2^bits) spread across buckets instead of degenerating the
    bound to |L|·|R|.
    """
    if jnp.issubdtype(keys.dtype, jnp.floating):
        keys = jnp.where(keys == 0, jnp.zeros_like(keys), keys)
        keys, _ = _sortable_bits(keys)
    if keys.dtype.itemsize == 8:  # only reachable under x64
        h = keys.astype(jnp.uint64) * jnp.uint64(0x9E3779B97F4A7C15)
        h = h >> jnp.uint64(64 - bits)
    else:
        h = keys.astype(jnp.uint32) * jnp.uint32(2654435761)
        h = h >> jnp.uint32(32 - bits)
    return h.astype(jnp.int32)


def estimate_max_matches(left_keys: jax.Array, right_keys: jax.Array, *,
                         bits: int = 16) -> int:
    """Histogram-product upper bound on the inner-join output size.

    Bucket both key columns on their low ``bits`` radix digits and sum
    ``count_left[b] * count_right[b]`` — keys can only match inside a
    shared bucket, so the product bound dominates the true match count
    (equality when every bucket holds one distinct key). This is the
    partitioned-join sizing rule (Manegold/Boncz): the same histogram
    that drives the radix partition prices the output buffer. Host-side
    int (the capacity is a SHAPE), so eager only.
    """
    left_keys = jnp.asarray(left_keys)
    right_keys = jnp.asarray(right_keys)
    if left_keys.shape[0] == 0 or right_keys.shape[0] == 0:
        return 0
    nb = 1 << bits
    cl = jnp.bincount(_radix_buckets(left_keys, bits), length=nb)
    cr = jnp.bincount(_radix_buckets(right_keys, bits), length=nb)
    return int(np.sum(np.asarray(cl, np.int64) * np.asarray(cr, np.int64)))


def hash_join(left_keys: jax.Array, right_keys: jax.Array, *,
              max_matches: "int | str | None" = "auto") -> JoinResult:
    """Inner equi-join of two (L,) / (R,) key columns.

    Pairs are emitted grouped by left row (left rows in input order;
    within a row, right matches in build-side sorted order). See the
    module doc for the ``max_matches`` capacity policy; the default
    ``"auto"`` bound is spill-safe (never drops a pair).
    """
    left_keys = jnp.asarray(left_keys)
    right_keys = jnp.asarray(right_keys)
    if left_keys.dtype != right_keys.dtype:
        raise TypeError(
            f"hash_join key dtypes must match: {left_keys.dtype} vs "
            f"{right_keys.dtype}")
    if max_matches == "auto":
        if isinstance(left_keys, jax.core.Tracer) or \
                isinstance(right_keys, jax.core.Tracer):
            raise ValueError(
                "hash_join(max_matches='auto') sizes the output from the "
                "data (eager only); under jit pass a static int cap — "
                "estimate_max_matches() on representative data gives a "
                "spill-safe one")
        bound = estimate_max_matches(left_keys, right_keys)
        if bound > np.iinfo(np.int32).max and not jax.config.jax_enable_x64:
            raise OverflowError(
                f"join upper bound {bound} exceeds int32 pair offsets; "
                "enable jax_enable_x64 for int64 accumulation")
        max_matches = bound
    L, R = left_keys.shape[0], right_keys.shape[0]
    if L == 0 or R == 0:
        M = 0 if max_matches is None else int(max_matches)
        pad = jnp.full((M,), -1, jnp.int32)
        return JoinResult(pad, pad, jnp.zeros((), jnp.int32))

    lnan = None
    if jnp.issubdtype(left_keys.dtype, jnp.floating):
        # Join floats in the monotone bit domain: a TOTAL order, so the
        # binary search stays valid even with NaN build keys (raw floats
        # are not sorted under < once a negative-sign NaN lands before
        # -inf). Signed zeros collapse (-0.0 == +0.0 must match); NaN
        # probe rows match nothing (NaN != NaN), enforced below.
        lnan = jnp.isnan(left_keys)
        rnan = jnp.isnan(right_keys)
        lc = jnp.where(left_keys == 0, jnp.zeros_like(left_keys), left_keys)
        rc = jnp.where(right_keys == 0, jnp.zeros_like(right_keys),
                       right_keys)
        left_keys, _ = _sortable_bits(lc)
        right_keys, _ = _sortable_bits(rc)
        # distinct build-NaN payloads could alias a probe bit pattern
        # only if the probe is NaN too — suppressed via lnan; park build
        # NaNs at the domain top so they cluster past every real key
        top = jnp.iinfo(right_keys.dtype).max  # no non-NaN key maps here
        right_keys = jnp.where(rnan, jnp.full_like(right_keys, top),
                               right_keys)

    # Build: partition the right side to sorted order (radix passes).
    rk, rperm = radix_sort(right_keys, jnp.arange(R, dtype=jnp.int32))
    lo = jnp.searchsorted(rk, left_keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(rk, left_keys, side="right").astype(jnp.int32)
    if lnan is not None:
        hi = jnp.where(lnan, lo, hi)  # NaN probes match nothing

    # Probe offsets: exclusive prefix sum of per-row match counts —
    # accumulated in int64 under x64 (see segmented._offsets_dtype);
    # in int32 mode an overflowing eager join raises instead of wrapping.
    acc_dt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    m = (hi - lo).astype(acc_dt)
    off = scanlib.cumsum(m, exclusive=True, algorithm="blocked")
    total = off[-1] + m[-1]

    if max_matches is None:
        # Exact host-side recount: int32 accumulation wraps mod 2^32, so
        # both negative AND positive-wrapped totals are caught.
        exact = int(np.sum(np.asarray(m), dtype=np.int64))
        if exact != int(total):
            raise OverflowError(
                "join result exceeds int32 pair offsets; enable "
                "jax_enable_x64 for int64 accumulation")
        M = exact
    else:
        M = int(max_matches)
    if M == 0:
        pad = jnp.zeros((0,), jnp.int32)
        return JoinResult(pad, pad, total)

    # Expand: output slot p belongs to the last left row whose offset is
    # <= p (right-bisect skips rows with zero matches), at match number
    # p - off[row] within that row's [lo, hi) run. Slot ids must not wrap:
    # past 2^31 slots an int32 arange would alias, so widen (x64) or raise.
    if M > np.iinfo(np.int32).max:
        if not jax.config.jax_enable_x64:
            raise OverflowError(
                f"join capacity {M} exceeds int32 slot ids; enable "
                "jax_enable_x64 for int64 expansion")
        p = jnp.arange(M, dtype=jnp.int64)
    else:
        p = jnp.arange(M, dtype=jnp.int32)
    li = jnp.clip(
        jnp.searchsorted(off, p, side="right").astype(jnp.int32) - 1,
        0, L - 1)
    j = p - off[li]
    rs = jnp.clip(lo[li] + j, 0, R - 1)
    valid = p < total
    lidx = jnp.where(valid, li, -1)
    ridx = jnp.where(valid, rperm[rs], -1)
    return JoinResult(lidx, ridx, total)
