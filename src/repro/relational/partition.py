"""Stable radix partition: histogram + exclusive-cumsum offsets + scatter.

The paper's §1 partitioning step ("prefix sums are computed from a
previously constructed histogram ... and then used as the new index
values") applied to table data: elements are binned by a bucket id, each
bucket's base write offset is the exclusive prefix sum of the histogram,
and each element's slot within its bucket is its running per-bucket rank
(a segmented/one-hot scan). All of it runs on the scan substrate via
``repro.core.scan.segmented.dispatch_offsets``; MoE expert dispatch
(``models/layers/moe.py``) routes through here, with experts playing the
role of radix buckets.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import segmented as _segmented

# Same fields, relational-facing name: counts (histogram), offsets
# (exclusive scan = bucket base), ranks (within-bucket slot), dest
# (offsets[bucket] + rank — the paper's "new index values").
PartitionPlan = _segmented.DispatchPlan


def partition_plan(bucket_ids: jax.Array, num_buckets: int) -> PartitionPlan:
    """Prefix-sum partitioning plan for (T,) int bucket ids.

    ``plan.dest`` is a stable permutation of [0, T): elements keep their
    input order within each bucket (the property LSD radix sort rests on).
    """
    return _segmented.dispatch_offsets(bucket_ids, num_buckets)


def apply_plan(plan: PartitionPlan, *arrays: jax.Array) -> tuple:
    """Scatter each (T, ...) array to its partitioned order via ``dest``."""
    return tuple(
        jnp.zeros_like(a).at[plan.dest].set(a) for a in arrays)


def radix_partition(bucket_ids: jax.Array, num_buckets: int,
                    *payload: jax.Array):
    """Stably reorder data so bucket ``b`` occupies
    ``[offsets[b], offsets[b] + counts[b])``.

    Returns ``(plan, partitioned_ids, *partitioned_payload)``.
    """
    bucket_ids = jnp.asarray(bucket_ids)
    plan = partition_plan(bucket_ids, num_buckets)
    outs = apply_plan(plan, bucket_ids, *map(jnp.asarray, payload))
    return (plan,) + outs
