"""Sharded, async, fault-tolerant checkpointing.

Design (scaled-down Orbax-style, self-contained):

  * One directory per step: ``<root>/step_<N>/``; each leaf saved as a
    ``.npy`` (host-gathered here; per-shard ``leaf.shard<k>.npy`` files
    when leaves are sharded across processes in a real deployment).
  * A JSON **manifest** (treedef, shapes, dtypes, mesh shape, step,
    data-stream position) written LAST, then an atomic ``COMMIT`` marker —
    a partially-written checkpoint is never restorable, so a node failure
    mid-save costs nothing (restart resumes from the previous commit).
  * **Async**: ``save()`` snapshots to host RAM synchronously (cheap) and
    writes to disk on a background thread — training continues during the
    write, the next save joins the previous writer (back-pressure).
  * **Elastic restore**: the manifest stores logical shapes only; restore
    re-shards into WHATEVER mesh the new job runs (device count may
    change) by ``jax.device_put`` against the target sharding tree —
    elastic scaling across restarts.
  * Retention: ``keep`` most recent commits are kept, older are deleted.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

Pytree = Any

_COMMIT = "COMMIT"
_MANIFEST = "manifest.json"

# numpy can't round-trip ml_dtypes (bf16 etc.) through .npy; store the raw
# bits with the logical dtype recorded in the manifest.
_BITCAST = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _BITCAST:
        return arr.view(getattr(ml_dtypes, logical_dtype))
    return arr


def _leaf_paths(tree: Pytree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = ".".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and os.path.exists(
                os.path.join(root, d, _COMMIT)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def save_checkpoint(root: str, step: int, tree: Pytree,
                    extra: Optional[dict] = None) -> None:
    """Synchronous commit of ``tree`` at ``step`` (see manager for async)."""
    d = os.path.join(root, f"step_{step}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        savable, logical = _to_savable(arr)
        np.save(os.path.join(tmp, name + ".npy"), savable)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": logical})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(d):
        shutil.rmtree(d)
    os.replace(tmp, d)


def restore_checkpoint(root: str, step: int, like: Pytree,
                       shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``like``; device_put to ``shardings``
    (elastic: the saved mesh shape need not match the current one)."""
    d = os.path.join(root, f"step_{step}")
    if not os.path.exists(os.path.join(d, _COMMIT)):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    logical = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    names = dict(_leaf_paths(like))
    shard_leaves = (dict(_leaf_paths(shardings))
                    if shardings is not None else {})
    restored = {}
    for name, leaf in names.items():
        arr = np.load(os.path.join(d, name + ".npy"))
        arr = _from_saved(arr, logical.get(name, str(arr.dtype)))
        tgt_dtype = leaf.dtype
        val = jnp.asarray(arr).astype(tgt_dtype)
        sh = shard_leaves.get(name)
        restored[name] = jax.device_put(val, sh) if sh is not None else val
    # Rebuild the pytree in `like`'s structure.
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in flat:
        name = ".".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(restored[name])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_extra(root: str, step: int) -> dict:
    with open(os.path.join(root, f"step_{step}", _MANIFEST)) as f:
        return json.load(f).get("extra", {})


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._writer: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def save(self, step: int, tree: Pytree, extra: Optional[dict] = None,
             block: bool = False) -> None:
        # Snapshot to host memory NOW (device buffers may be donated by the
        # next train step); write to disk in the background.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()

        def _write():
            save_checkpoint(self.root, step, host_tree, extra)
            self._gc()

        self._writer = threading.Thread(target=_write, daemon=True)
        self._writer.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def restore_latest(self, like: Pytree,
                       shardings: Optional[Pytree] = None
                       ) -> tuple[Optional[int], Pytree, dict]:
        step = latest_step(self.root)
        if step is None:
            return None, like, {}
        tree = restore_checkpoint(self.root, step, like, shardings)
        return step, tree, read_extra(self.root, step)

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.root)
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.root, d, _COMMIT)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)
