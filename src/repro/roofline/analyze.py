"""Three-term roofline from a compiled (AOT) step.

    compute    = HLO_FLOPs   / (chips · peak_FLOP/s)
    memory     = HLO_bytes   / (chips · HBM_bw)
    collective = coll_bytes  / (chips · link_bw · links)

MEASURED CONVENTION: ``compiled.cost_analysis()`` on an SPMD-partitioned
module reports the PER-DEVICE program (verified: an 8-way-sharded matmul
reports total/8 flops), i.e. the "/ chips" division in the formulas above
is already applied by XLA. The terms below therefore use the per-device
numbers directly against per-chip peak rates — equivalent to the spec's
formulas. The same holds for the optimized HLO text: collective op shapes
are per-device shapes, so summed collective bytes are per-chip wire bytes
(all-gather output = full gathered tensor ≈ bytes through each chip's
links for a ring schedule; all-reduce counted once ≈ the reduce-scatter
half — a deliberate ~2x-optimistic convention, constant across cells).

Collective bytes are NOT in cost_analysis: we parse the optimized HLO
(``compiled.as_text()``) and sum shapes of every all-gather / all-reduce
/ reduce-scatter / all-to-all / collective-permute. Cross-pod collectives
(replica groups spanning pods) are attributed to the DCN term separately
— the slow hop at 1000+ node scale.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Optional

import numpy as np

from repro.launch import mesh as meshlib

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,1024,512]{2,1,0} all-gather(...)"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum of output-shape bytes per collective kind in the optimized HLO.

    Output-shape convention: for all-gather the output is the gathered
    (full) tensor = bytes that cross links; for reduce-scatter the input
    is larger but wire bytes ≈ input ≈ output·shards — we report output
    bytes for a conservative, uniform convention and scale per-op in the
    roofline terms where it matters. Fusion parameters are skipped; both
    sync and async (``-start``) forms are counted once.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # match "<name> = <shape(s)> <op>(" — shape may be a tuple
        m = re.match(
            r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z][a-z\-]*)\(",
            line)
        if not m:
            continue
        shape_str, op = m.groups()
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        pieces = [_shape_bytes(p.group(0)) for p in
                  re.finditer(r"[a-z0-9]+\[[0-9,]*\]", shape_str)]
        if not pieces:
            continue
        # async ("-start") ops produce (operand, result) tuples — count the
        # RESULT (last element), matching the sync-op output convention.
        total = pieces[-1] if op.endswith("-start") and len(pieces) > 1 \
            else sum(pieces)
        out[base] += total
    return out


def _parse_replica_groups(line: str):
    """Yield device-id groups from either HLO replica-group syntax.

    Explicit:  replica_groups={{0,1},{2,3}}
    Iota:      replica_groups=[4,4]<=[16]            (reshape of arange)
               replica_groups=[4,4]<=[4,4]T(1,0)     (transposed arange)
    """
    m = re.search(r"replica_groups=\{\{([^=]*?)\}\}", line)
    if m:
        for grp in m.group(1).split("},{"):
            yield [int(x) for x in re.findall(r"\d+", grp)]
        return
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?",
        line)
    if not m:
        return
    g, s, dims_s, perm_s = m.groups()
    dims = [int(x) for x in dims_s.split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if perm_s:
        ids = ids.transpose([int(x) for x in perm_s.split(",")])
    ids = ids.reshape(int(g), int(s))
    for row in ids:
        yield row.tolist()


def _cross_pod_bytes(hlo_text: str, chips_per_pod: int) -> int:
    """Bytes of collectives whose replica groups span pod boundaries."""
    total = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z][a-z\-]*)\(",
            line)
        if not m:
            continue
        shape_str, op = m.groups()
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        spans = False
        for ids in _parse_replica_groups(line):
            if ids and (max(ids) // chips_per_pod) != (min(ids) //
                                                       chips_per_pod):
                spans = True
                break
        # collective-permute: source_target_pairs instead of replica_groups
        if not spans and "source_target_pairs" in line:
            pm = re.search(r"source_target_pairs=\{([^}]*(?:\},\{[^}]*)*)\}",
                           line)
            if pm:
                for pair in re.findall(r"\{(\d+),(\d+)\}", pm.group(0)):
                    a, b = int(pair[0]), int(pair[1])
                    if a // chips_per_pod != b // chips_per_pod:
                        spans = True
                        break
        if spans:
            pieces = [_shape_bytes(p.group(0)) for p in
                      re.finditer(r"[a-z0-9]+\[[0-9,]*\]", shape_str)]
            if pieces:
                total += (pieces[-1] if op.endswith("-start")
                          and len(pieces) > 1 else sum(pieces))
    return total


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: dict[str, int]
    cross_pod_bytes: int
    compute_s: float
    memory_s: float
    collective_s: float
    dcn_s: float
    dominant: str
    model_flops: float
    useful_ratio: float        # MODEL_FLOPS / HLO_FLOPs
    bytes_per_device: Optional[float] = None
    peak_memory_per_device: Optional[float] = None

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @property
    def bound(self) -> float:
        """Roofline-implied step seconds (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.dcn_s)


def model_flops(cfg, batch: int, seq: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward.

    N = active params (MoE: top-k experts only); D = tokens processed.
    Decode processes batch·1 new tokens per step.
    """
    n = cfg.active_param_count()
    if kind == "train":
        per_tok = 6.0 * n
        tokens = batch * seq
    elif kind == "prefill":
        per_tok = 2.0 * n
        tokens = batch * seq
    else:  # decode: one token per sequence
        per_tok = 2.0 * n
        tokens = batch * 1
    return per_tok * tokens


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cfg,
    batch: int,
    seq: int,
    kind: str,
    hlo_text: Optional[str] = None,
    chips_per_pod: int = 256,
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    xpod = _cross_pod_bytes(text, chips_per_pod) if chips > chips_per_pod \
        else 0
    coll_total = sum(coll.values())

    # cost_analysis numbers are PER-DEVICE (see module docstring): compare
    # against per-chip peak rates directly.
    compute_s = flops / meshlib.PEAK_FLOPS_BF16
    memory_s = nbytes / meshlib.HBM_BW
    collective_s = coll_total / (meshlib.ICI_BW * meshlib.ICI_LINKS)
    dcn_s = xpod / meshlib.DCN_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s, "dcn": dcn_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, batch, seq, kind)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "bytes_per_device": float(
                getattr(ma, "argument_size_in_bytes", 0) +
                getattr(ma, "output_size_in_bytes", 0)),
            "peak_memory_per_device": float(
                getattr(ma, "temp_size_in_bytes", 0) +
                getattr(ma, "argument_size_in_bytes", 0)),
        }
    except Exception:  # noqa: BLE001 — memory stats are best-effort
        pass

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, collective_bytes=coll,
        cross_pod_bytes=xpod, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dcn_s=dcn_s, dominant=dominant,
        model_flops=mf,
        # useful_ratio compares per-device useful flops to per-device HLO
        # flops (cost_analysis is per-device).
        useful_ratio=((mf / chips) / flops if flops else 0.0),
        **mem,
    )
