"""Per-op breakdowns of a compiled module — the dry-run 'profiler'.

No wall-clock exists on this container, so hypothesis formation works on
the optimized HLO: which collectives move the most bytes, how many dots /
how much dot-flops, what the biggest temp buffers are. This is the
"enumerate → napkin-math → pick the biggest win" input (EXPERIMENTS §Perf).
"""

from __future__ import annotations

import re
from collections import defaultdict

from repro.roofline.analyze import _COLLECTIVES, _SHAPE_RE, _shape_bytes

_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"([a-z\-]+)")


def top_collectives(hlo_text: str, k: int = 15) -> list[dict]:
    """Largest collective ops: kind, bytes, shape, metadata op_name."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        total = sum(_shape_bytes(p.group(0)) for p in
                    re.finditer(r"[a-z0-9]+\[[0-9,]*\]", shape_str))
        meta = re.search(r'op_name="([^"]*)"', line)
        out.append({"name": name, "kind": base, "bytes": total,
                    "shape": shape_str[:60],
                    "op_name": (meta.group(1)[:90] if meta else "")})
    out.sort(key=lambda d: -d["bytes"])
    return out[:k]


def collective_summary_by_source(hlo_text: str) -> dict[str, int]:
    """Collective bytes grouped by the annotated source op_name prefix."""
    agg: dict[str, int] = defaultdict(int)
    for rec in top_collectives(hlo_text, k=10**9):
        key = rec["op_name"].split("/")[:3]
        agg["/".join(key) or "(unannotated)"] += rec["bytes"]
    return dict(sorted(agg.items(), key=lambda kv: -kv[1]))


def dot_flops(hlo_text: str, k: int = 10) -> list[dict]:
    """Largest dot/convolution ops by output size (flops proxy)."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        if op not in ("dot", "convolution"):
            continue
        sm = _SHAPE_RE.match(shape_str)
        if not sm:
            continue
        meta = re.search(r'op_name="([^"]*)"', line)
        out.append({"name": name, "out_bytes": _shape_bytes(shape_str),
                    "shape": shape_str[:50],
                    "op_name": (meta.group(1)[:80] if meta else "")})
    out.sort(key=lambda d: -d["out_bytes"])
    return out[:k]


def top_outputs(hlo_text: str, k: int = 15, exclude=("parameter",)) -> list:
    """Largest op outputs (peak-memory suspects), excluding parameters."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        if op in exclude:
            continue
        total = sum(_shape_bytes(p.group(0)) for p in
                    re.finditer(r"[a-z0-9]+\[[0-9,]*\]", shape_str))
        meta = re.search(r'op_name="([^"]*)"', line)
        out.append({"name": name[:28], "op": op, "bytes": total,
                    "shape": shape_str[:44],
                    "op_name": (meta.group(1)[:70] if meta else "")})
    out.sort(key=lambda d: -d["bytes"])
    return out[:k]
