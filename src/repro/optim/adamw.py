"""AdamW with f32 master weights over bf16 compute params.

State layout mirrors the param pytree (so ``spec_for_params`` shards the
optimizer state identically to the parameters — ZeRO-style when
``embed_fsdp`` maps to the data axis). ``mu``/``nu`` are f32; ``master``
holds f32 weights when the params themselves are lower precision, else it
is an empty sentinel and updates apply directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array            # () int32
    mu: Pytree                 # f32, like params
    nu: Pytree                 # f32, like params
    master: Pytree             # f32 master copy (or params when already f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # decay only matrices (ndim >= 2); norms/biases are excluded, matching
    # standard LM practice.
    decay_min_ndim: int = 2


def adamw_init(params: Pytree) -> AdamWState:
    f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    # copy=True: an f32 param must not ALIAS its master copy, or donating
    # params and opt_state to the same jitted step double-donates a buffer.
    master = jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        master=master,
    )


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Pytree,
    state: AdamWState,
    params: Pytree,
    cfg: AdamWConfig = AdamWConfig(),
    lr: Optional[jax.Array] = None,
) -> tuple[Pytree, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(w, m, v):
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if w.ndim >= cfg.decay_min_ndim:
            delta = delta + cfg.weight_decay * w
        return w - lr_t * delta

    master = jax.tree.map(upd, state.master, mu, nu)
    new_params = jax.tree.map(
        lambda mw, p: mw.astype(p.dtype), master, params)
    new_state = AdamWState(step=step, mu=mu, nu=nu, master=master)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr_t, jnp.float32)}
    return new_params, new_state, metrics
