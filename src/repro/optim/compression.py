"""Gradient compression with error feedback (distributed-optimization trick).

int8 blockwise-scaled quantization of gradients before the cross-pod
all-reduce, with an error-feedback accumulator so the quantization error is
re-injected next step (Karimireddy et al. 2019 — EF-SGD convergence
guarantee). Intended use at 1000+ node scale: the in-pod reduce-scatter
stays full precision (cheap, fast ICI); only the slow cross-pod hop is
compressed — wired in ``train/step.py`` when ``compress_cross_pod=True``.

Quantization is blockwise over the last axis (block 256): each block
carries one f32 scale = max|g|/127 — 4.03 bits/elem effective vs 32.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

_BLOCK = 256


def init_error_feedback(grads_like: Pytree) -> Pytree:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def compress_grads(grads: Pytree, error: Pytree):
    """(grads + error) -> (q int8, scales f32, new shapes); per-leaf."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        pad = _pad_len(flat.shape[0])
        fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
        scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.shape[0]]
        new_err = g - deq.reshape(g.shape)
        return (q, scale.squeeze(-1)), new_err

    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error)
    qs, errs = [], []
    for g, e in zip(leaves, err_leaves):
        qe, ne = one(g, e)
        qs.append(qe)
        errs.append(ne)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, errs)


def compressed_psum(x: jax.Array, axis_name: str,
                    error: "jax.Array | None" = None):
    """int8 quantized psum over ``axis_name`` with local error feedback.

    For use INSIDE ``shard_map`` (manual-DP deployments): quantize the
    local contribution, integer-psum the int8 payload (4x fewer bytes on
    the wire than f32; the scales are one f32 pmax), dequantize, and
    return the residual so the caller can carry it into the next step
    (EF-SGD). ``error`` is the carried residual from the previous step.

    Returns (reduced f32 array, new local residual).
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    scale = jnp.maximum(jnp.max(jnp.abs(xf)) / 127.0, 1e-12)
    scale = jax.lax.pmax(scale, axis_name)  # shared scale: exact int sum
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    reduced = total.astype(jnp.float32) * scale
    residual = xf - q.astype(jnp.float32) * scale
    return reduced, residual


def decompress_grads(compressed: Pytree, shapes: Pytree) -> Pytree:
    """Inverse of ``compress_grads`` given the original leaf shapes."""

    def one(qe, like):
        q, scale = qe
        deq = q.astype(jnp.float32) * scale[:, None]
        flat = deq.reshape(-1)[: like.size]
        return flat.reshape(like.shape)

    # compressed is a tree of (q, scale) 2-tuples aligned with `shapes`.
    q_leaves, treedef = jax.tree.flatten(
        compressed, is_leaf=lambda x: isinstance(x, tuple))
    shape_leaves = jax.tree.leaves(shapes)
    outs = [one(q, s) for q, s in zip(q_leaves, shape_leaves)]
    return jax.tree.unflatten(treedef, outs)
