"""Learning-rate schedules (cosine with linear warmup)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(
    step,
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    min_ratio: float = 0.1,
):
    """Linear warmup to ``peak_lr`` then cosine decay to ``min_ratio·peak``."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)
