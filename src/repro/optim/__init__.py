from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               global_norm)
from repro.optim.compression import (compress_grads, decompress_grads,
                                     init_error_feedback)
from repro.optim.schedule import cosine_schedule

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "compress_grads",
    "cosine_schedule", "decompress_grads", "global_norm",
    "init_error_feedback",
]
