from repro.data.pipeline import (Batch, DataConfig, SyntheticDataset,
                                 make_batch_specs)
from repro.data.packing import pack_documents, packing_offsets

__all__ = [
    "Batch", "DataConfig", "SyntheticDataset", "make_batch_specs",
    "pack_documents", "packing_offsets",
]
