"""Synthetic sharded data pipeline.

Deterministic: batch for global step ``s`` is a pure function of
``(seed, s)`` — restart-safe (fault tolerance requires the data stream to
be reproducible from the checkpointed step counter alone) and
host-local: each host materializes ONLY its shard of the global batch
(``jax.make_array_from_process_local_data`` in multi-host deployments; in
this container single-process ``device_put`` with the right sharding).

The token stream is Zipf-distributed over the vocab (matches LM token
frequency shape, keeps the loss landscape non-degenerate) with document
boundaries every ~doc_len tokens so packing/segmenting paths are
exercised.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32_000
    seed: int = 0
    mean_doc_len: int = 512
    frontend_tokens: int = 0     # vlm/audio: precomputed embedding positions
    frontend_dim: int = 1024


class Batch(dict):
    """dict with attribute access: tokens, labels, mask[, embeds]."""

    def __getattr__(self, name):
        try:
            return self[name]
        except KeyError:
            raise AttributeError(name) from None


jax.tree_util.register_pytree_node(
    Batch,
    lambda b: (tuple(b[k] for k in sorted(b)), tuple(sorted(b))),
    lambda keys, vals: Batch(zip(keys, vals)),
)


def _batch_for_step(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD1CE]))
    B, S = cfg.global_batch, cfg.seq_len
    # Zipf-ish token draw (power law over vocab ranks).
    u = rng.random((B, S + 1))
    ranks = np.floor((cfg.vocab_size - 1) * u ** 3.0).astype(np.int32)
    toks = np.minimum(ranks, cfg.vocab_size - 1)
    # Document boundaries -> EOS resets for the mask.
    boundary = rng.random((B, S + 1)) < (1.0 / max(cfg.mean_doc_len, 2))
    toks = np.where(boundary, 1, toks)  # id 1 = synthetic EOS
    out = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].astype(np.int32),
        "mask": np.ones((B, S), np.float32),
    }
    if cfg.frontend_tokens:
        out["embeds"] = rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    return out


def make_batch_specs(mesh: Optional[Mesh], batch_axes: tuple[str, ...] = (
        "pod", "data")) -> "P":
    """PartitionSpec for batch leaves: batch dim over the data axes."""
    if mesh is None:
        return P()
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


class SyntheticDataset:
    """Iterator over deterministic synthetic batches, device-placed."""

    def __init__(self, cfg: DataConfig, mesh: Optional[Mesh] = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.step = start_step

    def batch_at(self, step: int) -> Batch:
        np_batch = _batch_for_step(self.cfg, step)
        if self.mesh is None:
            return Batch({k: jnp.asarray(v) for k, v in np_batch.items()})
        spec = make_batch_specs(self.mesh)
        out = {}
        for k, v in np_batch.items():
            sh = NamedSharding(self.mesh, P(*(list(spec) + [None] * (
                v.ndim - 1))))
            out[k] = jax.device_put(v, sh)
        return Batch(out)

    def __iter__(self):
        return self

    def __next__(self) -> Batch:
        b = self.batch_at(self.step)
        self.step += 1
        return b
