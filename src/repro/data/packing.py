"""Sequence packing via exclusive prefix-sum offsets.

This is the paper's motivating database use case ("determine the new
offsets of data items during a partitioning step") inside the training
data pipeline: documents of ragged lengths are packed into fixed-length
rows, and every document's destination offset is the exclusive prefix sum
of the lengths that precede it. The segment-id tensor used for the packed
attention mask comes from the same scan (a segmented cumsum of
begin-flags).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import scan as scanlib


def packing_offsets(lengths: jax.Array, row_len: int):
    """Greedy bin assignment of documents into rows of ``row_len``.

    Returns (row_idx, col_idx) per document: each document d goes to row
    ``row_idx[d]`` starting at column ``col_idx[d]``. Documents longer
    than ``row_len`` must be pre-split by the caller. The running total of
    lengths is an inclusive scan; the row boundary logic keeps a simple
    greedy next-fit: a doc that would overflow its row opens the next row.

    Implemented with the scan substrate (no Python loop over docs): the
    next-fit row assignment is itself computed by scanning the lengths
    with an affine-with-reset style recurrence expressed via lax.scan.

    Zero-length documents are tolerated: they never advance the packing
    state (no phantom row opens, later documents land exactly where they
    would without the empty entry) and are assigned the current cursor
    as a placeholder — callers must mask token writes by ``length > 0``
    (``pack_documents`` does, via its ``valid`` mask).
    """
    lengths = lengths.astype(jnp.int32)

    def step(carry, ln):
        row, col = carry
        overflow = (ln > 0) & (col + ln > row_len)
        row = jnp.where(overflow, row + 1, row)
        start = jnp.where(overflow, 0, col)
        return (row, start + ln), (row, start)

    (_, _), (rows, cols) = jax.lax.scan(
        step, (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)), lengths)
    return rows, cols


def pack_documents(docs: jax.Array, lengths: jax.Array, row_len: int,
                   num_rows: int, pad_id: int = 0):
    """Scatter ragged documents (docs: (D, max_doc_len)) into packed rows.

    Returns (tokens (num_rows, row_len), segment_ids (num_rows, row_len)).
    segment_ids are 1-based per row, 0 = padding; they feed block-diagonal
    attention masks. Uses the exclusive-scan offsets of ``packing_offsets``.
    """
    D, max_len = docs.shape
    rows, cols = packing_offsets(lengths, row_len)

    # Flatten destination: row * row_len + col + [0..len) per token.
    tok_pos = jnp.arange(max_len)[None, :]                  # (1, max_len)
    valid = tok_pos < lengths[:, None]                      # (D, max_len)
    dest = rows[:, None] * row_len + cols[:, None] + tok_pos
    dest = jnp.where(valid, dest, num_rows * row_len)       # park invalid

    flat = jnp.full((num_rows * row_len + 1,), pad_id, docs.dtype)
    flat = flat.at[dest.reshape(-1)].set(docs.reshape(-1))
    tokens = flat[:-1].reshape(num_rows, row_len)

    seg = jnp.zeros((num_rows * row_len + 1,), jnp.int32)
    seg = seg.at[dest.reshape(-1)].set(
        jnp.broadcast_to((jnp.arange(D) + 1)[:, None], dest.shape)
        .reshape(-1) * valid.reshape(-1).astype(jnp.int32))
    segment_ids = seg[:-1].reshape(num_rows, row_len)
    return tokens, segment_ids


def segment_starts_to_ids(starts: jax.Array) -> jax.Array:
    """Begin-flags -> 1-based segment ids via inclusive cumsum (scan API).

    Flags are clamped to 0/1 first: a slot where several documents
    "start" because zero-length entries collapsed onto it (scatter-add
    producing a flag of 2+) still begins exactly ONE segment — without
    the clamp the cumsum would skip ids, emitting phantom segments.
    """
    flags = (starts != 0).astype(jnp.int32)
    return scanlib.cumsum(flags, axis=-1, algorithm="blocked")
