"""Training loop with fault tolerance, straggler mitigation, elasticity.

Large-scale runnability mechanisms (DESIGN.md §5; all exercised by tests):

  * **Checkpoint/restart**: async committed checkpoints every
    ``checkpoint_every`` steps (manifest + COMMIT marker — a crash mid-
    write never corrupts); on start the trainer resumes from the latest
    commit, replaying the data stream from the checkpointed step (the
    synthetic pipeline is a pure function of (seed, step)).
  * **Step retry**: a failing step (device OOM, preempted host, flaky
    interconnect surfaces as an exception from the jitted call) is
    retried up to ``max_step_retries`` after re-materializing state from
    the last checkpoint — the single-process analogue of a coordinated
    restart; at fleet scale the same logic runs under a job scheduler
    that re-provisions the mesh first (elastic restore re-shards into the
    new topology via ``checkpoint.restore_checkpoint``).
  * **Straggler mitigation**: per-step wall-times feed an online
    mean/variance tracker; a step slower than ``straggler_zscore`` σ is
    logged with its index. In a multi-host deployment this signal drives
    the scheduler's hot-spare swap; here it additionally triggers an
    immediate checkpoint so the swap loses no work. (SPMD steps are
    globally synchronous, so "one slow step" IS the straggler signature
    visible from any single host.)
  * **NaN/overflow guard**: non-finite loss skips the update (params
    and optimizer state roll back to the pre-step buffers) and counts
    toward ``max_nan_skips`` — the standard bf16 large-batch guard.
"""

from __future__ import annotations

import dataclasses
import logging
import math
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.obs import default_registry, trace

log = logging.getLogger("repro.train")

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    max_step_retries: int = 2
    max_nan_skips: int = 10
    straggler_zscore: float = 3.0
    straggler_min_samples: int = 20


class _StragglerTracker:
    """Online mean/std of step times (Welford) + z-score flagging."""

    def __init__(self, zscore: float, min_samples: int):
        self.z = zscore
        self.min_samples = min_samples
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.n >= self.min_samples:
            std = math.sqrt(self.m2 / max(self.n - 1, 1))
            if std > 0 and (dt - self.mean) / std > self.z:
                is_straggler = True
                self.flagged.append(step)
        self.n += 1
        d = dt - self.mean
        self.mean += d / self.n
        self.m2 += d * (dt - self.mean)
        return is_straggler


class Trainer:
    def __init__(
        self,
        step_fn: Callable,          # (params, opt, batch, idx) -> (p, o, m)
        dataset,                    # iterator with .batch_at(step)
        tcfg: TrainerConfig,
        ckpt: Optional[CheckpointManager] = None,
    ):
        self.step_fn = step_fn
        self.dataset = dataset
        self.cfg = tcfg
        self.ckpt = ckpt or CheckpointManager(
            tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
        self.straggler = _StragglerTracker(
            tcfg.straggler_zscore, tcfg.straggler_min_samples)
        self.history: list[dict] = []
        self.nan_skips = 0

    # -- state (de)hydration -------------------------------------------
    def _bundle(self, params, opt_state):
        return {"params": params, "opt": opt_state}

    def maybe_restore(self, params, opt_state, shardings=None):
        step, tree, extra = self.ckpt.restore_latest(
            self._bundle(params, opt_state), shardings)
        if step is None:
            return 0, params, opt_state
        log.info("restored checkpoint at step %d", step)
        return step, tree["params"], tree["opt"]

    # -- main loop ------------------------------------------------------
    def run(self, params, opt_state, start_step: int = 0):
        step = start_step
        while step < self.cfg.total_steps:
            batch = self.dataset.batch_at(step)
            t0 = time.perf_counter()
            for attempt in range(self.cfg.max_step_retries + 1):
                try:
                    # The span closes on the loss sync, so it measures the
                    # whole step (dispatch + device) — what the straggler
                    # tracker sees.
                    with trace.span("train.step", step=step,
                                    attempt=attempt):
                        new_params, new_opt, metrics = self.step_fn(
                            params, opt_state, batch, jnp.asarray(step))
                        loss = float(jax.device_get(metrics["loss"]))
                    break
                except Exception as e:  # noqa: BLE001 — retry path
                    log.warning("step %d attempt %d failed: %s",
                                step, attempt, e)
                    trace.instant("train.step.retry", step=step,
                                  attempt=attempt, error=repr(e))
                    if attempt == self.cfg.max_step_retries:
                        raise
                    # Re-materialize from the last commit (simulated
                    # coordinated restart).
                    step_r, params, opt_state = self.maybe_restore(
                        params, opt_state)
                    step = max(step_r, 0)
                    batch = self.dataset.batch_at(step)
            dt = time.perf_counter() - t0
            default_registry().histogram("train.step_s").record(dt)

            if not math.isfinite(loss):
                trace.instant("train.nan_skip", step=step,
                              skips=self.nan_skips + 1)
                self.nan_skips += 1
                log.warning("non-finite loss at step %d (skip %d/%d)",
                            step, self.nan_skips, self.cfg.max_nan_skips)
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise FloatingPointError(
                        f"too many non-finite losses (step {step})")
                step += 1
                continue  # params/opt_state NOT updated — rollback

            params, opt_state = new_params, new_opt
            self.history.append(
                {"step": step, "loss": loss, "time_s": dt})

            if self.straggler.observe(step, dt):
                log.warning(
                    "straggler step %d (%.3fs vs mean %.3fs) — "
                    "checkpointing for hot-swap", step, dt,
                    self.straggler.mean)
                self.ckpt.save(step + 1, self._bundle(params, opt_state),
                               extra={"reason": "straggler"})

            if step % self.cfg.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, self._bundle(params, opt_state),
                               extra={"loss": loss})
            step += 1

        self.ckpt.save(step, self._bundle(params, opt_state), block=True)
        return params, opt_state
