from repro.train.step import TrainStepConfig, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["TrainStepConfig", "Trainer", "TrainerConfig", "make_train_step"]
