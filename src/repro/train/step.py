"""Jitted train step: loss -> grads -> AdamW, sharded over the mesh.

Distribution features (DESIGN.md §5):
  * DP over ('pod','data'); TP/EP over 'model' — all via the logical-axis
    tables in ``repro.dist.sharding`` (params + activations).
  * Gradient **accumulation** over microbatches: ``lax.scan`` over a
    leading micro axis, f32 grad accumulator, single optimizer apply.
  * **Remat** (activation checkpointing): configurable policy on the
    layer-scan body; "nothing_saveable" minimizes live memory, "dots"
    keeps matmul outputs (less recompute — the §Perf iteration toggles
    this).
  * **Buffer donation**: params/opt-state donated (in-place update, the
    paper's in-place variant at the XLA level).
  * **Cross-pod gradient compression** lives in
    ``repro.optim.compression.compressed_psum`` (int8 + error feedback)
    for manual-DP (shard_map) deployments where the slow inter-pod hop is
    compressed and the in-pod reduce-scatter stays full precision; the
    default pjit path leaves the hierarchical reduction to XLA (see
    DESIGN.md §5 — measured trade-off in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import make_batch_specs
from repro.dist import sharding as shd
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.schedule import cosine_schedule

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    remat: bool = True
    # None = auto (dense<=4k, blockwise); "flash" trains on the engine
    # kernel — its custom_vjp runs the backward as scan-engine folds, so
    # dense, blockwise and flash are grad-parity-checkable peers.
    attn_impl: Optional[str] = None
    attn_schedule: str = "auto"       # flash fold organization
    # None = auto (chunked reference when training); "kernel" trains SSM
    # layers on the engine's affine kernel — its custom_vjp runs the
    # backward as one more engine scan, mirroring attn_impl="flash".
    ssm_impl: Optional[str] = None
    unroll_layers: bool = False       # dry-run: full cost in the HLO
    loss_chunk: int = 512
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    weight_decay: float = 0.1
    compress_cross_pod: bool = False


def loss_fn_for(cfg: ModelConfig) -> Callable:
    if cfg.is_encdec:
        return encdec_mod.encdec_loss
    return lm_mod.lm_loss


def init_params(key, cfg: ModelConfig) -> Pytree:
    if cfg.is_encdec:
        return encdec_mod.init_encdec(key, cfg)
    return lm_mod.init_lm(key, cfg)


def _accumulate_grads(loss_fn, params, batch, tcfg: TrainStepConfig,
                      cfg: ModelConfig):
    """Microbatched value_and_grad with an f32 accumulator."""
    m = tcfg.microbatches
    if m <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, remat=tcfg.remat,
                              loss_chunk=tcfg.loss_chunk,
                              attn_impl=tcfg.attn_impl,
                              attn_schedule=tcfg.attn_schedule,
                              ssm_impl=tcfg.ssm_impl,
                              unroll=tcfg.unroll_layers),
            has_aux=True)(params)
        return loss, metrics, grads

    def reshape(x):
        B = x.shape[0]
        return x.reshape((m, B // m) + x.shape[1:])

    micro = jax.tree.map(reshape, dict(batch))
    gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        gacc, lacc, macc = carry
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, mb, cfg, remat=tcfg.remat,
                              loss_chunk=tcfg.loss_chunk,
                              attn_impl=tcfg.attn_impl,
                              attn_schedule=tcfg.attn_schedule,
                              ssm_impl=tcfg.ssm_impl,
                              unroll=tcfg.unroll_layers),
            has_aux=True)(params)
        gacc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / m, gacc, grads)
        macc = jax.tree.map(lambda a, v: a + v / m, macc, metrics)
        return (gacc, lacc + loss / m, macc), None

    metrics0 = jax.tree.map(
        lambda _: jnp.zeros((), jnp.float32),
        jax.eval_shape(lambda: loss_fn(params, jax.tree.map(
            lambda x: x[0], micro), cfg, remat=False,
            loss_chunk=tcfg.loss_chunk)[1]))
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (gz, jnp.zeros((), jnp.float32), metrics0), micro)
    return loss, metrics, grads


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainStepConfig = TrainStepConfig(),
    adamw_cfg: Optional[adamw.AdamWConfig] = None,
):
    """Returns ``step(params, opt_state, batch, step_idx) -> (...)``.

    Jit with shardings is applied by the caller (launch/train.py or
    launch/dryrun.py) so the same function serves CPU tests (no mesh) and
    the production mesh.
    """
    acfg = adamw_cfg or adamw.AdamWConfig(
        lr=tcfg.peak_lr, grad_clip=tcfg.grad_clip,
        weight_decay=tcfg.weight_decay)
    loss_fn = loss_fn_for(cfg)

    def step(params, opt_state, batch, step_idx):
        loss, metrics, grads = _accumulate_grads(
            loss_fn, params, batch, tcfg, cfg)
        lr = cosine_schedule(
            step_idx, peak_lr=tcfg.peak_lr, warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps)
        new_params, new_state, opt_metrics = adamw.adamw_update(
            grads, opt_state, params, acfg, lr=lr)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return new_params, new_state, metrics

    return step


def shardings_for(mesh: Mesh, params: Pytree, opt_state: Any,
                  batch_like: dict):
    """(in_shardings, out_shardings) trees for jit(train_step)."""
    with shd.use_mesh(mesh):
        pspec = shd.spec_for_params(params)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
    oshard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=pshard, nu=pshard, master=pshard)
    bspec = make_batch_specs(mesh)
    bshard = {
        k: NamedSharding(mesh, P(*([bspec[0]] + [None] * (v.ndim - 1))))
        if getattr(v, "ndim", 0) else NamedSharding(mesh, P())
        for k, v in batch_like.items()}
    mshard = NamedSharding(mesh, P())
    in_sh = (pshard, oshard, bshard, mshard)
    out_sh = (pshard, oshard, None)
    return in_sh, out_sh
