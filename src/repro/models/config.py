"""Model configuration schema shared by all 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact values live in repro/configs/)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    head_dim: int = 64
    d_ff: int = 3072
    vocab_size: int = 32000

    # --- attention ---
    rope_theta: float = 10_000.0
    rope_theta_local: Optional[float] = None  # gemma3 local layers
    sliding_window: Optional[int] = None      # width for "local" layers
    attn_softcap: Optional[float] = None      # gemma2 logit soft-capping
    final_softcap: Optional[float] = None     # gemma2 LM-head soft-capping
    qk_norm: bool = False                     # qwen3 / gemma3 per-head norm
    query_scale: Optional[float] = None       # overrides 1/sqrt(head_dim)

    # --- layer wiring ---
    # One period of block kinds; tiled num_layers//len(pattern) times, with
    # any remainder taken as a prefix of the pattern. Kinds:
    #   global | local | moe | mamba | slstm | mlstm | shared_attn
    layer_pattern: Tuple[str, ...] = ("global",)

    # --- norm / mlp ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm | gemma_rmsnorm
    norm_eps: float = 1e-6
    act: str = "silu"      # silu | gelu | relu
    gated_mlp: bool = True
    post_block_norm: bool = False  # gemma2/3 extra post-norms

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- SSM (mamba2 / xlstm) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 128

    # --- enc-dec (seamless) ---
    encoder_layers: int = 0  # > 0 ⇒ encoder-decoder with cross attention

    # --- modality frontend stub (vlm / audio): inputs arrive as embeddings
    frontend_tokens: int = 0  # prepended precomputed-embedding positions

    # --- misc ---
    tie_embeddings: bool = True
    max_seq_len: int = 131_072
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must divide evenly by num_kv_heads")
        if self.family == "moe" and not (self.num_experts and self.top_k):
            raise ValueError("moe family requires num_experts and top_k")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def pattern_periods(self) -> tuple[int, int]:
        """(full periods, remainder layers) of layer_pattern in num_layers."""
        p = len(self.layer_pattern)
        return self.num_layers // p, self.num_layers % p

    @property
    def ssm_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks); used for
        MODEL_FLOPS = 6·N·D in the roofline analysis."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_kind = {}
        attn = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads \
            * self.head_dim + self.num_heads * self.head_dim * d
        mlp = (3 if self.gated_mlp else 2) * d * self.d_ff
        per_kind["global"] = attn + mlp
        per_kind["local"] = attn + mlp
        # per-layer in/out projections around the ONE shared block
        per_kind["shared_attn"] = 3 * d * d + 2 * d
        router = d * self.num_experts
        expert = (3 if self.gated_mlp else 2) * d * self.moe_d_ff
        per_kind["moe"] = attn + router + self.num_experts * expert
        inner = self.ssm_heads * self.ssm_head_dim or self.ssm_expand * d
        conv_dim = inner + 2 * self.ssm_state
        per_kind["mamba"] = (d * (2 * inner + 2 * self.ssm_state
                                  + self.ssm_heads) + inner * d
                             + (self.conv_kernel + 1) * conv_dim
                             + 3 * self.ssm_heads + inner)
        # exact per init_slstm/init_mlstm (models/layers/xlstm.py)
        sl_heads = self.ssm_heads or self.num_heads
        sl_dh = d // sl_heads
        per_kind["slstm"] = (4 * (d * d + sl_heads * sl_dh * sl_dh
                                  + sl_heads * sl_dh)
                             + d + d * d
                             + (3 if self.gated_mlp else 2) * d
                             * (4 * d // 3) + 2 * d)
        m_inner = self.ssm_expand * d
        per_kind["mlstm"] = (d * 2 * m_inner
                             + (self.conv_kernel + 1) * m_inner
                             + 3 * m_inner * m_inner
                             + 2 * (m_inner * sl_heads + sl_heads)
                             + m_inner + m_inner * d)
        periods, rem = self.pattern_periods
        kinds = list(self.layer_pattern) * periods + \
            list(self.layer_pattern[:rem])
        total = emb + sum(per_kind.get(k, attn + mlp) for k in kinds)
        if "shared_attn" in self.layer_pattern:
            total += per_kind["global"]  # the ONE shared attn+mlp block
        if self.is_encdec:
            total += self.encoder_layers * (attn + mlp) \
                + self.num_layers * attn  # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        expert = (3 if self.gated_mlp else 2) * d * self.moe_d_ff
        total = self.param_count()
        total -= self.num_layers * (self.num_experts - self.top_k) * expert
        return int(total)
