"""Causal LM: init / forward / loss / decode for every decoder-only arch.

Layer stacking: ``cfg.layer_pattern`` must tile ``num_layers`` exactly
(``periods = num_layers / len(pattern)``). Parameters for pattern position
``k`` are stacked across periods into leaves with leading dim ``periods``
and the forward pass is a single ``lax.scan`` over periods whose body runs
one period (len(pattern) blocks). This keeps the HLO size O(pattern) rather
than O(layers) — essential for 94-layer dry-run compiles — and is the
idiomatic pjit pattern (params sharded per PARAM_RULES with a leading
unsharded 'layers' axis).

The cross-entropy loss is computed in SEQUENCE CHUNKS so the (B, S, V)
logits tensor is never materialized (V up to 262k): an online logsumexp —
i.e. one more scan with the paper's blocked structure.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.models import blocks as blk
from repro.models.config import ModelConfig
from repro.models.layers.common import split_keys
from repro.models.layers.embedding import (embed_tokens, init_embedding,
                                           lm_logits)
from repro.models.layers.frontend import apply_frontend, init_frontend
from repro.models.layers.norms import apply_norm, init_norm

Pytree = Any


def _periods(cfg: ModelConfig) -> int:
    periods, rem = cfg.pattern_periods
    if rem:
        raise ValueError(
            f"layer_pattern {cfg.layer_pattern} must tile num_layers="
            f"{cfg.num_layers} exactly")
    return periods


def init_lm(key, cfg: ModelConfig) -> Pytree:
    periods = _periods(cfg)
    ks = split_keys(key, 4 + len(cfg.layer_pattern))
    params: dict = init_embedding(ks[0], cfg)
    params["final_norm"] = init_norm(cfg)
    if "shared_attn" in cfg.layer_pattern:
        params["shared"] = blk.init_shared_block(ks[1], cfg)
    if cfg.frontend_tokens:
        params["frontend"] = init_frontend(ks[2], cfg)
    stacked = {}
    for pos, kind in enumerate(cfg.layer_pattern):
        keys = jnp.stack(split_keys(ks[4 + pos], periods))
        stacked[f"p{pos}_{kind}"] = jax.vmap(
            lambda k: blk.init_block(k, cfg, kind)
        )(keys)
    params["blocks"] = stacked
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    """Stacked (periods-leading) decode caches mirroring params['blocks']."""
    periods = _periods(cfg)

    def stack(kind):
        one = blk.init_block_cache(cfg, kind, batch, max_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (periods,) + x.shape), one)

    return {f"p{pos}_{kind}": stack(kind)
            for pos, kind in enumerate(cfg.layer_pattern)}


def _body_fn(cfg: ModelConfig, x0, positions, cache_len, attn_impl, decode,
             shared, attn_schedule="auto", ssm_impl=None, unroll=False):
    """Returns the lax.scan body over periods."""

    def body(carry, per_layer):
        x, aux = carry
        params_sl = per_layer[0] if decode else per_layer
        cache_sl = per_layer[1] if decode else None
        new_cache_sl = {}
        for pos, kind in enumerate(cfg.layer_pattern):
            name = f"p{pos}_{kind}"
            cache = cache_sl[name] if decode else None
            x, a, new_c = blk.apply_block(
                params_sl[name], x, cfg, kind, shared=shared, x0=x0,
                positions=positions, cache=cache, cache_len=cache_len,
                attn_impl=attn_impl, attn_schedule=attn_schedule,
                ssm_impl=ssm_impl, unroll=unroll)
            aux = jax.tree.map(jnp.add, aux, a)
            if decode:
                new_cache_sl[name] = new_c
        return (x, aux), (new_cache_sl if decode else None)

    return body


def forward(
    params: Pytree,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[Pytree] = None,
    cache_len: Optional[jax.Array] = None,
    attn_impl: Optional[str] = None,
    attn_schedule: str = "auto",
    ssm_impl: Optional[str] = None,
    remat: bool = False,
    unroll: bool = False,
):
    """tokens (B, S) [+ frontend embeds (B, F, E)] -> (hidden, aux, cache).

    ``unroll=True`` fully unrolls the layer scan — used by the dry-run so
    ``cost_analysis`` sees every layer's flops/bytes/collectives (XLA
    counts a while-loop body ONCE, not x trip count).

    Returns final-norm hidden states — callers pick ``lm_logits`` (full) or
    the chunked loss below. With ``cache`` (decode), S is the new-token
    count and ``cache_len`` the count of valid cache entries — a scalar,
    or a PER-ROW (B,) vector (the serve engine's heterogeneous pool:
    each row gets its own positions and masking extent). ``ssm_impl``
    overrides the SSM layers' scan route (``None`` keeps ``apply_ssm``'s
    auto policy; the engine's degradation ladder forces ``"chunked"``).
    """
    x = embed_tokens(params, tokens, cfg)
    if embeds is not None:
        fe = apply_frontend(params["frontend"], embeds, cfg)
        x = jnp.concatenate([fe, x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        if cache_len is not None and getattr(cache_len, "ndim", 0) == 1:
            positions = cache_len[:, None] + jnp.arange(S)[None]  # (B, S)
        else:
            start = 0 if cache_len is None else cache_len
            positions = start + jnp.arange(S)
    x = shard(x, "batch", "seq", "embed")

    decode = cache is not None
    shared = params.get("shared")
    body = _body_fn(cfg, x, positions, cache_len, attn_impl, decode, shared,
                    attn_schedule=attn_schedule, ssm_impl=ssm_impl,
                    unroll=unroll)
    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    aux0 = blk.zero_aux()
    xs = (params["blocks"], cache) if decode else params["blocks"]
    (x, aux), new_cache = jax.lax.scan(body, (x, aux0), xs,
                                       unroll=True if unroll else 1)
    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux, (new_cache if decode else None)


# ---------------------------------------------------------------------------
# loss (chunked over sequence so B×S×V never materializes)
# ---------------------------------------------------------------------------


def chunked_ce_loss(
    params, hidden, labels, mask, cfg: ModelConfig, chunk: int = 512,
    unroll: bool = False,
):
    """Mean CE over valid tokens; logits produced chunk-by-chunk."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nch = (S + pad) // chunk
    hs = hidden.reshape(B, nch, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, nch, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, nch, chunk).swapaxes(0, 1)

    def step(carry, xs):
        total, count = carry
        h, lab, m = xs
        logits = lm_logits(params, h, cfg)            # (B, chunk, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lab[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * m
        return (total + jnp.sum(ce), count + jnp.sum(m)), None

    (total, count), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms), unroll=True if unroll else 1)
    return total / jnp.maximum(count, 1.0)


def lm_loss(
    params, batch: dict, cfg: ModelConfig, *, remat: bool = False,
    loss_chunk: int = 512, attn_impl: Optional[str] = None,
    attn_schedule: str = "auto", ssm_impl: Optional[str] = None,
    unroll: bool = False,
):
    """batch: tokens (B,S) int32, labels (B,S) int32, mask (B,S) f32,
    optional embeds (B,F,E). Returns (loss, metrics).

    ``attn_impl="flash"`` trains on the engine-backed flash kernel —
    forward AND backward run as scan-engine folds via its custom VJP —
    with ``attn_schedule`` picking the fold organization; dense and
    blockwise remain the jnp autodiff peers. ``ssm_impl="kernel"``
    does the same for SSM layers: the inter-chunk recurrence runs the
    engine's affine kernel in the forward AND (via its custom VJP,
    another engine scan) in the backward.
    """
    hidden, aux, _ = forward(
        params, batch["tokens"], cfg, embeds=batch.get("embeds"),
        remat=remat, attn_impl=attn_impl, attn_schedule=attn_schedule,
        ssm_impl=ssm_impl, unroll=unroll)
    embeds = batch.get("embeds")
    F = embeds.shape[1] if embeds is not None else 0
    hidden = hidden[:, F:]
    ce = chunked_ce_loss(
        params, hidden, batch["labels"], batch["mask"], cfg,
        chunk=loss_chunk, unroll=unroll)
    loss = (ce
            + cfg.router_aux_coef * aux["load_balance_loss"]
            + cfg.router_z_coef * aux["router_z_loss"])
    metrics = {"ce": ce, "loss": loss, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(
    params, tokens, cache, cache_len, cfg: ModelConfig,
    ssm_impl: Optional[str] = None, unroll: bool = False,
):
    """One decode step: tokens (B, 1) + cache -> (logits (B, V), cache).

    ``cache_len`` may be a scalar (homogeneous pool) or a (B,) vector of
    per-row lengths (the serve engine's heterogeneous pool).
    """
    hidden, _, new_cache = forward(
        params, tokens, cfg, cache=cache, cache_len=cache_len,
        ssm_impl=ssm_impl, unroll=unroll)
    logits = lm_logits(params, hidden[:, -1:], cfg)[:, 0]
    return logits, new_cache


def prefill(
    params, tokens, cfg: ModelConfig, max_len: int,
    embeds: Optional[jax.Array] = None, attn_impl: Optional[str] = None,
    attn_schedule: str = "auto", ssm_impl: Optional[str] = None,
    unroll: bool = False,
):
    """Run the prompt through the model, returning (logits_last, cache).

    The KV/state caches are filled by running forward in decode mode with
    cache_len=0 over the whole prompt.
    """
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    hidden, _, cache = forward(
        params, tokens, cfg, embeds=embeds, cache=cache,
        cache_len=jnp.zeros((), jnp.int32), attn_impl=attn_impl,
        attn_schedule=attn_schedule, ssm_impl=ssm_impl, unroll=unroll)
    logits = lm_logits(params, hidden[:, -1:], cfg)[:, 0]
    return logits, cache
