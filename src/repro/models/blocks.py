"""Per-kind residual blocks and their caches.

Block kinds (``ModelConfig.layer_pattern``):
  global       pre-norm GQA attention (full causal) + pre-norm MLP
  local        same with sliding-window attention (+ local rope theta)
  moe          pre-norm attention + pre-norm MoE FFN (scan-offset dispatch)
  mamba        pre-norm Mamba2 (SSD blocked scan)
  mlstm        pre-norm mLSTM block (chunkwise scan, own up/down proj)
  slstm        pre-norm sLSTM + pre-norm gated FFN (pf = 4/3)
  shared_attn  zamba2-style: concat(x, x0) -> per-layer in-proj -> SHARED
               attention+MLP block -> per-layer out-proj, residual to x

Every ``apply_block`` returns ``(x, aux, cache)`` where ``aux`` is a dict of
scalar f32 auxiliaries (moe losses; zeros elsewhere so the lax.scan over
layers has a uniform carry).

Attention blocks thread ``attn_impl``/``attn_schedule`` down to
``apply_attention`` unchanged; since flash carries its engine-fold
custom VJP, every value of ``attn_impl`` — dense, blockwise, banded,
flash — is valid under ``jax.grad``, so blocks make no training-vs-
inference distinction here.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import xlstm
from repro.models.layers.attention import (apply_attention, init_attention,
                                           init_kv_cache)
from repro.models.layers.common import compute_dtype, dense_init, split_keys
from repro.models.layers.mlp import apply_mlp, init_mlp
from repro.models.layers.moe import apply_moe, init_moe
from repro.models.layers.norms import apply_norm, init_norm
from repro.models.layers.ssm import apply_ssm, init_ssm, init_ssm_cache

ATTN_KINDS = ("global", "local", "moe", "shared_attn")


def zero_aux() -> dict:
    return {
        "load_balance_loss": jnp.zeros((), jnp.float32),
        "router_z_loss": jnp.zeros((), jnp.float32),
        "dropped_fraction": jnp.zeros((), jnp.float32),
    }


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str):
    ks = split_keys(key, 4)
    if kind in ("global", "local"):
        p = {"norm1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
             "norm2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}
        if cfg.post_block_norm:
            p["post_norm1"] = init_norm(cfg)
            p["post_norm2"] = init_norm(cfg)
        return p
    if kind == "moe":
        return {"norm1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
                "norm2": init_norm(cfg), "moe": init_moe(ks[1], cfg)}
    if kind == "mamba":
        return {"norm1": init_norm(cfg), "ssm": init_ssm(ks[0], cfg)}
    if kind == "mlstm":
        return {"norm1": init_norm(cfg), "mlstm": xlstm.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"norm1": init_norm(cfg), "slstm": xlstm.init_slstm(ks[0], cfg),
                "norm2": init_norm(cfg),
                "mlp": init_mlp(ks[1], cfg, d_ff=4 * cfg.d_model // 3)}
    if kind == "shared_attn":
        d = cfg.d_model
        dt = compute_dtype(cfg)
        return {
            "norm1": init_norm(cfg, 2 * d),
            "shared_proj_in": {"w": dense_init(ks[0], (2 * d, d), 2 * d, dt)},
            "shared_proj_out": {"w": dense_init(ks[1], (d, d), d, dt)},
        }
    raise ValueError(f"unknown block kind {kind!r}")


def init_shared_block(key, cfg: ModelConfig):
    """The zamba2 SHARED attention+MLP block (one copy for the model)."""
    ks = split_keys(key, 2)
    return {"norm1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "norm2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("global", "local", "moe", "shared_attn"):
        window_kind = "local" if kind == "local" else None
        return {"kv": init_kv_cache(cfg, batch, max_len, window_kind)}
    if kind == "mamba":
        return {"ssm": init_ssm_cache(cfg, batch)}
    if kind == "mlstm":
        return {"mlstm": xlstm.init_mlstm_cache(cfg, batch)}
    if kind == "slstm":
        return {"slstm": xlstm.init_slstm_cache(cfg, batch)}
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _attn_mlp_core(params, x, cfg, *, kind, positions, cache, cache_len,
                   attn_impl, ffn, attn_schedule="auto", unroll=False):
    """Shared wiring for attention blocks; ``ffn`` runs the second half."""
    h = apply_norm(params["norm1"], x, cfg)
    attn_out, new_kv = apply_attention(
        params["attn"], h, cfg, kind=("local" if kind == "local" else
                                      "global"),
        positions=positions, cache=None if cache is None else cache["kv"],
        cache_len=cache_len, impl=attn_impl, schedule=attn_schedule,
        unroll=unroll,
    )
    if cfg.post_block_norm:
        attn_out = apply_norm(params["post_norm1"], attn_out, cfg)
    x = x + attn_out
    h = apply_norm(params["norm2"], x, cfg)
    ffn_out, aux = ffn(h)
    if cfg.post_block_norm:
        ffn_out = apply_norm(params["post_norm2"], ffn_out, cfg)
    x = x + ffn_out
    new_cache = None if cache is None else {"kv": new_kv}
    return x, aux, new_cache


def apply_block(
    params,
    x,
    cfg: ModelConfig,
    kind: str,
    *,
    shared: Any = None,
    x0: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    cache_len: Optional[jax.Array] = None,
    attn_impl: Optional[str] = None,
    attn_schedule: str = "auto",
    ssm_impl: Optional[str] = None,
    unroll: bool = False,
):
    if kind in ("global", "local"):
        def ffn(h):
            return apply_mlp(params["mlp"], h, cfg), zero_aux()
        return _attn_mlp_core(
            params, x, cfg, kind=kind, positions=positions, cache=cache,
            cache_len=cache_len, attn_impl=attn_impl,
            attn_schedule=attn_schedule, ffn=ffn, unroll=unroll)

    if kind == "moe":
        def ffn(h):
            y, moe_aux = apply_moe(params["moe"], h, cfg)
            return y, dict(zero_aux(),
                           load_balance_loss=moe_aux.load_balance_loss,
                           router_z_loss=moe_aux.router_z_loss,
                           dropped_fraction=moe_aux.dropped_fraction)
        return _attn_mlp_core(
            params, x, cfg, kind=kind, positions=positions, cache=cache,
            cache_len=cache_len, attn_impl=attn_impl,
            attn_schedule=attn_schedule, ffn=ffn, unroll=unroll)

    if kind == "mamba":
        h = apply_norm(params["norm1"], x, cfg)
        y, new_ssm = apply_ssm(
            params["ssm"], h, cfg,
            cache=None if cache is None else cache["ssm"],
            impl=ssm_impl or "auto")
        new_cache = None if cache is None else {"ssm": new_ssm}
        return x + y, zero_aux(), new_cache

    if kind == "mlstm":
        h = apply_norm(params["norm1"], x, cfg)
        y, new_m = xlstm.apply_mlstm(
            params["mlstm"], h, cfg,
            cache=None if cache is None else cache["mlstm"])
        new_cache = None if cache is None else {"mlstm": new_m}
        return x + y, zero_aux(), new_cache

    if kind == "slstm":
        h = apply_norm(params["norm1"], x, cfg)
        y, new_s = xlstm.apply_slstm(
            params["slstm"], h, cfg,
            cache=None if cache is None else cache["slstm"])
        x = x + y
        h = apply_norm(params["norm2"], x, cfg)
        x = x + apply_mlp(params["mlp"], h, cfg)
        new_cache = None if cache is None else {"slstm": new_s}
        return x, zero_aux(), new_cache

    if kind == "shared_attn":
        assert shared is not None and x0 is not None
        cat = jnp.concatenate([x, x0], axis=-1)
        h = apply_norm(params["norm1"], cat, cfg)
        h = jnp.einsum("btc,cd->btd", h, params["shared_proj_in"]["w"])
        h, aux, new_cache = _attn_mlp_core(
            shared, h, cfg, kind="global", positions=positions, cache=cache,
            cache_len=cache_len, attn_impl=attn_impl,
            attn_schedule=attn_schedule, unroll=unroll,
            ffn=lambda hh: (apply_mlp(shared["mlp"], hh, cfg), zero_aux()))
        y = jnp.einsum("btd,de->bte", h, params["shared_proj_out"]["w"])
        return x + y, aux, new_cache

    raise ValueError(f"unknown block kind {kind!r}")
