"""Rotary position embeddings (NeoX half-rotation convention)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """Rotate (..., S, head_dim) by per-position angles.

    positions: (S,) or broadcastable to x's sequence axis (-2).
    """
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, half)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)
