"""Normalization layers (params and compute kept in float32)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig


def init_norm(cfg: ModelConfig, dim: "int | None" = None):
    d = dim or cfg.d_model
    p = {"w": jnp.zeros(d, jnp.float32) if cfg.norm == "gemma_rmsnorm"
         else jnp.ones(d, jnp.float32)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros(d, jnp.float32)
    return p


def apply_norm(params, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + cfg.norm_eps)
        out = out * params["w"] + params["b"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf / jnp.sqrt(ms + cfg.norm_eps)
        if cfg.norm == "gemma_rmsnorm":
            out = out * (1.0 + params["w"])  # gemma's (1+w) convention
        else:
            out = out * params["w"]
    return out.astype(x.dtype)


def rms_norm_headwise(w, x, eps=1e-6):
    """Per-head RMS norm for qk-norm (qwen3 / gemma3); w: (head_dim,)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return ((xf / jnp.sqrt(ms + eps)) * w).astype(x.dtype)
