"""Mixture-of-Experts FFN with prefix-sum (scan-offset) dispatch.

This is the paper's §1 database use case embedded in an LM: partitioning
tokens by expert is a radix-partitioning step whose write offsets come from
an exclusive prefix sum over the expert histogram — the relational
subsystem's stable partition (`repro.relational.partition`), with experts
playing the role of radix buckets:

    counts[e]  = histogram of routed tokens            (paper: histogram)
    offsets[e] = exclusive_scan(counts)                (paper: prefix sum)
    rank[t]    = running per-expert count before t     (segmented scan)
    dest[t]    = offsets[expert[t]] + rank[t]          (paper: new index)

Tokens are scattered into per-expert capacity buffers at ``dest``, the
expert FFNs run as a batched einsum sharded over the 'experts' (model) mesh
axis, and results scatter back weighted by router probabilities. Tokens
whose rank exceeds capacity are dropped (standard capacity-factor routing);
their residual path passes through unchanged.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.dist.sharding import current_mesh
from repro.models.config import ModelConfig
from repro.models.layers.common import activation, compute_dtype, dense_init
from repro.relational.partition import partition_plan


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def init_moe(key, cfg: ModelConfig):
    dt = compute_dtype(cfg)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), d, dt),
        "w_up": dense_init(ks[2], (e, d, f), d, dt),
        "w_down": dense_init(ks[3], (e, f, d), f, dt),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for tiling


def _data_shards() -> int:
    """Data-parallel shard count under the installed mesh (1 otherwise).

    REPRO_BASELINE=1 forces the paper-faithful global dispatch (the
    pre-optimization baseline measured in EXPERIMENTS.md §Perf).
    """
    import os
    if os.environ.get("REPRO_BASELINE"):
        return 1
    mesh = current_mesh()
    if mesh is None:
        return 1
    n = 1
    for ax in ("pod", "data"):
        n *= mesh.shape.get(ax, 1)
    return n


def apply_moe(params, x, cfg: ModelConfig):
    """x (B, S, D) -> (y, MoEAux). Routing in float32.

    SHARDED DISPATCH (beyond-paper optimization, EXPERIMENTS.md §Perf):
    the naive formulation scatters all T tokens into ONE global
    (E·C, D) buffer — under pjit that scatter's operands get all-gathered
    across the data axis (measured: 34 GB/layer for granite train_4k).
    Instead tokens are dispatched WITHIN each data shard: reshape the
    token axis to (shards, T/shards), run routing/offsets/scatter
    batched over the (data-sharded) shard dim — every step is local —
    and give each shard its own capacity C/shards (GShard-style
    per-shard capacity; same aggregate slots, drops decided per shard).
    The expert einsum then carries both parallel axes:
    (shards@data, E@model, C_loc, D).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    G = _data_shards()
    if B % G:
        G = 1                       # fallback: undivisible batch
    TL = T // G                     # tokens per data shard
    xt = x.reshape(G, TL, D)
    xt = shard(xt, "batch", None, "embed")

    # --- routing (per shard; all ops batched over the shard dim) ---
    logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), params["router"]
    )  # (G, TL, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (G, TL, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- prefix-sum partitioning per shard (paper's offsets use case) ---
    flat_ids = expert_ids.reshape(G, TL * K)
    plan = jax.vmap(lambda ids: partition_plan(ids, E))(flat_ids)
    C = _capacity(TL, cfg)
    keep = plan.ranks < C                       # (G, TL*K)
    slot = jnp.where(keep, flat_ids * C + plan.ranks, E * C)

    # --- scatter tokens into PER-SHARD expert buffers (local) ---
    x_rep = jnp.repeat(xt, K, axis=1)           # (G, TL*K, D)
    buf = jnp.zeros((G, E * C + 1, D), xt.dtype)
    buf = jax.vmap(lambda b, s_, v: b.at[s_].set(v))(buf, slot, x_rep)
    buf = buf[:, : E * C].reshape(G, E, C, D)
    buf = shard(buf, "batch", "experts", "capacity", "embed")

    # --- expert FFNs (parallel over data shards AND experts) ---
    act = activation(cfg.act)
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    if cfg.gated_mlp:
        g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
        h = act(g) * up
    else:
        h = act(up)
    h = shard(h, "batch", "experts", "capacity", "mlp")
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"])

    # --- gather back + combine with router weights (local) ---
    flat_out = out_buf.reshape(G, E * C, D)
    flat_out = jnp.concatenate(
        [flat_out, jnp.zeros((G, 1, D), flat_out.dtype)], axis=1
    )
    y_rep = jax.vmap(lambda f, s_: f[s_])(flat_out, slot)  # (G, TL*K, D)
    w = (gate_vals.reshape(G, TL * K) * keep.astype(jnp.float32))
    y = jnp.sum(
        (y_rep.astype(jnp.float32) * w[..., None]).reshape(G, TL, K, D),
        axis=2)
    y = y.astype(x.dtype).reshape(B, S, D)
    y = shard(y, "batch", "seq", "embed")

    # --- aux losses (Switch-style load balance + router z-loss) ---
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=(0, 1, 2)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, MoEAux(lb, z, dropped)
