"""Modality frontend stubs (per assignment: embeddings arrive precomputed).

``llava-next-mistral-7b``: vision patches, ``seamless-m4t-large-v2``: audio
frames. The upstream encoders (CLIP tower / w2v-BERT) are NOT part of the
assigned backbone; ``input_specs()`` feeds precomputed embeddings of shape
(B, frontend_tokens, frontend_dim). The stub is a learned linear adapter
into d_model — the real systems have exactly this projection layer
(``mm_projector`` / modality adapter), so the backbone interface is
faithful even though the tower is stubbed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.models.config import ModelConfig
from repro.models.layers.common import compute_dtype, dense_init

FRONTEND_DIM = 1024  # CLIP-large / w2v-BERT feature width


def init_frontend(key, cfg: ModelConfig):
    dt = compute_dtype(cfg)
    return {
        "proj": dense_init(key, (FRONTEND_DIM, cfg.d_model), FRONTEND_DIM, dt),
        "bias": jnp.zeros(cfg.d_model, jnp.float32),
    }


def apply_frontend(params, embeds, cfg: ModelConfig):
    """(B, F, FRONTEND_DIM) precomputed features -> (B, F, d_model).

    Output is cast to the model compute dtype regardless of the feature
    dtype (features arrive f32 from the stubbed tower; the backbone runs
    bf16 — mixing the two poisons downstream concat/cache dtypes).
    """
    dt = compute_dtype(cfg)
    y = jnp.einsum("bfe,ed->bfd", embeds.astype(dt), params["proj"])
    y = (y.astype(jnp.float32) + params["bias"]).astype(dt)
    return shard(y, "batch", "seq", "embed")
