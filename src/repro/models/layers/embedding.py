"""Token embedding and LM head (optionally tied)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.models.config import ModelConfig
from repro.models.layers.common import compute_dtype, embed_init


def init_embedding(key, cfg: ModelConfig):
    dt = compute_dtype(cfg)
    p = {"embed": {"table": embed_init(key, (cfg.vocab_size, cfg.d_model), dt)}}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["lm_head"] = {"w": embed_init(k2, (cfg.d_model, cfg.vocab_size), dt)}
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    """tokens (B, S) int32 -> (B, S, D). Gemma-style sqrt(d) scaling when
    embeddings are tied (keeps tied-logit scale sane)."""
    table = params["embed"]["table"]
    x = jnp.take(table, tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", "seq", "embed")


def lm_logits(params, h, cfg: ModelConfig):
    """(B, S, D) -> (B, S, V) float32 logits (+ gemma2 final softcap)."""
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(jnp.float32)  # (V, D)
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32), w)
    else:
        w = params["lm_head"]["w"].astype(jnp.float32)  # (D, V)
        logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32), w)
    if cfg.final_softcap is not None:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, "batch", "seq", "vocab")
