"""Shared helpers for functional layers: init, dtypes, activations."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, fan_in=None, dtype=jnp.bfloat16):
    """Truncated-normal with 1/sqrt(fan_in) scaling (lecun-ish)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * 0.02).astype(dtype)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def split_keys(key, n):
    return list(jax.random.split(key, n))
