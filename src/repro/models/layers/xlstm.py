"""xLSTM layers: mLSTM (chunkwise-parallel) and sLSTM (sequential).

mLSTM is a matrix-memory linear recurrence — the same blocked-scan shape as
Mamba2's SSD: within-chunk ``cumsum(log f)`` (prefix sum), across-chunk
affine carry of the matrix state ``S`` and normalizer ``n``. sLSTM has a
true hidden-to-gate recurrence (nonlinear), so it cannot be scanned in
parallel — it runs as a ``lax.scan`` over time, with the exp-gate max
stabilizer carried exactly as in the xLSTM paper.

Numerics note (recorded in DESIGN.md): the chunked mLSTM path runs the gate
algebra in float32 *without* the max stabilizer. With ``logsigmoid`` forget
gates (decays ≤ 1) and input gates bounded near init, every exponent is
≤ i_max ≈ O(10), which is safe in f32; the sequential sLSTM keeps the
stabilizer because its exponents accumulate.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import scan as scanlib
from repro.dist import shard
from repro.models.config import ModelConfig
from repro.models.layers.common import compute_dtype, dense_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _m_dims(cfg: ModelConfig):
    inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or cfg.num_heads
    return inner, heads, inner // heads


def init_mlstm(key, cfg: ModelConfig):
    dt = compute_dtype(cfg)
    d = cfg.d_model
    inner, H, _ = _m_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * inner), d, dt),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, inner),
                             cfg.conv_kernel, dt),
        "conv_b": jnp.zeros(inner, jnp.float32),
        "w_q": dense_init(ks[2], (inner, inner), inner, dt),
        "w_k": dense_init(ks[3], (inner, inner), inner, dt),
        "w_v": dense_init(ks[4], (inner, inner), inner, dt),
        "w_i": dense_init(ks[5], (inner, H), inner, jnp.float32),
        "w_f": dense_init(ks[6], (inner, H), inner, jnp.float32),
        "b_i": jnp.zeros(H, jnp.float32),
        # positive forget bias ⇒ sigmoid(f) ≈ 1 at init (long memory).
        "b_f": 3.0 * jnp.ones(H, jnp.float32),
        "norm_w": jnp.ones(inner, jnp.float32),
        "w_out": dense_init(ks[7], (inner, d), inner, dt),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    inner, H, dh = _m_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, inner),
                          compute_dtype(cfg)),
        "S": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
    }


def _conv_silu(xm, w, b, tail):
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xm.shape[0], K - 1, xm.shape[-1]), xm.dtype)
    xfull = jnp.concatenate([tail, xm], axis=1)
    T = xm.shape[1]
    y = sum(xfull[:, k: k + T].astype(jnp.float32) *
            w[k].astype(jnp.float32) for k in range(K))
    return jax.nn.silu(y + b).astype(xm.dtype), xfull[:, -(K - 1):]


def _headwise_norm(h, w, H, eps):
    """GroupNorm over each head's channels (f32)."""
    B, T, inner = h.shape
    hh = h.reshape(B, T, H, inner // H)
    mu = jnp.mean(hh, -1, keepdims=True)
    var = jnp.var(hh, -1, keepdims=True)
    out = ((hh - mu) / jnp.sqrt(var + eps)).reshape(B, T, inner)
    return out * w


def apply_mlstm(
    params, x, cfg: ModelConfig, *, cache: Optional[dict] = None,
):
    """mLSTM block over (B, T, D) -> (y, new_cache). Includes the block's
    own up/down projection (pf=2) and output skip gate (xLSTM wiring)."""
    B, T, D = x.shape
    inner, H, dh = _m_dims(cfg)
    up = jnp.einsum("btd,dm->btm", x, params["w_up"])
    xm, zg = up[..., :inner], up[..., inner:]
    xm = shard(xm, "batch", "seq", "ssm_inner")
    xc, new_tail = _conv_silu(
        xm, params["conv_w"], params["conv_b"],
        None if cache is None else cache["conv"],
    )
    q = jnp.einsum("btm,mn->btn", xc, params["w_q"]).reshape(B, T, H, dh)
    k = jnp.einsum("btm,mn->btn", xc, params["w_k"]).reshape(B, T, H, dh)
    v = jnp.einsum("btm,mn->btn", xm, params["w_v"]).reshape(B, T, H, dh)
    i_raw = jnp.einsum(
        "btm,mh->bth", xc.astype(jnp.float32), params["w_i"]
    ) + params["b_i"]
    f_raw = jnp.einsum(
        "btm,mh->bth", xc.astype(jnp.float32), params["w_f"]
    ) + params["b_f"]
    log_f = jax.nn.log_sigmoid(f_raw)                  # (B,T,H) ≤ 0
    log_i = -jax.nn.softplus(-i_raw) - 3.0             # bounded input gate

    S_prev = n_prev = None
    if cache is not None:
        S_prev, n_prev = cache["S"], cache["n"]
    if T == 1 and cache is not None:
        h, S_new, n_new = _mlstm_step(q, k, v, log_i, log_f, S_prev, n_prev)
    else:
        h, S_new, n_new = _mlstm_chunked(
            q, k, v, log_i, log_f, cfg.ssm_chunk, S_prev, n_prev
        )
    h = h.reshape(B, T, inner)
    h = _headwise_norm(h, params["norm_w"], H, cfg.norm_eps)
    h = h * jax.nn.silu(zg.astype(jnp.float32))
    h = shard(h.astype(x.dtype), "batch", "seq", "ssm_inner")
    y = jnp.einsum("btm,md->btd", h, params["w_out"])
    y = shard(y, "batch", "seq", "embed")
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail, "S": S_new, "n": n_new}
    return y, new_cache


def _mlstm_step(q, k, v, log_i, log_f, S_prev, n_prev):
    B, _, H, dh = q.shape
    if S_prev is None:
        S_prev = jnp.zeros((B, H, dh, dh), jnp.float32)
        n_prev = jnp.zeros((B, H, dh), jnp.float32)
    scale = dh ** -0.5
    f = jnp.exp(log_f[:, 0])[:, :, None, None]
    i = jnp.exp(log_i[:, 0])[:, :, None, None]
    kv = jnp.einsum("bhk,bhv->bhkv", k[:, 0].astype(jnp.float32),
                    v[:, 0].astype(jnp.float32))
    S = f * S_prev + i * kv
    n = f[..., 0] * n_prev + i[..., 0] * k[:, 0].astype(jnp.float32)
    qf = q[:, 0].astype(jnp.float32) * scale
    num = jnp.einsum("bhk,bhkv->bhv", qf, S)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return h[:, None], S, n


def _mlstm_chunked(q, k, v, log_i, log_f, chunk, S_prev, n_prev):
    """Chunkwise-parallel mLSTM: the paper's partitioned two-pass scan with
    the (decay, [S;n]) affine monoid across chunks."""
    B, T, H, dh = q.shape
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        zf = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(u, zf) for u in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)  # exp → 0 contribution
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q
    scale = dh ** -0.5

    qc = (q.reshape(B, nc, Q, H, dh) * scale).astype(jnp.float32)
    kc = k.reshape(B, nc, Q, H, dh).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, H, dh).astype(jnp.float32)
    ic = log_i.reshape(B, nc, Q, H)
    fc = log_f.reshape(B, nc, Q, H)

    # (1) prefix sum of log-forget within each chunk.
    F = scanlib.cumsum(fc, axis=2, algorithm="ref")    # (B,nc,Q,H)
    F_tot = F[:, :, -1]

    # Intra-chunk: W[i,j] = exp(F_i - F_j + i_j) (q_i·k_j), j ≤ i.
    rel = F[:, :, :, None, :] - F[:, :, None, :, :] + ic[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    G = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    qk = jnp.einsum("bcihd,bcjhd->bcijh", qc, kc)
    W = qk * G                                         # (B,nc,Q,Q,H)
    num_intra = jnp.einsum("bcijh,bcjhd->bcihd", W, vc)
    den_intra = jnp.sum(W, axis=3)                     # (B,nc,Q,H)

    # (2) chunk totals (accumulate-first, Fig 1b) + affine scan across
    # chunks for matrix state S and normalizer n.
    w_out = jnp.exp(F_tot[:, :, None] - F + ic)        # (B,nc,Q,H)
    S_tot = jnp.einsum("bcjh,bcjhk,bcjhv->bchkv", w_out, kc, vc)
    n_tot = jnp.einsum("bcjh,bcjhk->bchk", w_out, kc)
    a_chunk = jnp.exp(F_tot)                           # (B,nc,H)
    aS = jnp.broadcast_to(a_chunk[..., None, None], S_tot.shape)
    an = jnp.broadcast_to(a_chunk[..., None], n_tot.shape)
    _, S_inc = scanlib.scan((aS, S_tot), op="affine", axis=1,
                            algorithm="ref")
    _, n_inc = scanlib.scan((an, n_tot), op="affine", axis=1,
                            algorithm="ref")
    if S_prev is None:
        S_prev = jnp.zeros((B, H, dh, dh), jnp.float32)
        n_prev = jnp.zeros((B, H, dh), jnp.float32)
    cum = jnp.cumprod(a_chunk, axis=1)
    S_inc = S_inc + cum[..., None, None] * S_prev[:, None]
    n_inc = n_inc + cum[..., None] * n_prev[:, None]
    S_in = jnp.concatenate([S_prev[:, None], S_inc[:, :-1]], axis=1)
    n_in = jnp.concatenate([n_prev[:, None], n_inc[:, :-1]], axis=1)

    # (3) pass 2: fold the exclusive carry into per-position outputs.
    decay_in = jnp.exp(F)                              # (B,nc,Q,H)
    num_inter = jnp.einsum(
        "bcihk,bchkv->bcihv", qc * decay_in[..., None], S_in
    )
    den_inter = jnp.einsum(
        "bcihk,bchk->bcih", qc * decay_in[..., None], n_in
    )
    num = num_intra + num_inter
    den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
    h = (num / den[..., None]).reshape(B, Tp, H, dh)[:, :T]
    return h, S_inc[:, -1], n_inc[:, -1]


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig):
    """sLSTM at model width with block-diagonal recurrence over heads."""
    d = cfg.d_model
    H = cfg.ssm_heads or cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 9)
    p = {}
    for idx, g in enumerate("ifoz"):
        p[f"w_{g}"] = dense_init(ks[idx], (d, d), d, jnp.float32)
        p[f"r_{g}"] = dense_init(ks[4 + idx], (H, dh, dh), dh, jnp.float32)
        p[f"b_{g}"] = (3.0 * jnp.ones(d // H * H, jnp.float32)
                       .reshape(H, dh) if g == "f"
                       else jnp.zeros((H, dh), jnp.float32))
    p["norm_w"] = jnp.ones(d, jnp.float32)
    p["w_out"] = dense_init(ks[8], (d, d), d, compute_dtype(cfg))
    return p


def init_slstm_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    H = cfg.ssm_heads or cfg.num_heads
    dh = d // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z + 1.0, "m": z}


def apply_slstm(
    params, x, cfg: ModelConfig, *, cache: Optional[dict] = None,
):
    """Sequential sLSTM over (B, T, D) via lax.scan (stabilized exp gates)."""
    B, T, D = x.shape
    H = cfg.ssm_heads or cfg.num_heads
    dh = D // H
    xf = x.astype(jnp.float32)
    # Precompute input contributions for all gates: (B,T,H,dh) each.
    pre = {
        g: jnp.einsum("btd,de->bte", xf, params[f"w_{g}"])
        .reshape(B, T, H, dh) + params[f"b_{g}"]
        for g in "ifoz"
    }
    if cache is None:
        state0 = init_slstm_cache(cfg, B)
    else:
        state0 = cache

    r = {g: params[f"r_{g}"] for g in "ifoz"}

    def step(s, t_in):
        pi, pf, po, pz = t_in
        rec = {
            g: jnp.einsum("bhe,hde->bhd", s["h"], r[g]) for g in "ifoz"
        }
        i_t = pi + rec["i"]
        f_t = pf + rec["f"]
        o_t = jax.nn.sigmoid(po + rec["o"])
        z_t = jnp.tanh(pz + rec["z"])
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + s["m"], i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(log_f + s["m"] - m_new)
        c = f_p * s["c"] + i_p * z_t
        n = jnp.maximum(f_p * s["n"] + i_p, 1e-6)
        h = o_t * c / n
        return {"h": h, "c": c, "n": n, "m": m_new}, h

    seq = tuple(jnp.moveaxis(pre[g], 1, 0) for g in "ifoz")
    import os
    chunk = cfg.ssm_chunk or 128
    if (T > 4 * chunk and T % chunk == 0
            and not os.environ.get("REPRO_BASELINE")):
        # Cache-sized partitioning applied to BACKWARD memory (paper §2.2
        # generalized): an outer scan over T/chunk chunks whose body is
        # rematerialized — the VJP saves only chunk-boundary states and
        # recomputes the T-step residuals one chunk at a time, cutting
        # the saved-residual footprint by T/chunk.
        seq_c = tuple(
            x.reshape(T // chunk, chunk, *x.shape[1:]) for x in seq)

        @jax.checkpoint
        def chunk_body(state, chunk_in):
            return jax.lax.scan(step, state, chunk_in)

        state, hs = jax.lax.scan(chunk_body, state0, seq_c)
        hs = hs.reshape(T, *hs.shape[2:])
    else:
        state, hs = jax.lax.scan(step, state0, seq)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, D)
    # Headwise group norm + projection.
    h = _headwise_norm(h, params["norm_w"], H, cfg.norm_eps)
    y = jnp.einsum("btd,de->bte", h.astype(x.dtype), params["w_out"])
    y = shard(y, "batch", "seq", "embed")
    return y, (state if cache is not None else None)
