"""Attention: GQA + RoPE + sliding windows + softcap + qk-norm + KV cache.

Implementation selection mirrors the scan policy (paper §5): small sequences
use the dense form; long sequences use the *blockwise online-softmax scan*
(`repro.kernels.flash_attention.ref.blockwise_ref`, autodiff-able) and the
engine-backed flash kernel (`impl="flash"`) — all three compute the same
softmax-pair monoid fold, and all three are TRAINING-ROUTE peers:
``flash_attention`` carries a ``jax.custom_vjp`` whose backward runs as
two more engine folds (dq over KV blocks, dk/dv over the transposed
q-major layout), so ``impl="flash"`` survives ``jax.grad`` without
detouring through the jnp references. The flash route threads
``schedule`` ("carry"|"decoupled"|"auto") down to the scan engine's fold
schedules, so the serve prefill path can land on the split-KV decoupled
form for the long-KV class via ``policy.choose_attention_schedule``.

All implementations share the zeroed-probability masking convention:
a fully-masked row emits exactly 0 with zero gradients (see ref.py) —
the invariant the gradient-parity wall and the causal-aware KV bound
both rest on.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.kernels.flash_attention import (banded_ref, blockwise_ref,
                                            flash_attention, masked_softmax)
from repro.models.config import ModelConfig
from repro.models.layers.common import compute_dtype, dense_init
from repro.models.layers.norms import rms_norm_headwise
from repro.models.layers.rope import apply_rope


def init_attention(key, cfg: ModelConfig):
    dt = compute_dtype(cfg)
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), d, dt),
        "wk": dense_init(ks[1], (d, hk * hd), d, dt),
        "wv": dense_init(ks[2], (d, hk * hd), d, dt),
        "wo": dense_init(ks[3], (h * hd, d), h * hd, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones(hd, jnp.float32)
        p["k_norm"] = jnp.ones(hd, jnp.float32)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window_kind:
                  "str | None" = None):
    """Empty cache for one attention layer. Local (sliding-window) layers
    allocate only `window` slots — the 500k-context memory saver."""
    dt = compute_dtype(cfg)
    slots = max_len
    if window_kind == "local" and cfg.sliding_window:
        slots = min(max_len, cfg.sliding_window)
    shape = (batch, cfg.num_kv_heads, slots, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _project(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dm->bsm", x, params["wq"]).reshape(B, S, h, hd)
    k = jnp.einsum("bsd,dm->bsm", x, params["wk"]).reshape(B, S, hk, hd)
    v = jnp.einsum("bsd,dm->bsm", x, params["wv"]).reshape(B, S, hk, hd)
    if cfg.qk_norm:
        q = rms_norm_headwise(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm_headwise(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "local" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _dense_attn(q, k, v, *, scale, causal, window, softcap, q_pos, k_pos,
                kv_len):
    """q (B,H,Sq,hd), k/v (B,Hkv,Sk,hd); GQA via head reshape.

    ``q_pos`` (Sq,) or (B,Sq), ``k_pos`` (Sk,) or (B,Sk), ``kv_len``
    scalar or (B,): the serve engine passes PER-ROW positions/extents so
    co-resident sequences of different lengths are masked independently
    — one row's output never depends on its pool neighbours (the
    isolation the chaos wall's bitwise invariant rests on). Scalar /
    unbatched arguments keep the original broadcast shapes bit-for-bit.
    """
    B, H, Sq, hd = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    qf = q.reshape(B, Hkv, g, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.asarray(q_pos)
    k_pos = jnp.asarray(k_pos)
    kv_len = jnp.asarray(kv_len)
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]        # (B|1, Sq)
    kp = k_pos if k_pos.ndim == 2 else k_pos[None]        # (B|1, Sk)
    kv = kv_len.reshape(-1, 1, 1)                         # (B|1, 1, 1)
    mask = (kp[:, None, :] < kv) & (kp[:, None, :] >= 0)
    if causal:
        mask = mask & (kp[:, None, :] <= qp[:, :, None])
    if window is not None:
        mask = mask & (kp[:, None, :] > qp[:, :, None] - window)
    p = masked_softmax(s, mask[:, None, None])
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def apply_attention(
    params,
    x,
    cfg: ModelConfig,
    *,
    kind: str = "global",
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    cache_len: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    schedule: str = "auto",
    causal: bool = True,
    unroll: bool = False,
):
    """Self-attention over (B, S, D).

    Training/prefill: ``cache=None``; decode: pass the layer cache and the
    number of valid entries ``cache_len`` — new K/V are written at
    ``cache_len`` (modulo window for local layers) and attention spans the
    cache. Returns (out, new_cache). ``schedule`` picks the flash-engine
    fold organization when the flash route runs (carry|decoupled|auto).
    """
    B, S, _ = x.shape
    window = cfg.sliding_window if kind == "local" else None
    scale = cfg.query_scale if cfg.query_scale is not None \
        else cfg.head_dim ** -0.5
    if positions is None:
        positions = jnp.arange(S)
    positions = jnp.asarray(positions)
    # Per-row positions (B, S) — the serve engine's heterogeneous-length
    # decode. RoPE rotates per row: lift to (B, 1, S) so the angle table
    # broadcasts over heads; 1-D positions keep the original shapes.
    rope_pos = positions[:, None, :] if positions.ndim == 2 else positions

    q, k, v = _project(params, x, cfg)
    theta = _theta(cfg, kind)
    q = apply_rope(q.swapaxes(1, 2), rope_pos, theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), rope_pos, theta).swapaxes(1, 2)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    qh = q.swapaxes(1, 2)  # (B, H, S, hd)
    kh = k.swapaxes(1, 2)
    vh = v.swapaxes(1, 2)

    paged_pools = None
    if cache is not None and "k_pages" in cache:
        # Paged KV (serve/paging.py): the layer cache is a page POOL
        # plus a per-row page table. Gather the per-row contiguous view
        # and run the standard append path on it — the gathered live
        # positions hold exactly the contiguous layout's values and the
        # dead ones are finite null-page data that the zeroed-probability
        # mask turns into exact-zero contributions, so decode stays
        # BITWISE identical to the contiguous cache. The written token
        # is scattered back to its page afterwards.
        from repro.serve import paging as _paging
        if S != 1:
            # Genuinely impossible from the engine (prefill and chunked
            # prefill both stage through a contiguous cache; the paged
            # step only ever decodes one token). Geometry/layer-support
            # errors are raised with layer context at construction time
            # by ``paging.validate_paged_support``.
            raise ValueError(
                f"paged KV cache supports single-token decode only "
                f"(prefill stages contiguously); got S={S}")
        pt_full = cache["pt"]
        ps = cache["k_pages"].shape[2]
        pt = pt_full
        if window is not None:
            # Windowed layer on pages: the ring rides the FIRST
            # ``ring // ps`` entries of the shared page-table row
            # (ring slot s lives in logical page s // ps), so clamping
            # the gather to those entries reproduces the contiguous
            # layout's ring buffer exactly — same slot count, same
            # ``write_at = cache_len % slots`` arithmetic below, bitwise
            # identical outputs. Dead ring slots read null-page data
            # instead of zeros; the mask makes both exact-zero.
            ring = min(int(window), pt_full.shape[1] * ps)
            pt = pt_full[:, : ring // ps]
        paged_pools = (cache["k_pages"], cache["v_pages"], pt_full, pt,
                       _paging)
        cache = {
            "k": _paging.gather_pages(cache["k_pages"], pt),
            "v": _paging.gather_pages(cache["v_pages"], pt),
        }

    new_cache = cache
    import os as _os
    _baseline = bool(_os.environ.get("REPRO_BASELINE"))
    if cache is not None and window is not None and S >= cache["k"].shape[2]:
        # Prefill covering the whole ring (S ≥ window slots): attention is
        # computed from the in-segment keys directly (window-masked), and
        # the ring is (re)filled with the last `slots` keys. Only valid
        # when prefilling from an empty cache (the serve engine does).
        slots = cache["k"].shape[2]
        if window < S and S % min(512, S) == 0 and not _baseline:
            # banded: touch only the in-window KV band (§Perf) — at 32k
            # prefill this is 21x less attention traffic than masking.
            # K/V repeat to full heads FIRST: kv_heads (e.g. 8) cannot
            # shard 16-way, but repeated heads can — keeps the banded
            # einsums fully local under TP (§Perf iteration 3).
            g_rep = cfg.num_heads // cfg.num_kv_heads
            kr = shard(jnp.repeat(kh, g_rep, axis=1).swapaxes(1, 2),
                       "batch", "seq", "heads", None).swapaxes(1, 2)
            vr = shard(jnp.repeat(vh, g_rep, axis=1).swapaxes(1, 2),
                       "batch", "seq", "heads", None).swapaxes(1, 2)
            out = banded_ref(
                qh, kr, vr, scale=scale, window=window,
                softcap=cfg.attn_softcap, block_q=min(512, S),
                block_k=min(512, S), unroll=unroll)
        else:
            out = _dense_attn(
                qh, kh, vh, scale=scale, causal=causal, window=window,
                softcap=cfg.attn_softcap, q_pos=positions, k_pos=positions,
                kv_len=positions[-1] + 1,
            )
        roll = (cache_len + S) % slots  # ring write head after this segment
        ktail = kh[:, :, -slots:]
        vtail = vh[:, :, -slots:]
        idx = (jnp.arange(slots) - roll) % slots
        new_cache = {"k": ktail[:, :, idx], "v": vtail[:, :, idx]}
    elif (cache is not None and window is None and S == cache["k"].shape[2]
          and S > 4096 and not _baseline):
        # Full-cache prefill of a GLOBAL layer at long S: the O(S²) f32
        # logits of the dense path dwarf HBM — use the online-softmax
        # fold and write the cache directly (§Perf). ``impl="flash"``
        # lands on the scan-engine kernel (schedule=auto routes long-KV
        # shapes to the split-KV decoupled fold); otherwise the
        # autodiff-able jnp blockwise scan.
        H, Hkv = cfg.num_heads, cfg.num_kv_heads
        if impl == "flash":
            out = flash_attention(
                qh, kh, vh, scale=scale, causal=causal,
                softcap=cfg.attn_softcap, schedule=schedule)
        else:
            out = blockwise_ref(
                qh.reshape(B * H, S, cfg.head_dim),
                kh.reshape(B * Hkv, S, cfg.head_dim),
                vh.reshape(B * Hkv, S, cfg.head_dim),
                group=H // Hkv, scale=scale, causal=causal,
                softcap=cfg.attn_softcap, block_k=1024, unroll=unroll,
            ).reshape(B, H, S, cfg.head_dim)
        new_cache = {"k": kh, "v": vh}
    elif cache is not None:
        slots = cache["k"].shape[2]
        per_row = getattr(cache_len, "ndim", 0) == 1  # (B,) vector lengths
        # Ring-buffer write for windowed layers, append otherwise. With
        # per-row lengths each row writes at ITS own position (vmapped
        # scatter) and masks against ITS own extent — pool neighbours of
        # different lengths cannot leak into each other.
        write_at = (cache_len % slots) if window is not None else cache_len
        if per_row:
            row_update = jax.vmap(
                lambda c, u, p: jax.lax.dynamic_update_slice(
                    c, u, (0, p, 0)))
            kc = row_update(cache["k"], kh, write_at)
            vc = row_update(cache["v"], vh, write_at)
        else:
            kc = jax.lax.dynamic_update_slice(
                cache["k"], kh, (0, 0, write_at, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], vh, (0, 0, write_at, 0))
        new_cache = {"k": kc, "v": vc}
        k_slot = jnp.arange(slots)
        if window is not None:
            # Recover absolute positions of ring slots.
            total = cache_len + S
            if per_row:
                wrap = (k_slot[None] - (total[:, None] % slots)) % slots
                k_pos = total[:, None] - slots + wrap       # (B, slots)
            else:
                wrap = (k_slot - (total % slots)) % slots
                k_pos = total - slots + wrap
        else:
            k_pos = k_slot

        def _cached_dense(_):
            return _dense_attn(
                qh, kc, vc, scale=scale, causal=causal, window=window,
                softcap=cfg.attn_softcap, q_pos=positions,
                k_pos=k_pos, kv_len=cache_len + S,
            )

        if impl == "flash" and window is None and S > 1:
            # Prefill of a GLOBAL layer into a PADDED cache (cache longer
            # than the live prefix): attend the S live keys directly on
            # the engine-backed fold — masking dead slots is implicit
            # (they are never read). Valid only from an EMPTY cache
            # (absolute q/k positions equal segment offsets), and
            # ``cache_len`` is traced, so the guard is a runtime
            # ``lax.cond``: a mid-stream call (chunked prefill,
            # multi-token verification) keeps the dense path's cached
            # keys instead of silently dropping them.
            def _flash_prefill(_):
                return flash_attention(
                    qh, kh, vh, scale=scale, causal=causal,
                    softcap=cfg.attn_softcap, schedule=schedule)

            out = jax.lax.cond(
                cache_len == 0, _flash_prefill, _cached_dense, None)
        else:
            out = _cached_dense(None)
        if paged_pools is not None:
            # The gathered view was a scratch copy; persist only the
            # newly-written token (kh/vh at S == 1) back into its page.
            # Inactive rows (cache_len 0, unassigned table entries) land
            # in the null page by construction.
            # ``pt`` is the (possibly ring-clamped) gather view;
            # ``write_at`` is already ring-modded for windowed layers,
            # so the scatter goes through the same clamped table.
            pool_k, pool_v, pt_full, pt, _paging = paged_pools
            w = write_at if per_row else jnp.broadcast_to(
                jnp.asarray(write_at)[None], (B,))
            new_cache = {
                "k_pages": _paging.scatter_token(
                    pool_k, kh[:, :, 0, :], pt, w),
                "v_pages": _paging.scatter_token(
                    pool_v, vh[:, :, 0, :], pt, w),
                "pt": pt_full,
            }
    else:
        if impl is None:
            import os
            if os.environ.get("REPRO_BASELINE"):
                impl = "dense" if S <= 4096 else "blockwise"
            elif window is not None and window < S:
                # Local layer: touch only the in-window KV band (banded
                # flash — beyond-paper opt, EXPERIMENTS.md §Perf).
                impl = "banded"
            else:
                impl = "dense" if S <= 2048 else "blockwise"
        if impl == "banded":
            g_rep = cfg.num_heads // cfg.num_kv_heads
            kr = shard(jnp.repeat(kh, g_rep, axis=1).swapaxes(1, 2),
                       "batch", "seq", "heads", None).swapaxes(1, 2)
            vr = shard(jnp.repeat(vh, g_rep, axis=1).swapaxes(1, 2),
                       "batch", "seq", "heads", None).swapaxes(1, 2)
            out = banded_ref(
                qh, kr, vr, scale=scale, window=window,
                softcap=cfg.attn_softcap, block_q=min(512, S),
                block_k=min(512, S), unroll=unroll,
            )
        elif impl == "dense":
            out = _dense_attn(
                qh, kh, vh, scale=scale, causal=causal, window=window,
                softcap=cfg.attn_softcap, q_pos=positions,
                k_pos=positions, kv_len=positions[-1] + 1,
            )
        elif impl == "blockwise":
            H, Hkv = cfg.num_heads, cfg.num_kv_heads
            out = blockwise_ref(
                qh.reshape(B * H, S, cfg.head_dim),
                kh.reshape(B * Hkv, S, cfg.head_dim),
                vh.reshape(B * Hkv, S, cfg.head_dim),
                group=H // Hkv, scale=scale, causal=causal, window=window,
                softcap=cfg.attn_softcap, block_k=1024, unroll=unroll,
            ).reshape(B, H, S, cfg.head_dim)
        elif impl == "flash":
            out = flash_attention(
                qh, kh, vh, scale=scale, causal=causal, window=window,
                softcap=cfg.attn_softcap, schedule=schedule,
            )
        else:
            raise ValueError(f"unknown attention impl {impl!r}")

    out = out.swapaxes(1, 2).reshape(B, S, cfg.num_heads * cfg.head_dim)
    y = jnp.einsum("bsm,md->bsd", out, params["wo"])
    return shard(y, "batch", "seq", "embed"), new_cache


# --- cross attention (seamless decoder) -----------------------------------


def init_cross_attention(key, cfg: ModelConfig):
    return init_attention(key, cfg)


def apply_cross_attention(params, x, memory, cfg: ModelConfig):
    """x (B,S,D) attends into encoder memory (B,Sm,D); not causal, no rope."""
    B, S, _ = x.shape
    Sm = memory.shape[1]
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dm->bsm", x, params["wq"]).reshape(B, S, h, hd)
    k = jnp.einsum("bsd,dm->bsm", memory, params["wk"]).reshape(B, Sm, hk, hd)
    v = jnp.einsum("bsd,dm->bsm", memory, params["wv"]).reshape(B, Sm, hk, hd)
    if cfg.qk_norm:
        q = rms_norm_headwise(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm_headwise(params["k_norm"], k, cfg.norm_eps)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5
    out = _dense_attn(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        scale=scale, causal=False, window=None, softcap=cfg.attn_softcap,
        q_pos=jnp.arange(S), k_pos=jnp.arange(Sm), kv_len=Sm,
    )
    out = out.swapaxes(1, 2).reshape(B, S, h * hd)
    y = jnp.einsum("bsm,md->bsd", out, params["wo"])
    return shard(y, "batch", "seq", "embed")
