"""Feed-forward blocks: gated (SwiGLU/GeGLU) or plain two-layer."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.models.config import ModelConfig
from repro.models.layers.common import activation, compute_dtype, dense_init


def init_mlp(key, cfg: ModelConfig, d_ff: "int | None" = None):
    dt = compute_dtype(cfg)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], (d, f), d, dt),
         "w_down": dense_init(ks[2], (f, d), f, dt)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[0], (d, f), d, dt)
    return p


def apply_mlp(params, x, cfg: ModelConfig):
    act = activation(cfg.act)
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    h = shard(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    return shard(out, "batch", "seq", "embed")
