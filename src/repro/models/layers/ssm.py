"""Mamba2 (SSD) layer — the paper's blocked scan generalized to matrix state.

The chunked SSD algorithm *is* the paper's §2.2 cache-friendly partitioned
scan, instantiated twice:

  1. WITHIN a chunk: ``cumsum(log decay)`` — a plain prefix sum
     (``repro.core.scan``), used to build the intra-chunk decay kernel.
  2. ACROSS chunks: the matrix-valued state ``S_c`` carries through the
     affine monoid ``h_c = a_c · h_{c-1} + S_c`` — an exclusive scan with
     the MATRIX_AFFINE monoid. This is the two-pass structure of Fig. 1:
     pass 1 reduces each chunk to a total (``S_c``), the carry exchange is
     the scan over chunk totals, pass 2 combines the exclusive prefix back
     into each chunk's outputs.

The inter-chunk scan runs through ``repro.core.scan`` by default when
training; on the TPU serve path (``cache`` present) ``impl="auto"``
routes the diagonal-decay carry through the Pallas ``ssm_scan`` kernel
with ``schedule="auto"``, so the policy's four-way grid rule (carry /
decoupled / fused / tree — ``core/scan/policy.choose_schedule``) governs
the decode recurrence end to end. ``impl="kernel"`` is also TRAINABLE:
the kernel carries a ``jax.custom_vjp`` whose backward is one more
engine affine scan (flipped time, rolled gates), so an SSM train step
can hit the kernel family in both directions.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import scan as scanlib
from repro.dist import shard
from repro.models.config import ModelConfig
from repro.models.layers.common import compute_dtype, dense_init


def _dims(cfg: ModelConfig):
    inner = cfg.ssm_heads * cfg.ssm_head_dim
    conv_dim = inner + 2 * cfg.ssm_state
    return inner, conv_dim


def init_ssm(key, cfg: ModelConfig):
    """Mamba2 parameters. in_proj emits [z | x | B | C | dt]."""
    dt = compute_dtype(cfg)
    d = cfg.d_model
    inner, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * inner + 2 * cfg.ssm_state + cfg.ssm_heads
    # dt bias initialized so softplus(dt_bias) spans ~[1e-3, 1e-1].
    u = jax.random.uniform(ks[2], (cfg.ssm_heads,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inv_softplus
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), d, dt),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_dim),
                             cfg.conv_kernel, dt),
        "conv_b": jnp.zeros(conv_dim, jnp.float32),
        "a_log": jnp.log(jnp.arange(1, cfg.ssm_heads + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "d_skip": jnp.ones(cfg.ssm_heads, jnp.float32),
        "norm_w": jnp.ones(inner, jnp.float32),
        "out_proj": dense_init(ks[3], (inner, d), inner, dt),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int):
    """Decode-time cache: depthwise-conv tail + SSM state (f32)."""
    dtc = compute_dtype(cfg)
    inner, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtc),
        "h": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    inner, _ = _dims(cfg)
    N, H = cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :inner]
    xBC = zxbcdt[..., inner: 2 * inner + 2 * N]
    dt_raw = zxbcdt[..., 2 * inner + 2 * N:]
    return z, xBC, dt_raw


def _causal_conv(xBC, params, cfg: ModelConfig, tail: Optional[jax.Array]):
    """Depthwise causal conv over (B, T, conv_dim); ``tail`` is the cached
    last (K-1) inputs for decode continuity. Returns (y, new_tail)."""
    K = cfg.conv_kernel
    w = params["conv_w"].astype(jnp.float32)  # (K, C)
    if tail is None:
        tail = jnp.zeros(
            (xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype
        )
    xfull = jnp.concatenate([tail, xBC], axis=1)  # (B, K-1+T, C)
    T = xBC.shape[1]
    y = sum(
        xfull[:, k: k + T].astype(jnp.float32) * w[k]
        for k in range(K)
    )
    y = y + params["conv_b"]
    new_tail = xfull[:, -(K - 1):]
    return jax.nn.silu(y).astype(xBC.dtype), new_tail


def _gated_norm(y, z, norm_w, eps):
    """Mamba2's RMSNorm(y * silu(z)) output gate (computed in f32)."""
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), -1, keepdims=True)
    return (g / jnp.sqrt(ms + eps)) * norm_w


def apply_ssm(
    params,
    x,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    impl: str = "auto",
):
    """Mamba2 over (B, T, D) -> (y, new_cache).

    Training / prefill: ``cache=None`` (or a prior state to continue from),
    chunked SSD path. Decode: ``T == 1`` recurrent update.

    ``impl="auto"`` routes the SERVE path (cache present — the engine's
    prefill-into-slot and multi-token decode) through the Pallas
    ``ssm_scan`` kernel with ``schedule="auto"``, so long low-batch
    sequences land on the policy's parallel-sequence schedule end to end.
    The route is gated to TPU (off-TPU the kernel would run the Pallas
    interpreter — same gate as ``relational``'s auto rules); the training
    path (``cache=None``) defaults to the autodiff-able chunked reference
    scan everywhere. ``impl="kernel"`` forces the kernel route on any
    backend (interpret mode off-TPU) — including under ``jax.grad``,
    where the kernel's custom VJP runs the backward as another engine
    scan rather than differentiating through the reference.
    """
    if impl == "auto":
        serve = cache is not None and jax.default_backend() == "tpu"
        impl = "kernel" if serve else "chunked"
    B, T, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner, _ = _dims(cfg)

    zxbcdt = jnp.einsum("btd,dm->btm", x, params["in_proj"])
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)
    xBC, new_tail = _causal_conv(
        xBC, params, cfg, None if cache is None else cache["conv"]
    )
    xs = xBC[..., :inner].reshape(B, T, H, P)
    Bm = xBC[..., inner: inner + N]          # (B, T, N) one state group
    Cm = xBC[..., inner + N:]                # (B, T, N)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )                                         # (B, T, H)
    a = -jnp.exp(params["a_log"])             # (H,) negative decay rates
    da = dt * a                               # (B, T, H) log decay ≤ 0

    h_prev = None if cache is None else cache["h"]
    if T == 1 and cache is not None:
        y, h_new = _ssm_step(xs, Bm, Cm, dt, da, h_prev)
    else:
        y, h_new = _ssd_chunked(
            xs, Bm, Cm, dt, da, cfg.ssm_chunk, h_prev, impl
        )

    y = y + (
        params["d_skip"][:, None] * xs.astype(jnp.float32)
    )                                         # (B, T, H, P) skip connection
    y = y.reshape(B, T, inner)
    y = _gated_norm(y, z, params["norm_w"], cfg.norm_eps)
    y = shard(y.astype(x.dtype), "batch", "seq", "ssm_inner")
    out = jnp.einsum("btm,md->btd", y, params["out_proj"])
    out = shard(out, "batch", "seq", "embed")
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail, "h": h_new}
    return out, new_cache


def _ssm_step(xs, Bm, Cm, dt, da, h_prev):
    """One-token recurrent update. h: (B, H, P, N)."""
    B, _, H, P = xs.shape
    N = Bm.shape[-1]
    if h_prev is None:
        h_prev = jnp.zeros((B, H, P, N), jnp.float32)
    decay = jnp.exp(da[:, 0])[:, :, None, None]             # (B,H,1,1)
    dBx = jnp.einsum(
        "bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(jnp.float32),
        xs[:, 0].astype(jnp.float32),
    )
    h = decay * h_prev + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
    return y[:, None], h                                     # (B,1,H,P)


def _ssd_chunked(xs, Bm, Cm, dt, da, chunk, h_prev, impl):
    """Chunked SSD: intra-chunk quadratic + inter-chunk affine scan."""
    B, T, H, P = xs.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q

    xs = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    dac = da.reshape(B, nc, Q, H)

    # (1) WITHIN-chunk prefix sum of log-decays — the paper's primitive.
    A = scanlib.cumsum(dac, axis=2, algorithm="ref")  # (B,nc,Q,H) inclusive
    A_tot = A[:, :, -1]                               # (B,nc,H)

    # Intra-chunk (causal masked) contribution.
    # L[i,j] = exp(A_i - A_j) for j <= i.
    rel = A[:, :, :, None, :] - A[:, :, None, :, :]   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (B,nc,Q,Q)
    W = CB[..., None] * L * dtc[:, :, None, :, :]     # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xs)

    # (2) ACROSS-chunk carry — chunk totals + affine scan (paper Fig. 1b:
    # accumulate-first). S_c = Σ_j exp(A_tot - A_j) dt_j B_j ⊗ x_j.
    decay_out = jnp.exp(A_tot[:, :, None] - A)        # (B,nc,Q,H)
    S = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchpn", decay_out * dtc, Bc, xs
    )                                                 # (B,nc,H,P,N)
    a_chunk = jnp.exp(A_tot)                          # (B,nc,H)
    if impl == "kernel":
        from repro.kernels.ssm_scan import ops as kops
        flatS = S.reshape(B, nc, H * P * N)
        flata = jnp.broadcast_to(
            a_chunk[..., None, None], S.shape
        ).reshape(B, nc, H * P * N)
        states = kops.ssm_scan(flata, flatS).reshape(S.shape)
    else:
        ab = jnp.broadcast_to(a_chunk[..., None, None], S.shape)
        _, states = scanlib.scan(
            (ab, S), op="affine", axis=1, algorithm="ref"
        )                                             # inclusive over chunks
    # Fold a non-zero entering state through every chunk's inclusive state
    # (affine identity: states_c += (Π_{c'<=c} a_c') · h_prev).
    if h_prev is None:
        h_prev = jnp.zeros((B, H, P, N), jnp.float32)
    cumdecay = jnp.cumprod(a_chunk, axis=1)           # (B,nc,H)
    states = states + cumdecay[..., None, None] * h_prev[:, None]
    # Exclusive prefix: the state ENTERING each chunk.
    h_in = jnp.concatenate(
        [h_prev[:, None], states[:, :-1]], axis=1
    )                                                 # (B,nc,H,P,N)

    # (3) Pass 2: combine exclusive carry into chunk outputs.
    decay_in = jnp.exp(A)                             # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bcin,bcihpn->bcihp",
        Cc, decay_in[..., None, None] * h_in[:, :, None],
    )
    y = (y_intra + y_inter).reshape(B, Tp, H, P)[:, :T]
    h_last = states[:, -1]
    return y, h_last
