"""Encoder–decoder backbone (seamless-m4t): audio-frame encoder + text
decoder with cross attention.

The speech frontend (w2v-BERT conformer) is stubbed per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, F, 1024) that a
learned adapter projects into d_model. Encoder layers are non-causal
attention blocks; decoder layers are causal self-attention + cross
attention + MLP, stacked with the same periods-scan as ``lm.py``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist import shard
from repro.models import blocks as blk
from repro.models.config import ModelConfig
from repro.models.layers.attention import (apply_attention,
                                           apply_cross_attention,
                                           init_attention, init_cross_attention,
                                           init_kv_cache)
from repro.models.layers.common import split_keys
from repro.models.layers.embedding import (embed_tokens, init_embedding,
                                           lm_logits)
from repro.models.layers.frontend import apply_frontend, init_frontend
from repro.models.layers.mlp import apply_mlp, init_mlp
from repro.models.layers.norms import apply_norm, init_norm

Pytree = Any


def _init_dec_block(key, cfg: ModelConfig):
    ks = split_keys(key, 3)
    return {
        "norm1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
        "norm2": init_norm(cfg), "cross_attn": init_cross_attention(ks[1], cfg),
        "norm3": init_norm(cfg), "mlp": init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg: ModelConfig) -> Pytree:
    assert cfg.encoder_layers > 0
    ks = split_keys(key, 5)
    params: dict = init_embedding(ks[0], cfg)
    params["frontend"] = init_frontend(ks[1], cfg)
    enc_keys = jnp.stack(split_keys(ks[2], cfg.encoder_layers))
    params["encoder_blocks"] = jax.vmap(
        lambda k: blk.init_block(k, cfg, "global"))(enc_keys)
    dec_keys = jnp.stack(split_keys(ks[3], cfg.num_layers))
    params["decoder_blocks"] = jax.vmap(
        lambda k: _init_dec_block(k, cfg))(dec_keys)
    params["enc_norm"] = init_norm(cfg)
    params["final_norm"] = init_norm(cfg)
    return params


def init_dec_cache(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    one = {"kv": init_kv_cache(cfg, batch, max_len)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one)


def encode(params, frame_embeds, cfg: ModelConfig, remat: bool = False,
           unroll: bool = False, attn_impl: "str | None" = None,
           attn_schedule: str = "auto"):
    """(B, F, 1024) precomputed frames -> encoder memory (B, F, D)."""
    x = apply_frontend(params["frontend"], frame_embeds, cfg)
    positions = jnp.arange(x.shape[1])

    def body(carry, p_sl):
        h = apply_norm(p_sl["norm1"], carry, cfg)
        a, _ = apply_attention(p_sl["attn"], h, cfg, positions=positions,
                               causal=False, impl=attn_impl,
                               schedule=attn_schedule)
        carry = carry + a
        h = apply_norm(p_sl["norm2"], carry, cfg)
        carry = carry + apply_mlp(p_sl["mlp"], h, cfg)
        return carry, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder_blocks"],
                        unroll=True if unroll else 1)
    return apply_norm(params["enc_norm"], x, cfg)


def decode_forward(
    params, tokens, memory, cfg: ModelConfig, *,
    cache: Optional[Pytree] = None, cache_len: Optional[jax.Array] = None,
    remat: bool = False, unroll: bool = False,
    attn_impl: Optional[str] = None, attn_schedule: str = "auto",
):
    """Decoder stack -> final-norm hidden (B, S, D); cache for serving."""
    x = embed_tokens(params, tokens, cfg)
    S = x.shape[1]
    start = 0 if cache_len is None else cache_len
    positions = start + jnp.arange(S)
    decode = cache is not None

    def body(carry, per_layer):
        x = carry
        p_sl = per_layer[0] if decode else per_layer
        c_sl = per_layer[1] if decode else None
        h = apply_norm(p_sl["norm1"], x, cfg)
        a, new_kv = apply_attention(
            p_sl["attn"], h, cfg, positions=positions,
            cache=None if c_sl is None else c_sl["kv"], cache_len=cache_len,
            impl=attn_impl, schedule=attn_schedule)
        x = x + a
        h = apply_norm(p_sl["norm2"], x, cfg)
        x = x + apply_cross_attention(p_sl["cross_attn"], h, memory, cfg)
        h = apply_norm(p_sl["norm3"], x, cfg)
        x = x + apply_mlp(p_sl["mlp"], h, cfg)
        return x, ({"kv": new_kv} if decode else None)

    if remat:
        body = jax.checkpoint(body)
    xs = (params["decoder_blocks"], cache) if decode \
        else params["decoder_blocks"]
    x, new_cache = jax.lax.scan(body, x, xs, unroll=True if unroll else 1)
    x = apply_norm(params["final_norm"], x, cfg)
    return x, (new_cache if decode else None)


def encdec_loss(params, batch: dict, cfg: ModelConfig, *,
                remat: bool = False, loss_chunk: int = 512,
                attn_impl: "str | None" = None,
                attn_schedule: str = "auto",
                ssm_impl: "str | None" = None, unroll: bool = False):
    """batch: embeds (B,F,1024), tokens (B,S), labels, mask.

    ``ssm_impl`` is accepted for signature parity with ``lm_loss`` (the
    train step passes one knob set for every family) but unused: the
    encoder/decoder stacks contain no SSM layers.
    """
    del ssm_impl
    from repro.models.lm import chunked_ce_loss
    memory = encode(params, batch["embeds"], cfg, remat=remat,
                    unroll=unroll, attn_impl=attn_impl,
                    attn_schedule=attn_schedule)
    hidden, _ = decode_forward(params, batch["tokens"], memory, cfg,
                               remat=remat, unroll=unroll,
                               attn_impl=attn_impl,
                               attn_schedule=attn_schedule)
    ce = chunked_ce_loss(params, hidden, batch["labels"], batch["mask"],
                         cfg, chunk=loss_chunk, unroll=unroll)
    return ce, {"ce": ce, "loss": ce}


def serve_step(params, tokens, memory, cache, cache_len, cfg: ModelConfig,
               unroll: bool = False):
    """One decoder token against a precomputed encoder memory."""
    hidden, new_cache = decode_forward(
        params, tokens, memory, cfg, cache=cache, cache_len=cache_len,
        unroll=unroll)
    logits = lm_logits(params, hidden[:, -1:], cfg)[:, 0]
    return logits, new_cache
