"""gemma3-12b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family; unverified] 48L d_model=3840 16H
(GQA kv=8) d_ff=15360 vocab=262144. Gemma-3 wiring: pattern of five
sliding-window (1024) layers followed by one global layer; separate RoPE
bases (10k local / 1M global); per-head qk-norm; sandwich (post-block)
norms; GeGLU MLP.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    qk_norm=True,
    query_scale=256.0 ** -0.5,
    norm="gemma_rmsnorm",
    act="gelu",
    post_block_norm=True,
    max_seq_len=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    sliding_window=32,
    query_scale=16.0 ** -0.5,
    max_seq_len=256,
)
