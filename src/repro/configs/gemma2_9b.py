"""gemma2-9b — dense, alternating local/global attention, logit softcaps.

[arXiv:2408.00118; hf] 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000. Gemma-2 wiring: local(4096-window)/global alternation,
attention-logit softcap 50, final-logit softcap 30, sandwich norms, GeGLU.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    layer_pattern=("local", "global"),
    sliding_window=4096,
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=256.0 ** -0.5,
    norm="gemma_rmsnorm",
    act="gelu",
    post_block_norm=True,
    max_seq_len=8_192,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    sliding_window=32,
    query_scale=16.0 ** -0.5,
    max_seq_len=256,
)
