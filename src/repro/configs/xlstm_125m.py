"""xlstm-125m — xLSTM with alternating mLSTM/sLSTM blocks.

[arXiv:2405.04517; unverified] 12L d_model=768 4H (kv=4) d_ff=0
vocab=50304. The xLSTM[7:1]-style stack: mostly mLSTM (matrix-memory,
fully parallelizable via the matrix-affine scan) with sLSTM blocks
(scalar-memory, gated FFN pf=4/3) interleaved. d_ff=0 per the assignment:
mLSTM blocks carry their own up/down projection (expand factor 2) and
sLSTM blocks use the 4/3-gated FFN — there is no standalone transformer
MLP. Pure recurrent: runs long_500k.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    # 12 layers = 2 periods of [5 mLSTM, 1 sLSTM] — the 7:1-ish mix at 12L.
    layer_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    ssm_heads=4,
    ssm_head_dim=384,  # inner = expand(2) * d_model / heads
    ssm_expand=2,
    ssm_state=0,
    gated_mlp=True,
    act="gelu",
    tie_embeddings=True,
    max_seq_len=1_048_576,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    ssm_heads=4,
    ssm_head_dim=32,
    vocab_size=512,
    max_seq_len=256,
)
