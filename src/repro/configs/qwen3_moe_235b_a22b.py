"""qwen3-moe-235b-a22b — MoE, 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B family; hf] 94L d_model=4096 64H (GQA kv=4)
d_ff(expert)=1536 vocab=151936, MoE 128e top-8, per-head qk-norm.
Largest assigned arch; exercises expert parallelism (128/16 = 8 experts
per model-axis chip) and the scan-offset dispatch at scale.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    moe_d_ff=1536,
    vocab_size=151_936,
    num_experts=128,
    top_k=8,
    layer_pattern=("moe",),
    rope_theta=1_000_000.0,
    qk_norm=True,
    act="silu",
    tie_embeddings=False,
    max_seq_len=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    moe_d_ff=64,
    vocab_size=512,
    num_experts=8,
    top_k=2,
    max_seq_len=256,
)
