"""zamba2-7b — hybrid Mamba2 backbone + shared attention block.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64. Zamba2 wiring: a deep Mamba2 trunk with ONE
shared attention+MLP block invoked periodically; each invocation
concatenates the current hidden state with the original embedding
(``concat(x, x0)``), runs per-layer in/out projections around the shared
weights. 81 = 27 periods of (mamba, mamba, shared_attn). Sub-quadratic:
runs long_500k (the shared-attn KV grows, but decode is O(n)/step; the
Mamba trunk is O(1)/step).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    layer_pattern=("mamba", "mamba", "shared_attn"),
    rope_theta=10_000.0,
    act="gelu",
    ssm_heads=112,     # inner = expand(2)·3584 = 7168; head_dim 64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_state=64,
    conv_kernel=4,
    ssm_chunk=128,
    max_seq_len=1_048_576,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    ssm_heads=4,
    ssm_head_dim=32,
    ssm_state=16,
    max_seq_len=256,
)
