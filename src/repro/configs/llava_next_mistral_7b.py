"""llava-next-mistral-7b — VLM: Mistral-7B backbone + anyres patch stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000. The assignment specifies the
transformer BACKBONE only; the vision tower is a STUB — ``input_specs()``
provides precomputed patch embeddings (anyres tiling: up to 5 tiles of
24×24 = 2880 patch positions at 1024-d, projected by a learned 2-layer
adapter into d_model and prepended to the text sequence).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    layer_pattern=("global",),
    rope_theta=1_000_000.0,
    act="silu",
    frontend_tokens=2880,  # anyres: 5 tiles × 576 patches
    tie_embeddings=False,
    max_seq_len=32_768,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    frontend_tokens=16,
    max_seq_len=256,
)
