"""Assigned input-shape set (common to all 10 LM-family architectures).

  train_4k      seq 4,096  × global_batch 256   → lowers train_step
  prefill_32k   seq 32,768 × global_batch 32    → lowers prefill (serve)
  decode_32k    seq 32,768 × global_batch 128   → lowers serve_step
                 (ONE new token against a KV cache of seq_len)
  long_500k     seq 524,288 × global_batch 1    → serve_step, sub-quadratic
                 archs only (SSM/hybrid/SWA) — skips per DESIGN.md §6.

VLM (llava): ``frontend_tokens`` of the sequence arrive as precomputed
patch embeddings, the rest as text tokens. Audio (seamless): the sequence
splits half/half into encoder frames and decoder tokens for train/prefill;
decode uses a fixed 4,096-frame encoder memory (≈3 min of audio) with the
full-seq decoder cache.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic or windowed attention).
LONG_CONTEXT_ARCHS = frozenset(
    {"gemma3-12b", "gemma2-9b", "xlstm-125m", "zamba2-7b"}
)


def cells(arch: str) -> list[str]:
    """Shape names applicable to ``arch`` (the dry-run row)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
