"""stablelm-12b — dense decoder with GQA.

[hf:stabilityai/stablelm-2-1_6b family; hf] 40L d_model=5120 32H
(GQA kv=8) d_ff=13824 vocab=100352. StableLM-2 wiring: LayerNorm
(parametric), SwiGLU, partial-rotary RoPE (we apply full rotary — noted in
DESIGN.md deviations), untied embeddings.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100_352,
    layer_pattern=("global",),
    rope_theta=10_000.0,
    norm="layernorm",
    act="silu",
    tie_embeddings=False,
    max_seq_len=4_096,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    max_seq_len=256,
)
