"""phi3-medium-14b — dense decoder, RoPE + SwiGLU + GQA.

[arXiv:2404.14219; unverified] 40L d_model=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352. Standard pre-norm Llama-style wiring.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100_352,
    layer_pattern=("global",),
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=False,
    max_seq_len=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    max_seq_len=256,
)
