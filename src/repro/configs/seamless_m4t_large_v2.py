"""seamless-m4t-large-v2 — audio encoder-decoder (multimodal backbone).

[arXiv:2308.11596; hf] 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206, enc-dec. The speech frontend (w2v-BERT conformer stack) is
a STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (B, F, 1024). 24 encoder layers (non-causal) + 24 decoder
layers (causal self-attn + cross-attn + MLP). No decode skip: the decoder
serves `decode_32k` against a fixed encoder memory; `long_500k` is
skipped (enc-dec, full attention).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    layer_pattern=("global",),
    rope_theta=10_000.0,
    norm="layernorm",
    act="relu",
    frontend_tokens=4096,  # ~3 min of 20ms frames after subsampling
    tie_embeddings=True,
    max_seq_len=8_192,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    frontend_tokens=16,
    max_seq_len=256,
)
