"""Architecture registry — one module per assigned architecture.

``get_config(name)`` returns the exact published full-scale ModelConfig;
``get_smoke_config(name)`` a reduced same-family config for CPU tests.
Select on the command line via ``--arch <id>`` (launch/train.py,
launch/serve.py, launch/dryrun.py).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.shapes import (LONG_CONTEXT_ARCHS, SHAPES, ShapeSpec,
                                  cells)
from repro.models.config import ModelConfig

ARCHS: tuple[str, ...] = (
    "gemma3-12b",
    "gemma2-9b",
    "phi3-medium-14b",
    "stablelm-12b",
    "granite-moe-1b-a400m",
    "qwen3-moe-235b-a22b",
    "xlstm-125m",
    "zamba2-7b",
    "llava-next-mistral-7b",
    "seamless-m4t-large-v2",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_") for name in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[name])
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced config of the same family/wiring for CPU smoke tests."""
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE


def all_configs() -> dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCHS}


def scale_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Generic reducer used by the per-arch SMOKE definitions."""
    return dataclasses.replace(cfg, **overrides)


__all__ = [
    "ARCHS", "LONG_CONTEXT_ARCHS", "SHAPES", "ShapeSpec", "all_configs",
    "cells", "get_config", "get_smoke_config", "scale_down",
]
