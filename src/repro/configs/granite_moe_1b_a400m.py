"""granite-moe-1b-a400m — MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d_model=1024 16H
(GQA kv=8) d_ff(expert)=512 vocab=49155, MoE 32e top-8. Every FFN is MoE;
prefix-sum dispatch offsets are the paper's core use case (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49_155,
    num_experts=32,
    top_k=8,
    layer_pattern=("moe",),
    rope_theta=10_000.0,
    act="silu",
    max_seq_len=4_096,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    moe_d_ff=64,
    vocab_size=512,
    num_experts=4,
    top_k=2,
    max_seq_len=256,
)
