"""Tree scan — the paper's §3.3 (Blelloch two-sweep, work-efficient).

Up-sweep builds subtree totals in place; down-sweep distributes exclusive
prefixes back down. O(n) combines over 2·log2(n) strided passes. The paper's
verdict (Observation 5): work-efficiency loses to memory-access efficiency —
the strided gathers/scatters at every level trash locality. The same holds
on TPU: the strided ``at[]`` updates force relayouts, so this stays a
validation oracle and a benchmark baseline, exactly as in the paper.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scan import assoc

Pytree = Any


def _strided_get(tree: Pytree, start: int, stride: int) -> Pytree:
    return jax.tree.map(lambda x: x[start::stride], tree)


def _strided_set(tree: Pytree, start: int, stride: int, val: Pytree) -> Pytree:
    return jax.tree.map(lambda x, v: x.at[start::stride].set(v), tree, val)


def scan_tree(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    exclusive: bool = False,
) -> Pytree:
    """Blelloch up/down-sweep scan along ``axis``."""
    monoid = assoc.get(op)
    leaves = jax.tree.leaves(elems)
    axis = axis % leaves[0].ndim
    n = leaves[0].shape[axis]
    if n == 0:
        # The pow2 pad would round 0 up to 1, but identity_like of an
        # empty tree has nothing to pad WITH — return the empty scan.
        return elems

    # Work on axis 0; pad to a power of two with identities.
    x = jax.tree.map(lambda a: jnp.moveaxis(a, axis, 0), elems)
    pow2 = 1
    while pow2 < n:
        pow2 *= 2
    if pow2 != n:
        ident_full = monoid.identity_like(x)
        x = jax.tree.map(
            lambda a, i: jnp.concatenate([a, i[: pow2 - n]], axis=0),
            x,
            ident_full,
        )

    levels = pow2.bit_length() - 1  # log2(pow2)

    # Up-sweep (reduction): parents accumulate left+right subtree totals.
    for d in range(levels):
        stride = 2 ** (d + 1)
        left = _strided_get(x, 2**d - 1, stride)
        right = _strided_get(x, stride - 1, stride)
        x = _strided_set(x, stride - 1, stride, monoid.combine(left, right))

    # Down-sweep: root gets identity; each node passes its value to the left
    # child and (value ∘ old-left-total) to the right child.
    last = jax.tree.map(lambda a: a[-1:], x)
    x = jax.tree.map(
        lambda a, i: a.at[-1:].set(i), x, monoid.identity_like(last)
    )
    for d in reversed(range(levels)):
        stride = 2 ** (d + 1)
        t = _strided_get(x, 2**d - 1, stride)  # old left subtree totals
        parent = _strided_get(x, stride - 1, stride)
        x = _strided_set(x, 2**d - 1, stride, parent)
        # parent's exclusive prefix is EARLIER than the left subtree => left arg.
        x = _strided_set(x, stride - 1, stride, monoid.combine(parent, t))

    # x now holds the exclusive scan (padded).
    x = jax.tree.map(lambda a: a[:n], x)
    if not exclusive:
        orig = jax.tree.map(lambda a: jnp.moveaxis(a, axis, 0), elems)
        x = monoid.combine(x, orig)
    return jax.tree.map(lambda a: jnp.moveaxis(a, 0, axis), x)
