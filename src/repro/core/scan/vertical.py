"""Vertical scan — the paper's §3.2, adapted to TPU layout semantics.

CPU version: divide the data into ``w`` chunks of length ``k = n/w``; lane
``i`` of the SIMD register walks chunk ``i`` sequentially, using
gather/scatter at stride ``k``. Work-efficient (O(n) adds), two passes:

  * V1: pass 1 writes per-chunk local prefix sums (scatter), pass 2 adds the
    exclusive scan of chunk totals.
  * V2: pass 1 only accumulates chunk totals (no writes), pass 2 computes
    the global scan directly with the chunk offset folded in.

TPU adaptation: the strided gather becomes a **reshape** ``(w, k)`` — chunk
``i`` is row ``i`` — and "lane ``i`` walks its chunk" is a ``lax.scan`` down
the columns, vectorized across rows. On CPUs the paper finds gather/scatter
make this uncompetitive (Observation 5); on TPU the reshape is a layout
change served from VMEM, so the verdict partially inverts — our Pallas SSM
kernel (``repro.kernels.ssm_scan``) is exactly this vertical pattern with
lanes = model channels.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scan import assoc
from repro.core.scan import reference

Pytree = Any


def _set_axis(shape, axis, v):
    s = list(shape)
    s[axis] = v
    return tuple(s)


def scan_vertical(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    lanes: int = 16,
    variant: int = 2,
    exclusive: bool = False,
) -> Pytree:
    """Two-pass vertical scan with ``lanes`` parallel chunks.

    Args:
      variant: 1 → local scans in pass 1 (paper's SIMD-V1);
               2 → totals-only in pass 1, fused scan in pass 2 (SIMD-V2).
    """
    if variant not in (1, 2):
        raise ValueError("variant must be 1 or 2")
    monoid = assoc.get(op)
    leaves = jax.tree.leaves(elems)
    axis = axis % leaves[0].ndim
    n = leaves[0].shape[axis]
    if n == 0:
        # Nothing to scan: the pad path would blow the axis up to
        # ``lanes`` identities and variant 2 would fold an empty chunk.
        return elems

    if n % lanes != 0:
        # Pad the tail with identity elements; slice the result back.
        padded_n = -(-n // lanes) * lanes
        ident_full = monoid.identity_like(elems)
        padded = jax.tree.map(
            lambda x, i: jnp.concatenate(
                [x, jnp.broadcast_to(
                    jax.lax.slice_in_dim(i, 0, 1, axis=axis),
                    _set_axis(x.shape, axis, padded_n - n))],
                axis=axis,
            ),
            elems,
            ident_full,
        )
        out = scan_vertical(padded, monoid, axis, lanes, variant, exclusive)
        return jax.tree.map(lambda x: jax.lax.slice_in_dim(x, 0, n, axis=axis), out)

    k = n // lanes

    def to_grid(x):
        x = jnp.moveaxis(x, axis, 0)
        return x.reshape((lanes, k) + x.shape[1:])

    def from_grid(x):
        x = x.reshape((n,) + x.shape[2:])
        return jnp.moveaxis(x, 0, axis)

    grid = jax.tree.map(to_grid, elems)  # leaves: (lanes, k, ...)

    if variant == 1:
        # Pass 1: per-chunk local scans (the paper's scatter-writes).
        local = reference.scan_ref(grid, monoid, axis=1)
        totals = jax.tree.map(lambda x: x[:, -1], local)
        # Exclusive scan of the tiny `sums` array across chunks.
        offsets = reference.scan_ref(totals, monoid, axis=0, exclusive=True)
        # Pass 2: combine offsets into the stored local scans.
        out = monoid.combine(jax.tree.map(lambda o: o[:, None], offsets), local)
        # combine() may have broadcast the (lanes, 1, ...) offset; fix shapes.
        out = jax.tree.map(lambda o, l: jnp.broadcast_to(o, l.shape), out, local)
    else:
        # Pass 1: reduce only — no writes (the paper's bandwidth saving).
        totals = monoid.fold(grid, axis=1)
        offsets = reference.scan_ref(totals, monoid, axis=0, exclusive=True)

        # Pass 2: re-scan each chunk with its offset as the initial carry.
        def step(carry, x):
            new = monoid.combine(carry, x)
            return new, new

        def scan_row(off, row):
            _, ys = jax.lax.scan(step, off, row)
            return ys

        out = jax.vmap(scan_row)(offsets, grid)

    result = jax.tree.map(from_grid, out)
    if exclusive:
        result = _exclusive_from_inclusive(result, monoid, axis)
    return result


def _exclusive_from_inclusive(inc: Pytree, monoid: assoc.Monoid, axis: int):
    ident_full = monoid.identity_like(inc)
    return jax.tree.map(
        lambda x, i: jnp.concatenate(
            [jax.lax.slice_in_dim(i, 0, 1, axis=axis),
             jax.lax.slice_in_dim(x, 0, x.shape[axis] - 1, axis=axis)],
            axis=axis,
        ),
        inc,
        ident_full,
    )
