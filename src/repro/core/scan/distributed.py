"""Distributed scans — the paper's §2 multithreaded algorithms across chips.

The paper's threads become mesh devices; its two-pass organizations become
``shard_map`` programs; its `sums` array exchange becomes a collective. The
mapping is exact:

  paper thread t_m            →  device with mesh index m along `axis_name`
  pass 1 local scan/reduce    →  per-shard scan/fold (no communication)
  `sums` buffer + barrier     →  all-gather / permute of per-shard totals
  pass 2 increment/scan       →  per-shard combine with the exclusive offset

Three carry-exchange schedules are provided (the paper's §2.2.1 discusses
barrier cost; on a TPU mesh the analogous choice is which collective):

  * ``all_gather``  — one all-gather of totals; every device folds its own
    exclusive prefix. One collective, O(m) payload per device. Best for
    small carries (scalars — plain cumsum).
  * ``hillis_permute`` — log2(m) ``ppermute`` rounds (Hillis–Steele over
    the device axis). O(log m) latency, O(1) payload per round. Best for
    LARGE carries (SSM matrix states under sequence parallelism), where
    all-gathering m full matrices would dominate.
  * ``ring`` — m-1 chained ``ppermute``s: the adjacent-only-synchronization
    StreamScan variant the paper cites ([35]). Exposes maximal overlap of
    the carry chain with local compute to the XLA scheduler.

``variant`` selects the paper's Fig 1a (1: scan-then-increment) vs Fig 1b
(2: accumulate-then-scan). Variant 2 performs no writes in pass 1 — the
bandwidth observation that makes SIMD2-P the paper's most robust algorithm
(Observation 3) — and is the default.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scan import assoc
from repro.core.scan import blocked
from repro.core.scan import horizontal
from repro.core.scan import reference

Pytree = Any


def _exclusive_offset_all_gather(total, monoid, axis_name, m):
    """All-gather per-device totals; fold my exclusive prefix locally."""
    totals = jax.lax.all_gather(total, axis_name, axis=0)  # (m, ...)
    excl = reference.scan_ref(totals, monoid, axis=0, exclusive=True)
    my = jax.lax.axis_index(axis_name)
    return jax.tree.map(
        lambda e: jax.lax.dynamic_index_in_dim(e, my, 0, keepdims=False), excl
    )


def _exclusive_offset_hillis(total, monoid, axis_name, m):
    """Log-step doubling scan over the device axis via ppermute."""
    my = jax.lax.axis_index(axis_name)
    val = total  # running inclusive fold of a trailing window
    k = 1
    while k < m:
        perm = [(i, i + k) for i in range(m - k)]
        recv = jax.tree.map(
            lambda v: jax.lax.ppermute(v, axis_name, perm), val
        )
        val = jax.tree.map(
            lambda r, v, c: jnp.where(my >= k, c, v),
            recv,
            val,
            monoid.combine(recv, val),
        )
        k *= 2
    # val is the inclusive scan of totals; shift by one device for exclusive.
    perm = [(i, i + 1) for i in range(m - 1)]
    recv = jax.tree.map(lambda v: jax.lax.ppermute(v, axis_name, perm), val)
    ident = monoid.identity_like(total)
    return jax.tree.map(
        lambda r, i: jnp.where(my == 0, i, r), recv, ident
    )


def _exclusive_offset_ring(total, monoid, axis_name, m):
    """m-1 chained permutes: adjacent-only synchronization (StreamScan)."""
    my = jax.lax.axis_index(axis_name)
    ident = monoid.identity_like(total)
    offset = ident
    perm = [(i, i + 1) for i in range(m - 1)]
    for _ in range(m - 1):
        send = monoid.combine(offset, total)
        recv = jax.tree.map(
            lambda s: jax.lax.ppermute(s, axis_name, perm), send
        )
        offset = jax.tree.map(
            lambda r, i: jnp.where(my == 0, i, r), recv, ident
        )
    return offset


_EXCHANGES = {
    "all_gather": _exclusive_offset_all_gather,
    "hillis_permute": _exclusive_offset_hillis,
    "ring": _exclusive_offset_ring,
}


def _local_scan(xs, monoid, algorithm, block_size):
    if algorithm == "blocked":
        return blocked.scan_blocked(xs, monoid, axis=0, block_size=block_size)
    if algorithm == "horizontal":
        return horizontal.scan_horizontal(xs, monoid, axis=0)
    if algorithm == "ref":
        return reference.scan_ref(xs, monoid, axis=0)
    raise ValueError(f"unknown local algorithm {algorithm!r}")


def scan_sharded(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    *,
    mesh: Mesh,
    axis_name: str,
    spec: P,
    scan_axis: int = 0,
    variant: int = 2,
    carry_exchange: str = "all_gather",
    local_algorithm: str = "blocked",
    block_size: int = 4096,
    exclusive: bool = False,
) -> Pytree:
    """Global scan of an array sharded along ``axis_name``.

    Args:
      elems: pytree of arrays, all sharded with ``spec``; the scanned axis
        must be the one mapped to ``axis_name``.
      spec: the PartitionSpec of ``elems`` (in == out).
      variant: 1 = Fig 1a (scan first), 2 = Fig 1b (accumulate first).
      carry_exchange: collective schedule for the `sums` array (see module
        docstring).
      local_algorithm: per-shard algorithm; "blocked" = the paper's
        cache-friendly partitioning *within* each device.
    """
    if variant not in (1, 2):
        raise ValueError("variant must be 1 or 2")
    monoid = assoc.get(op)
    m = mesh.shape[axis_name]
    exchange = _EXCHANGES[carry_exchange]

    def local_fn(xs):
        xs0 = jax.tree.map(lambda x: jnp.moveaxis(x, scan_axis, 0), xs)
        if variant == 1:
            # Pass 1: full local prefix sums (writes), totals as byproduct.
            local = _local_scan(xs0, monoid, local_algorithm, block_size)
            total = jax.tree.map(lambda x: x[-1], local)
            offset = exchange(total, monoid, axis_name, m)
            # Pass 2: increment by the exclusive device-prefix.
            out = monoid.combine(
                jax.tree.map(lambda o: o[None], offset), local
            )
            out = jax.tree.map(
                lambda o, l: jnp.broadcast_to(o, l.shape), out, local
            )
        else:
            # Pass 1: fold only — no writes (the bandwidth saver).
            total = monoid.fold(xs0, axis=0)
            offset = exchange(total, monoid, axis_name, m)
            # Pass 2: local scan fused with the offset.
            local = _local_scan(xs0, monoid, local_algorithm, block_size)
            out = monoid.combine(
                jax.tree.map(lambda o: o[None], offset), local
            )
            out = jax.tree.map(
                lambda o, l: jnp.broadcast_to(o, l.shape), out, local
            )
        if exclusive:
            # Local shift with the offset itself entering at position 0.
            out = jax.tree.map(
                lambda o, off: jnp.concatenate(
                    [jnp.broadcast_to(off[None], o[:1].shape), o[:-1]], axis=0
                ),
                out,
                offset,
            )
        return jax.tree.map(lambda x: jnp.moveaxis(x, 0, scan_axis), out)

    from repro.dist.sharding import shard_map

    fn = shard_map(
        local_fn, mesh=mesh, in_specs=(spec,), out_specs=spec
    )
    return fn(elems)


def make_sharded_cumsum(
    mesh: Mesh,
    axis_name: str,
    spec: P,
    **kw,
) -> "functools.partial":
    """Convenience: jit-ready global cumsum over a sharded axis."""
    return functools.partial(
        scan_sharded, mesh=mesh, axis_name=axis_name, spec=spec, **kw
    )
