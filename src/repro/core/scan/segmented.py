"""Segmented scans and partitioning-offset helpers.

This module hosts the paper's *motivating database use case* (§1): "prefix
sums are computed from a previously constructed histogram ... and then used
as the new index values" during a partitioning step. In this framework the
partitioning step is MoE token dispatch: tokens are partitioned by expert,
and the write offsets come from an exclusive prefix sum over the expert
histogram — plus a per-expert running rank, which is a segmented/one-hot
scan. Also used by the data pipeline for packed-sequence boundaries.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scan import assoc
from repro.core.scan import reference


def segmented_scan(
    values,
    flags,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    algorithm: str = "ref",
):
    """Inclusive scan restarting wherever ``flags != 0``.

    ``algorithm="kernel"`` routes sum-segmented scans through the Pallas
    scan engine's segmented registration (``kernels/segscan``), under
    whichever grid schedule ``core/scan/policy`` picks for the shape.
    """
    if algorithm == "kernel":
        if assoc.get(op).name != "sum":
            raise ValueError("kernel path supports the sum monoid")
        from repro.kernels.segscan import ops as seg_ops
        import jax.numpy as jnp
        v = jnp.moveaxis(values, axis, -1)
        f = jnp.moveaxis(flags, axis, -1)
        return jnp.moveaxis(seg_ops.segmented_cumsum(v, f), -1, axis)
    monoid = assoc.segmented(assoc.get(op))
    _, out = reference.scan_ref((flags, values), monoid, axis=axis)
    return out


class DispatchPlan(NamedTuple):
    """Result of the prefix-sum partitioning step (paper §1 use case).

    Attributes:
      counts: (E,) tokens routed to each expert (the histogram).
      offsets: (E,) exclusive prefix sum of counts — each expert's base
        write offset, exactly the paper's "new index values".
      ranks: (T,) position of each token within its expert's bucket.
      dest: (T,) = offsets[expert_id] + rank — the scatter destination.
    """

    counts: jax.Array
    offsets: jax.Array
    ranks: jax.Array
    dest: jax.Array


def _offsets_dtype(total: int):
    """Offset/rank dtype safe for ``total`` dispatched items.

    int32 covers totals below 2**31 (offsets and dest are bounded by the
    item count). Beyond that the scan would silently wrap, so we require
    x64 mode and widen — the join build path (``repro.relational.join``)
    leans on these offsets for billion-row sides.
    """
    if total < 2 ** 31:
        return jnp.int32
    if not jax.config.jax_enable_x64:
        raise OverflowError(
            f"dispatch over {total} items overflows int32 offsets; "
            "enable jax_enable_x64 for int64 dispatch")
    return jnp.int64


def dispatch_offsets(expert_ids: jax.Array, num_experts: int) -> DispatchPlan:
    """Compute partitioning offsets for tokens → experts via prefix sums.

    ``ranks`` is the exclusive running count of each expert along the token
    axis: a (T, E) one-hot cumulative sum — computed with the scan
    substrate — gathered at each token's own expert. This is the
    radix-partitioning pattern from the paper's §1 (Satish et al. / radix
    join), with experts playing the role of radix buckets.

    Args:
      expert_ids: (T,) int32 expert assignment per token (already flattened
        over top-k: a token chosen by k experts appears k times upstream).
    """
    dt = _offsets_dtype(expert_ids.shape[0])
    onehot = jax.nn.one_hot(expert_ids, num_experts, dtype=dt)  # (T, E)
    # Exclusive scan over tokens — per-expert running counts before me.
    running = reference.scan_ref(onehot, "sum", axis=0, exclusive=True)
    ranks = jnp.take_along_axis(
        running, expert_ids[:, None], axis=1
    ).squeeze(-1)
    counts = jnp.sum(onehot, axis=0)
    offsets = reference.scan_ref(counts, "sum", axis=0, exclusive=True)
    dest = offsets[expert_ids] + ranks
    return DispatchPlan(counts=counts, offsets=offsets, ranks=ranks, dest=dest)


def packed_segment_ids(lengths: jax.Array, total: int) -> jax.Array:
    """Segment ids for packed sequences from an exclusive length scan.

    Data-pipeline use: given per-document lengths, the exclusive prefix sum
    gives each document's start offset; the segment id of every token slot
    is then the count of starts at-or-before it, minus one.
    """
    starts = reference.scan_ref(lengths, "sum", axis=0, exclusive=True)
    slot = jnp.arange(total)
    return jnp.sum(slot[:, None] >= starts[None, :], axis=1) - 1
