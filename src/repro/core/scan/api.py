"""Public scan API — single entry point over every algorithm in the package.

    from repro.core import scan
    y = scan.cumsum(x)                      # policy-picked algorithm
    y = scan.scan(x, op="max", algorithm="blocked", block_size=8192)
    y = scan.scan((a, b), op="affine")      # SSM-style affine recurrence

Distributed use goes through ``scan.scan_sharded`` (see distributed.py);
kernel-backed use through ``repro.kernels.scan_blocked.ops``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scan import assoc
from repro.core.scan import blocked as _blocked
from repro.core.scan import horizontal as _horizontal
from repro.core.scan import policy
from repro.core.scan import reference as _reference
from repro.core.scan import tree as _tree
from repro.core.scan import vertical as _vertical

Pytree = Any

_ALGORITHMS = ("auto", "ref", "horizontal", "vertical", "tree", "blocked",
               "two_pass", "kernel")


def scan(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    algorithm: str = "auto",
    exclusive: bool = False,
    **kw,
) -> Pytree:
    """Inclusive (or exclusive) scan of ``elems`` along ``axis``."""
    if algorithm not in _ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; one of {_ALGORITHMS}")
    monoid = assoc.get(op)

    if algorithm == "auto":
        leaves = jax.tree.leaves(elems)
        n = leaves[0].shape[axis]
        batch = max(leaves[0].size // max(n, 1), 1)
        itemsize = sum(l.dtype.itemsize for l in leaves)
        kernel_ok = monoid.name == "sum" and len(leaves) == 1
        choice = policy.choose(n, itemsize, kernel_available=kernel_ok,
                               batch=batch)
        algorithm = choice.algorithm
        kw.setdefault("block_size", choice.block_size)
        if algorithm == "two_pass":
            kw.setdefault("variant", choice.variant)
        if algorithm == "kernel":
            kw.setdefault("schedule", choice.schedule)

    if algorithm == "kernel":
        from repro.kernels.scan_blocked import ops as kernel_ops

        (x,) = jax.tree.leaves(elems)
        kw.pop("block_size", None)
        return kernel_ops.cumsum(x, axis=axis, exclusive=exclusive, **kw)
    if algorithm == "ref":
        kw.pop("block_size", None)
        return _reference.scan_ref(elems, monoid, axis, exclusive=exclusive)
    if algorithm == "horizontal":
        kw.pop("block_size", None)
        return _horizontal.scan_horizontal(elems, monoid, axis, exclusive)
    if algorithm == "vertical":
        kw.pop("block_size", None)
        return _vertical.scan_vertical(elems, monoid, axis, exclusive=exclusive, **kw)
    if algorithm == "tree":
        kw.pop("block_size", None)
        return _tree.scan_tree(elems, monoid, axis, exclusive)
    if algorithm == "blocked":
        return _blocked.scan_blocked(elems, monoid, axis, exclusive=exclusive, **kw)
    if algorithm == "two_pass":
        if exclusive:
            inc = _blocked.scan_two_pass(elems, monoid, axis, **kw)
            return _shift_exclusive(inc, monoid, axis)
        return _blocked.scan_two_pass(elems, monoid, axis, **kw)
    raise AssertionError(algorithm)


def cumsum(x: jax.Array, axis: int = -1, exclusive: bool = False,
           algorithm: str = "auto", **kw) -> jax.Array:
    """Prefix sum with the policy-selected algorithm."""
    return scan(x, "sum", axis=axis, algorithm=algorithm,
                exclusive=exclusive, **kw)


def _shift_exclusive(inc: Pytree, monoid: assoc.Monoid, axis: int) -> Pytree:
    if jax.tree.leaves(inc)[0].shape[axis] == 0:
        return inc  # nothing to shift; identity_like of empty has no [0:1)
    ident_full = monoid.identity_like(inc)
    return jax.tree.map(
        lambda x, i: jnp.concatenate(
            [jax.lax.slice_in_dim(i, 0, 1, axis=axis),
             jax.lax.slice_in_dim(x, 0, x.shape[axis] - 1, axis=axis)],
            axis=axis,
        ),
        inc,
        ident_full,
    )
