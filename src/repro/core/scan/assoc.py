"""Associative-operator (monoid) framework for generalized prefix scans.

The paper computes prefix *sums* (binary op = ``+``), but every algorithm in
it — horizontal/vertical/tree SIMD, the two-pass multithreaded organizations,
and cache-friendly partitioning — only requires an *associative* operator
with an identity. We expose that generality so the same machinery drives:

  * plain cumulative sums (the paper's object of study),
  * ``max``/``min`` scans (running extrema),
  * the *affine* monoid ``h' = a*h + b`` (diagonal SSM recurrences: Mamba2
    decay, xLSTM gates),
  * the *softmax pair* monoid ``(m, s)`` (flash attention's online softmax),
  * the *segmented* wrapper that resets at flag boundaries (MoE ranking).

Elements of a monoid may be arbitrary pytrees (e.g. the affine monoid's
elements are ``(a, b)`` pairs); ``combine`` must be associative over them.

Monoids that also run INSIDE Pallas kernels carry a :class:`KernelSpec`
(flat array leaves, identity fill constants, in-kernel combine/select
emitters) — the interface the monoid-generic scan engine
(``repro.kernels.scan_engine``) writes each grid schedule against, once.
Registered here: sum, segmented sum, affine, the compact-mask spec, and
the flash-attention softmax-pair spec (a *carried payload* monoid: its
elements are built per block by an input TRANSFORM from raw operand
tiles rather than read from pre-materialized element arrays) plus its
two BACKWARD specs — dq as a sum fold over KV blocks, dk/dv as a sum
fold over a transposed q-major layout — which recompute the logits
per tile instead of materializing the attention matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Kernel-side monoid: flat array leaves plus in-kernel emitters.

    The Pallas scan engine (``repro.kernels.scan_engine``) writes each grid
    organization — carry chain, decoupled reduce-then-scan, fused
    single-launch — exactly ONCE against this interface; registering a spec
    is all it takes to run a new monoid under every schedule.

    Unlike :class:`Monoid` (pytree elements, library scans), a kernel spec
    works on TUPLES of same-shape arrays, because Pallas refs are flat.
    Every callable must be shape-polymorphic and broadcasting-safe: the
    engine applies them to full VMEM tiles, to size-1 carry slices, and to
    per-chunk totals alike.

    Attributes:
      name: registry key (also the Pallas kernel name suffix).
      fills: per-leaf identity CONSTANTS — used to pad log-scan shifts, to
        reset the grid carry, and to seed the decoupled combine chain.
      combine: ``combine(left, right)`` over leaf tuples; ``left`` is the
        earlier (lower-index) element. Must broadcast (carries keep the
        scan axis at size 1).
      elem_dtypes: operand dtypes -> accumulation dtype per element leaf.
      out_dtypes: operand dtypes -> dtype per emitted output array.
      out_leaves: which combined leaves are emitted (default: leaf 0).
      emit: optional ``emit(elems, combined) -> outputs`` override — the
        in-kernel select emitter (e.g. compaction's fused predicate
        select). ``elems`` are the raw block elements in accumulate dtype,
        ``combined`` the carry-adjusted inclusive scan.
      supports_exclusive: whether the engine may shift-and-fill for
        ``exclusive=True``.
      transform: optional per-block INPUT TRANSFORM. When set, the monoid
        is a *carried payload*: the engine does not read element arrays
        at all — each grid block along the scanned axis yields ONE macro
        element ``transform(op_tiles, block_ids) -> leaf tuple`` computed
        from the raw operand tiles (flash attention: the ``q·kᵀ`` logits
        block with masking, folded to its ``(m, l, p·v)`` triple).
        ``block_ids`` are the layout's grid coordinates (the transform
        needs them for position-dependent masking). Leaves may have
        per-leaf trailing dims (the layout's ``leaf_dims``); the scan is
        a FOLD over blocks — outputs are emitted once, from the final
        carried state.
      finalize: ``finalize(combined) -> outputs`` for transform monoids —
        the fold-time emitter (flash attention's ``acc / l`` normalize).
    """

    name: str
    fills: tuple
    combine: Callable[[tuple, tuple], tuple]
    elem_dtypes: Callable[[tuple], tuple]
    out_dtypes: Callable[[tuple], tuple]
    out_leaves: tuple = (0,)
    emit: "Callable[[tuple, tuple], tuple] | None" = None
    supports_exclusive: bool = True
    transform: "Callable[[tuple, tuple], tuple] | None" = None
    finalize: "Callable[[tuple], tuple] | None" = None

    @property
    def n_leaves(self) -> int:
        return len(self.fills)


@dataclasses.dataclass(frozen=True)
class Monoid:
    """An associative operator with identity, over pytree elements.

    Attributes:
      name: registry key.
      combine: ``combine(left, right)`` — associative, pytree -> pytree.
        Convention: ``left`` is the earlier (lower-index) element.
      identity_like: given one element (pytree of arrays), produce the
        identity element with matching shapes/dtypes.
      kernel_spec: optional :class:`KernelSpec` — the same monoid stated
        kernel-side, consumed by ``repro.kernels.scan_engine``.
    """

    name: str
    combine: Callable[[Pytree, Pytree], Pytree]
    identity_like: Callable[[Pytree], Pytree]
    kernel_spec: "KernelSpec | None" = None

    def fold(self, elems: Pytree, axis: int = 0) -> Pytree:
        """Reduce ``elems`` along ``axis`` with this monoid (tree-shaped).

        Pairs ADJACENT elements at every level (like the paper's up-sweep),
        which preserves operand order — required for non-commutative
        monoids such as the affine SSM recurrence.
        """
        n = _axis_len(elems, axis)
        if n == 0:
            raise ValueError("cannot fold an empty axis")
        while n > 1:
            half = n // 2
            even = _stride2(elems, axis, 0, half)
            odd = _stride2(elems, axis, 1, half)
            merged = self.combine(even, odd)
            if n % 2:
                tail = _slice(elems, axis, 2 * half, n)
                merged = _concat([merged, tail], axis)
            elems, n = merged, half + (n % 2)
        return _squeeze(elems, axis)


def _axis_len(tree: Pytree, axis: int) -> int:
    leaves = jax.tree.leaves(tree)
    return leaves[0].shape[axis]


def _stride2(tree: Pytree, axis: int, start: int, count: int) -> Pytree:
    """Every other element along ``axis``: indices start, start+2, ..."""

    def f(x):
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(start, start + 2 * count, 2)
        return x[tuple(idx)]

    return jax.tree.map(f, tree)


def _slice(tree: Pytree, axis: int, lo: int, hi: int) -> Pytree:
    def f(x):
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(lo, hi)
        return x[tuple(idx)]

    return jax.tree.map(f, tree)


def _concat(trees, axis: int) -> Pytree:
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=axis), *trees)


def _squeeze(tree: Pytree, axis: int) -> Pytree:
    def f(x):
        idx = [slice(None)] * x.ndim
        idx[axis] = 0
        return x[tuple(idx)]

    return jax.tree.map(f, tree)


# ---------------------------------------------------------------------------
# Kernel specs (flat-leaf monoids for the Pallas scan engine)
# ---------------------------------------------------------------------------


def accum_dtype(dt):
    """Accumulation dtype policy shared by every kernel registration."""
    dt = jnp.dtype(dt)
    if dt in (jnp.bfloat16, jnp.float16):
        return jnp.dtype(jnp.float32)
    if dt in (jnp.int8, jnp.int16):
        return jnp.dtype(jnp.int32)
    return dt


def _sum_kcombine(left, right):
    return (left[0] + right[0],)


SUM_KERNEL = KernelSpec(
    name="sum",
    fills=(0,),
    combine=_sum_kcombine,
    elem_dtypes=lambda dts: (accum_dtype(dts[0]),),
    out_dtypes=lambda dts: (jnp.dtype(dts[0]),),
)


def _segmented_sum_kcombine(left, right):
    v1, f1 = left
    v2, f2 = right
    # A flag anywhere on the right KILLS the incoming value (Blelloch's
    # segmented lift). Flags accumulate as a boolean OR of ``!= 0`` — NOT
    # a max, which a negative nonzero flag would silently escape.
    seen = jnp.logical_or(f1 != 0, f2 != 0)
    return (jnp.where(f2 != 0, v2, v1 + v2), seen.astype(f1.dtype))


SEGMENTED_SUM_KERNEL = KernelSpec(
    name="segsum",
    fills=(0, 0),
    combine=_segmented_sum_kcombine,
    elem_dtypes=lambda dts: (accum_dtype(dts[0]), jnp.dtype(jnp.int32)),
    out_dtypes=lambda dts: (jnp.dtype(dts[0]),),
)


def _affine_kcombine(left, right):
    a1, b1 = left
    a2, b2 = right
    return (a1 * a2, a2 * b1 + b2)


AFFINE_KERNEL = KernelSpec(
    name="affine",
    fills=(1, 0),
    combine=_affine_kcombine,
    elem_dtypes=lambda dts: (accum_dtype(dts[0]), accum_dtype(dts[1])),
    out_dtypes=lambda dts: (jnp.dtype(dts[1]),),
    out_leaves=(1,),
)


def mask_kernel_spec(sentinel: int) -> KernelSpec:
    """Compact-mask monoid: a 0/1 keep-mask cumsum with the predicate
    select FUSED into the writeback — surviving lanes emit their exclusive
    rank (global scatter destination once the chunk offset is combined),
    dropped lanes emit ``sentinel``. The monoid itself is integer SUM; the
    select emitter is what makes it stream compaction (paper §1).
    """

    def emit(elems, combined):
        m = elems[0]
        # combined is the carry-adjusted INCLUSIVE mask scan; minus the
        # element itself gives the exclusive rank (exact: integers).
        return (jnp.where(m != 0, combined[0] - m, sentinel),)

    return KernelSpec(
        name="mask",
        fills=(0,),
        combine=_sum_kcombine,
        elem_dtypes=lambda dts: (jnp.dtype(jnp.int32),),
        out_dtypes=lambda dts: (jnp.dtype(jnp.int32),),
        emit=emit,
        supports_exclusive=False,
    )


# Finite stand-in for -inf in masked logits: keeps the softmax-pair
# max-carry NaN-free (``-inf - -inf`` is NaN; ``NEG_INF - NEG_INF`` is 0).
# Masked probabilities are additionally zeroed (``p = where(mask, ·, 0)``)
# so a fully-masked row yields l == 0 and finalizes to EXACTLY 0 — not
# the visited-column-count-dependent uniform softmax. That invariance is
# what makes the causal-aware KV bound bitwise-free: a skipped
# fully-masked block's element is the monoid identity ``(NEG_INF, 0, 0)``,
# and combining the identity in is bitwise a no-op.
NEG_INF = -1e30


def _softmax_acc_kcombine(left, right):
    """Carried-payload lift of the softmax pair: (m, l, acc) triples.

    ``m`` is the running row max, ``l`` the sum of ``exp(s - m)``, and
    ``acc`` the exp-weighted value accumulator — both sums rescale by
    ``exp(m_i - m)`` when the shared max moves. Associative; identity is
    ``(NEG_INF, 0, 0)`` (exp underflows to exactly 0 against any live
    max, and ``exp(0) = 1`` against another NEG_INF).
    """
    m1, l1, a1 = left
    m2, l2, a2 = right
    m = jnp.maximum(m1, m2)
    alpha1 = jnp.exp(m1 - m)
    alpha2 = jnp.exp(m2 - m)
    return (m, l1 * alpha1 + l2 * alpha2, a1 * alpha1 + a2 * alpha2)


def _attn_block_logits(q, k, block_ids, *, scale, causal, window, softcap,
                       kv_len, block_q, block_k):
    """Shared q·kᵀ logits tile for the attention forward AND backward
    transforms: ``(s, mask)`` where ``s`` is the scaled (and softcapped)
    logits block BEFORE masking and ``mask`` the combined
    causal/window/length liveness — stated once so the backward's
    recomputed logits are bit-identical to the forward's.

    ``block_ids`` convention (``KVBlocks``/``QBlocks`` layouts):
    ``(head, q_block, kv_block)`` — absolute row/col positions derive
    from the last two. ``kv_len`` masks padded KV tails (``None``: no
    length mask beyond the geometry).
    """
    _, qi, kj = block_ids[0], block_ids[-2], block_ids[-1]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if kv_len is not None:
        mask &= cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return s, mask


def softmax_pair_kernel_spec(
    *,
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    with_stats: bool = False,
) -> KernelSpec:
    """Flash-attention monoid: online softmax with the value payload.

    The KV-block loop of flash attention is an inclusive FOLD over KV
    blocks of :data:`SOFTMAX_PAIR` with the weighted-value accumulator
    carried alongside. The per-block element is produced by the input
    transform — ``q·kᵀ`` logits with causal/window/softcap/length
    masking, folded within the block to its ``(m, l, acc)`` triple — so
    the engine's schedules never see an element array, only operands
    ``(q, k, v)`` tiles of shapes ``(bq, d)/(bk, d)/(bk, d)``.

    ``with_stats=True`` additionally emits the folded ``(m, l)`` row
    statistics (f32, trailing dim 1) after the normalized output — the
    residuals the backward folds need to reconstruct the softmax without
    materializing the attention matrix.

    Masked probabilities are zeroed, so a fully-masked row emits exactly
    0 (and zero gradients) rather than a uniform average over however
    many masked columns the grid happened to visit — the invariance that
    lets the causal-aware KV bound skip fully-masked blocks bitwise-free.
    """

    def transform(ops, block_ids):
        q, k, v = (o.astype(jnp.float32) for o in ops)
        s, mask = _attn_block_logits(
            q, k, block_ids, scale=scale, causal=causal, window=window,
            softcap=softcap, kv_len=kv_len, block_q=block_q,
            block_k=block_k)
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)             # (bq, 1)
        # exp underflows to exactly 0 at masked columns of LIVE rows, so
        # the where only changes fully-masked rows (m == NEG_INF there,
        # where exp(s - m) would be exp(0) = 1): they get l == 0.
        p = jnp.where(mask, jnp.exp(s - m), 0.0)          # (bq, bk)
        l = jnp.sum(p, axis=1, keepdims=True)             # (bq, 1)
        acc = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, d)
        return (m, l, acc)

    def finalize(combined):
        m, l, acc = combined
        # l == 0 marks a fully-masked row (or an empty fold): acc is 0
        # there, and the guarded divide makes the output exactly 0.
        safe = jnp.where(l == 0.0, 1.0, l)
        if with_stats:
            return (acc / safe, m, l)
        return (acc / safe,)

    def out_dtypes(dts):
        if with_stats:
            return (jnp.dtype(dts[0]), jnp.dtype(jnp.float32),
                    jnp.dtype(jnp.float32))
        return (jnp.dtype(dts[0]),)

    return KernelSpec(
        name="softmax_pair",
        fills=(NEG_INF, 0, 0),
        combine=_softmax_acc_kcombine,
        elem_dtypes=lambda dts: (jnp.dtype(jnp.float32),) * 3,
        out_dtypes=out_dtypes,
        supports_exclusive=False,
        transform=transform,
        finalize=finalize,
    )


def _identity_finalize(combined):
    return tuple(combined)


def _attn_bwd_ds(ops, block_ids, *, scale, causal, window, softcap, kv_len,
                 block_q, block_k):
    """Shared backward tile: recomputed probabilities ``p`` and masked
    logit gradients ``ds`` for one (q-block, kv-block) cell.

    ``ops`` are f32 tiles ``(q, k, v, do, m, l, delta)`` where ``m``/``l``
    are the forward's saved row statistics and ``delta = rowsum(dO ⊙ O)``
    — the standard flash backward: ``p = exp(s - m)/l`` (no materialized
    attention matrix outside this tile), ``dp = dO·Vᵀ``,
    ``ds = p ⊙ (dp - delta)``, with the softcap chain rule
    ``tanh' = 1 - (s/cap)²`` applied on the recomputed capped logits.
    """
    q, k, v, do, m, l, delta = ops
    s, mask = _attn_block_logits(
        q, k, block_ids, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_len=kv_len, block_q=block_q, block_k=block_k)
    sm = jnp.where(mask, s, NEG_INF)
    safe_l = jnp.where(l == 0.0, 1.0, l)
    p = jnp.where(mask, jnp.exp(sm - m), 0.0) / safe_l    # (bq, bk)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bq, bk)
    ds = p * (dp - delta)
    if softcap is not None:
        ds = ds * (1.0 - (s / softcap) ** 2)              # tanh'
    return p, ds


def _dsum_kcombine(left, right):
    return tuple(a + b for a, b in zip(left, right))


def softmax_pair_bwd_dq_kernel_spec(
    *,
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
) -> KernelSpec:
    """Flash-backward dq: a SUM fold over KV blocks (``KVBlocks``).

    Operands ``(q, k, v, do, m, l, delta)``; each block contributes
    ``scale · ds @ K`` to the carried (bq, d) dq accumulator. Plain sum
    monoid — all the attention structure lives in the transform, so the
    engine's fold schedules (carry accumulate / split-KV decoupled) run
    it unchanged.
    """

    def transform(ops, block_ids):
        ops = tuple(o.astype(jnp.float32) for o in ops)
        _, ds = _attn_bwd_ds(
            ops, block_ids, scale=scale, causal=causal, window=window,
            softcap=softcap, kv_len=kv_len, block_q=block_q,
            block_k=block_k)
        k = ops[1]
        dq = jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, d)
        return (dq,)

    return KernelSpec(
        name="softmax_bwd_dq",
        fills=(0,),
        combine=_dsum_kcombine,
        elem_dtypes=lambda dts: (jnp.dtype(jnp.float32),),
        out_dtypes=lambda dts: (jnp.dtype(dts[0]),),
        supports_exclusive=False,
        transform=transform,
        finalize=_identity_finalize,
    )


def softmax_pair_bwd_dkv_kernel_spec(
    *,
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
) -> KernelSpec:
    """Flash-backward dk/dv: a SUM fold over q blocks (``QBlocks``).

    The transposed organization: for each KV block the fold walks the
    (group × q-block) axis — GQA head summation included, since every q
    head mapping to this KV head is part of the fold — accumulating
    ``dk += scale · dsᵀ @ Q`` and ``dv += pᵀ @ dO`` into the carried
    (bk, d) pair.
    """

    def transform(ops, block_ids):
        ops = tuple(o.astype(jnp.float32) for o in ops)
        p, ds = _attn_bwd_ds(
            ops, block_ids, scale=scale, causal=causal, window=window,
            softcap=softcap, kv_len=kv_len, block_q=block_q,
            block_k=block_k)
        q, do = ops[0], ops[3]
        dk = jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bk, d)
        dv = jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bk, d)
        return (dk, dv)

    return KernelSpec(
        name="softmax_bwd_dkv",
        fills=(0, 0),
        combine=_dsum_kcombine,
        elem_dtypes=lambda dts: (jnp.dtype(jnp.float32),) * 2,
        out_dtypes=lambda dts: (jnp.dtype(dts[1]), jnp.dtype(dts[2])),
        supports_exclusive=False,
        transform=transform,
        finalize=_identity_finalize,
    )


# ---------------------------------------------------------------------------
# Standard monoids
# ---------------------------------------------------------------------------


def _sum_identity(x):
    return jax.tree.map(jnp.zeros_like, x)


SUM = Monoid("sum", lambda a, b: jax.tree.map(jnp.add, a, b), _sum_identity,
             kernel_spec=SUM_KERNEL)

PROD = Monoid(
    "prod",
    lambda a, b: jax.tree.map(jnp.multiply, a, b),
    lambda x: jax.tree.map(jnp.ones_like, x),
)


def _min_value(dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.iinfo(dtype).min
    return -jnp.inf


def _max_value(dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.iinfo(dtype).max
    return jnp.inf


MAX = Monoid(
    "max",
    lambda a, b: jax.tree.map(jnp.maximum, a, b),
    lambda x: jax.tree.map(lambda v: jnp.full_like(v, _min_value(v.dtype)), x),
)

MIN = Monoid(
    "min",
    lambda a, b: jax.tree.map(jnp.minimum, a, b),
    lambda x: jax.tree.map(lambda v: jnp.full_like(v, _max_value(v.dtype)), x),
)


# ---------------------------------------------------------------------------
# Affine monoid: elements (a, b) represent x -> a*x + b (elementwise).
# Composition (earlier ∘ later): (a1,b1) then (a2,b2) is x -> a2*(a1*x+b1)+b2
#   = (a1*a2, a2*b1 + b2).  Identity: (1, 0).
# This is the recurrence h_t = a_t * h_{t-1} + b_t: the inclusive scan of
# the (a_t, b_t) elements yields, at position t, the map from h_0 to h_t;
# its `b` component (with h_0 = 0) is the hidden state trajectory.
# ---------------------------------------------------------------------------


def _affine_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return (a1 * a2, a2 * b1 + b2)


AFFINE = Monoid(
    "affine",
    _affine_combine,
    lambda x: (jnp.ones_like(x[0]), jnp.zeros_like(x[1])),
    kernel_spec=AFFINE_KERNEL,
)


# ---------------------------------------------------------------------------
# Online-softmax monoid: elements (m, s) where m is a running max and s the
# sum of exp(x - m). Flash attention's KV-block loop is an inclusive scan of
# these pairs — i.e. the paper's blocked-scan pattern with this monoid.
# ---------------------------------------------------------------------------


def _softmax_combine(left, right):
    m1, s1 = left
    m2, s2 = right
    m = jnp.maximum(m1, m2)
    s = s1 * jnp.exp(m1 - m) + s2 * jnp.exp(m2 - m)
    return (m, s)


# Kernel-side, the registration is ``softmax_pair_kernel_spec`` — a
# config-dependent factory (like ``mask_kernel_spec``) because masking
# geometry is baked into the per-block input transform, so the Monoid
# carries no static ``kernel_spec``.
SOFTMAX_PAIR = Monoid(
    "softmax_pair",
    _softmax_combine,
    lambda x: (jnp.full_like(x[0], -jnp.inf), jnp.zeros_like(x[1])),
)


# ---------------------------------------------------------------------------
# Matrix-affine monoid for matrix-state recurrences (mLSTM / general SSM):
# elements (a, B) with scalar (or broadcastable) decay a and matrix update B:
#   H' = a * H + B.  Same composition law as AFFINE (a broadcasts over B).
# ---------------------------------------------------------------------------

MATRIX_AFFINE = Monoid(
    "matrix_affine",
    _affine_combine,
    lambda x: (jnp.ones_like(x[0]), jnp.zeros_like(x[1])),
)


REGISTRY: dict[str, Monoid] = {
    m.name: m for m in (SUM, PROD, MAX, MIN, AFFINE, SOFTMAX_PAIR, MATRIX_AFFINE)
}


def get(op: "str | Monoid") -> Monoid:
    if isinstance(op, Monoid):
        return op
    try:
        return REGISTRY[op]
    except KeyError:
        raise ValueError(
            f"unknown monoid {op!r}; known: {sorted(REGISTRY)}"
        ) from None


def segmented(base: Monoid) -> Monoid:
    """Lift ``base`` into its segmented variant.

    Elements are ``(flag, value)`` where ``flag != 0`` marks the start of a
    new segment. The scan of the lifted monoid restarts at every flag —
    standard construction (Blelloch 1990), used here for MoE per-expert
    ranking and for packed-sequence boundaries in the data pipeline.
    """

    def combine(left, right):
        f1, v1 = left
        f2, v2 = right
        both = base.combine(v1, v2)
        keep_right = jax.tree.map(
            lambda b, r: jnp.where(_bcast(f2, r), r, b), both, v2
        )
        # OR of ``!= 0``, not max: any nonzero flag (negative included)
        # must keep marking the segment start through later combines.
        seen = jnp.logical_or(f1 != 0, f2 != 0).astype(f1.dtype)
        return (seen, keep_right)

    def identity_like(x):
        f, v = x
        return (jnp.zeros_like(f), base.identity_like(v))

    kspec = SEGMENTED_SUM_KERNEL if base.name == "sum" else None
    return Monoid(f"segmented_{base.name}", combine, identity_like,
                  kernel_spec=kspec)


def _bcast(flag, val):
    """Broadcast a flag array against a value array from the left."""
    extra = val.ndim - flag.ndim
    if extra > 0:
        flag = flag.reshape(flag.shape + (1,) * extra)
    return flag != 0
