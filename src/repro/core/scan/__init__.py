"""Prefix-scan substrate — the paper's contribution as a composable library.

Algorithm map (paper section → module):
  §3.1 horizontal SIMD  → horizontal.scan_horizontal
  §3.2 vertical SIMD    → vertical.scan_vertical (V1/V2)
  §3.3 tree SIMD        → tree.scan_tree
  §2.1 two-pass threads → blocked.scan_two_pass (variants, dilation),
                          distributed.scan_sharded (devices as threads)
  §2.2 cache partition  → blocked.scan_blocked, kernels/scan_blocked (Pallas)
  §5   recommendations  → policy.choose
"""

from repro.core.scan import assoc
from repro.core.scan.api import cumsum, scan
from repro.core.scan.assoc import (AFFINE, MATRIX_AFFINE, MAX, MIN, PROD,
                                   SOFTMAX_PAIR, SUM, Monoid)
from repro.core.scan.blocked import (partition_sizes, scan_blocked,
                                     scan_two_pass)
from repro.core.scan.distributed import make_sharded_cumsum, scan_sharded
from repro.core.scan.horizontal import scan_horizontal
from repro.core.scan.policy import Choice, choose
from repro.core.scan.reference import cumsum_ref, scan_ref, segmented_scan_ref
from repro.core.scan.segmented import (DispatchPlan, dispatch_offsets,
                                       packed_segment_ids, segmented_scan)
from repro.core.scan.tree import scan_tree
from repro.core.scan.vertical import scan_vertical

__all__ = [
    "AFFINE", "MATRIX_AFFINE", "MAX", "MIN", "PROD", "SOFTMAX_PAIR", "SUM",
    "Monoid", "Choice", "DispatchPlan", "choose", "cumsum", "cumsum_ref",
    "dispatch_offsets", "make_sharded_cumsum", "packed_segment_ids",
    "partition_sizes", "scan", "scan_blocked", "scan_horizontal", "scan_ref",
    "scan_sharded", "scan_tree", "scan_two_pass", "scan_vertical",
    "segmented_scan", "segmented_scan_ref",
]
