"""Pure sequential reference scans — the semantic ground truth.

Every parallel algorithm in this package (horizontal, vertical, tree,
blocked, distributed, and the Pallas kernels) is validated against these
oracles. They correspond to the paper's ``Scalar`` baseline: one sequential
pass of the associative operator (Table 2, row 1).

The implementations use ``jax.lax.scan`` so they are jittable and exactly
sequential (no reassociation — relevant for float32, see paper §1.1's
non-associativity caveat).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scan import assoc

Pytree = Any


def _move_axis_first(tree: Pytree, axis: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.moveaxis(x, axis, 0), tree)


def _move_axis_back(tree: Pytree, axis: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.moveaxis(x, 0, axis), tree)


def scan_ref(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    exclusive: bool = False,
    reverse: bool = False,
) -> Pytree:
    """Sequential inclusive (or exclusive) scan along ``axis``.

    For ``exclusive=True`` the output at position ``i`` is the fold of
    elements ``[0, i)`` with the identity at position 0 (the paper's
    "pre-scan").
    """
    monoid = assoc.get(op)
    elems = _move_axis_first(elems, axis)
    n = jax.tree.leaves(elems)[0].shape[0]
    if n == 0:
        # A length-0 scan is its (empty) input — there is nothing to
        # combine and lax.scan's init would need a leaf to infer from.
        return _move_axis_back(elems, axis)
    first = jax.tree.map(lambda x: x[0], elems)
    init = monoid.identity_like(first)

    if reverse:
        elems = jax.tree.map(lambda x: jnp.flip(x, 0), elems)

    def step(carry, x):
        new = monoid.combine(carry, x)
        out = carry if exclusive else new
        return new, out

    _, ys = jax.lax.scan(step, init, elems)
    if reverse:
        ys = jax.tree.map(lambda x: jnp.flip(x, 0), ys)
    return _move_axis_back(ys, axis)


def cumsum_ref(x: jax.Array, axis: int = -1, exclusive: bool = False) -> jax.Array:
    """Prefix sum oracle (inclusive by default), accumulating in f32/i64-safe dtype."""
    acc_dtype = _accum_dtype(x.dtype)
    out = scan_ref(x.astype(acc_dtype), "sum", axis=axis, exclusive=exclusive)
    return out.astype(x.dtype) if x.dtype != acc_dtype else out


def segmented_scan_ref(
    values: Pytree,
    flags: jax.Array,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
) -> Pytree:
    """Segmented inclusive scan: restart at every nonzero flag."""
    monoid = assoc.segmented(assoc.get(op))
    _, out = scan_ref((flags, values), monoid, axis=axis)
    return out


def _accum_dtype(dtype) -> jnp.dtype:
    """Widen low-precision dtypes for accumulation — ONE policy, shared
    with the kernel engine (``assoc.accum_dtype``) so reference and
    kernel accumulation can never silently diverge."""
    return assoc.accum_dtype(dtype)
