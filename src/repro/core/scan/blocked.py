"""Cache-friendly partitioned scan — the paper's §2.2, on the XLA/TPU stack.

Two entry points:

``scan_blocked``
    The partitioned ("-P") algorithm: data is cut into cache/VMEM-sized
    blocks; BOTH passes over a block happen while it is resident, and a
    running carry links consecutive blocks. Expressed as a ``lax.scan``
    whose carry is the block total — one pass over the data in memory-
    traffic terms (the Pallas kernel ``repro.kernels.scan_blocked`` is the
    explicitly-tiled version of this same schedule).

``scan_two_pass``
    The NON-partitioned baseline (paper Fig. 1a–d): pass 1 over *all* data,
    then pass 2 over *all* data — i.e. twice the slow-memory traffic. Both
    pass organizations are implemented:
      variant 1 (Fig 1a/1c): local prefix sums first, increment second;
      variant 2 (Fig 1b/1d): accumulate totals first, offset scan second.
    Supports the paper's dilation factor ``d`` (Fig 1c/1d: partition 0 is
    shrunk to ``d × B`` to balance scan-vs-increment subprocedure speeds).

On real hardware the difference between these two is the paper's headline
result (partitioned ≈ 1.7× faster once bandwidth-bound). In XLA the fusion
boundary plays the cache's role: ``scan_two_pass`` materializes the full
intermediate, ``scan_blocked`` streams it.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.scan import assoc
from repro.core.scan import horizontal
from repro.core.scan import reference

Pytree = Any


def _axis_first(tree: Pytree, axis: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.moveaxis(x, axis, 0), tree)


def _axis_back(tree: Pytree, axis: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.moveaxis(x, 0, axis), tree)


def _pad_to(tree: Pytree, monoid: assoc.Monoid, n: int, target: int) -> Pytree:
    if target == n:
        return tree
    ident_full = monoid.identity_like(tree)
    return jax.tree.map(
        lambda x, i: jnp.concatenate(
            [x, jnp.broadcast_to(i[:1], (target - n,) + i.shape[1:])], axis=0
        ),
        tree,
        ident_full,
    )


def _inner_scan(block: Pytree, monoid: assoc.Monoid, inner: str) -> Pytree:
    if inner == "horizontal":
        return horizontal.scan_horizontal(block, monoid, axis=0)
    if inner == "ref":
        return reference.scan_ref(block, monoid, axis=0)
    raise ValueError(f"unknown inner scan {inner!r}")


def scan_blocked(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    block_size: int = 4096,
    inner: str = "horizontal",
    exclusive: bool = False,
) -> Pytree:
    """Partitioned scan with a carried running total (paper §2.2).

    The ``lax.scan`` carry is the prior blocks' fold — the role played by
    "the total sum from the previous partition" in the paper. Within a
    block the inclusive scan uses the horizontal (in-register) algorithm.
    """
    monoid = assoc.get(op)
    leaves = jax.tree.leaves(elems)
    axis = axis % leaves[0].ndim
    n = leaves[0].shape[axis]
    if n == 0:
        # Zero blocks: the lax.scan init below would index block [0, 0].
        return elems

    x = _axis_first(elems, axis)
    num_blocks = -(-n // block_size)
    padded = num_blocks * block_size
    x = _pad_to(x, monoid, n, padded)
    x = jax.tree.map(
        lambda a: a.reshape((num_blocks, block_size) + a.shape[1:]), x
    )

    first = jax.tree.map(lambda a: a[0, 0], x)
    init = monoid.identity_like(first)

    def step(carry, block):
        local = _inner_scan(block, monoid, inner)
        # Both "passes" over this block happen here, while it is resident:
        # pass 1 = the in-block scan, pass 2 = the carry combine.
        out = monoid.combine(jax.tree.map(lambda c: c[None], carry), local)
        out = jax.tree.map(
            lambda o, l: jnp.broadcast_to(o, l.shape), out, local
        )
        new_carry = jax.tree.map(lambda o: o[-1], out)
        return new_carry, out

    _, blocks_out = jax.lax.scan(step, init, x)
    out = jax.tree.map(
        lambda a: a.reshape((padded,) + a.shape[2:])[:n], blocks_out
    )
    if exclusive:
        ident_full = monoid.identity_like(out)
        out = jax.tree.map(
            lambda o, i: jnp.concatenate([i[:1], o[:-1]], axis=0),
            out,
            ident_full,
        )
    return _axis_back(out, axis)


def partition_sizes(
    n: int, num_partitions: int, dilation: float = 1.0
) -> list[int]:
    """Split ``n`` into partitions, partition 0 scaled by ``dilation``.

    ``dilation=1`` → equal sizes (the standard-library default the paper
    criticizes); ``dilation=0`` → partition 0 vanishes (Fig 1a/1b are the
    d=0 special cases of Fig 1c/1d).
    """
    if not 0.0 <= dilation <= 1.0:
        raise ValueError("dilation must be in [0, 1]")
    denom = dilation + (num_partitions - 1)
    first = int(round(n * dilation / denom)) if denom else 0
    rest = num_partitions - 1
    base = (n - first) // rest if rest else 0
    sizes = [first] + [base] * rest
    sizes[-1] += n - sum(sizes)
    return [s for s in sizes if s > 0] or [n]


def scan_two_pass(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    num_partitions: int = 8,
    variant: int = 2,
    dilation: float = 1.0,
    sizes: "Sequence[int] | None" = None,
) -> Pytree:
    """Unfused two-full-pass scan (paper Fig. 1) — the baseline to beat.

    Partition sizes are static Python values, so unequal (dilated)
    partitions lower to a flat XLA graph; parallelism across partitions is
    explicit in the graph exactly as thread-parallelism is in the paper.
    """
    if variant not in (1, 2):
        raise ValueError("variant must be 1 or 2")
    monoid = assoc.get(op)
    leaves = jax.tree.leaves(elems)
    axis = axis % leaves[0].ndim
    n = leaves[0].shape[axis]
    if n == 0:
        # partition_sizes(0, ...) yields one empty partition, whose
        # pass-1 fold has nothing to reduce — the scan is its input.
        return elems
    if sizes is None:
        sizes = partition_sizes(n, num_partitions, dilation)
    if sum(sizes) != n:
        raise ValueError("partition sizes must sum to the axis length")

    x = _axis_first(elems, axis)
    parts, lo = [], 0
    for s in sizes:
        parts.append(jax.tree.map(lambda a: a[lo : lo + s], x))
        lo += s

    if variant == 1:
        # Pass 1: local prefix sums (writes the whole array once).
        locals_ = [horizontal.scan_horizontal(p, monoid, axis=0) for p in parts]
        totals = [jax.tree.map(lambda a: a[-1], l) for l in locals_]
        offsets = _exclusive_offsets(totals, monoid)
        # Pass 2: increment every element (reads + writes the array again).
        out_parts = [
            jax.tree.map(
                lambda o, l: jnp.broadcast_to(o, l.shape),
                monoid.combine(jax.tree.map(lambda c: c[None], off), loc),
                loc,
            )
            for off, loc in zip(offsets, locals_)
        ]
    else:
        # Pass 1: accumulate totals only (reads, NO writes — Fig 1b).
        totals = [monoid.fold(p, axis=0) for p in parts]
        offsets = _exclusive_offsets(totals, monoid)
        # Pass 2: scan with the offset folded in.
        out_parts = []
        for off, p in zip(offsets, parts):
            loc = horizontal.scan_horizontal(p, monoid, axis=0)
            out = monoid.combine(jax.tree.map(lambda c: c[None], off), loc)
            out_parts.append(
                jax.tree.map(lambda o, l: jnp.broadcast_to(o, l.shape), out, loc)
            )

    out = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *out_parts)
    return _axis_back(out, axis)


def _exclusive_offsets(totals: list, monoid: assoc.Monoid) -> list:
    """Exclusive folds of the per-partition totals (the `sums` array)."""
    offsets = [monoid.identity_like(totals[0])]
    acc = totals[0]
    for t in totals[1:]:
        offsets.append(acc)
        acc = monoid.combine(acc, t)
    return offsets
