"""Algorithm selection policy — the paper's §5 observations, codified.

The paper closes with five observations about which algorithm to use when.
This module turns them into an executable policy so `repro.core.scan.api`
can pick a sensible default, and so the choice is documented in one place:

  Obs 1  Dilation factors are fragile → we never auto-pick dilated variants;
         equal partitions + partitioning (whose one tunable, the block size,
         follows from cache/VMEM geometry) are the default.
  Obs 2  Partition only when bandwidth-bound → tiny inputs that fit in
         VMEM/cache skip the blocked machinery.
  Obs 3  SIMD2-P (accumulate-first + partitioning) is the most robust
         multithreaded organization → variant=2 is the distributed default.
  Obs 4  In/out-of-place interacts with structure → exposed as buffer
         donation in the jitted wrappers, not an algorithm change.
  Obs 5  Tree/vertical lose on memory access → never auto-picked; they
         remain available for study and as oracles.
"""

from __future__ import annotations

import dataclasses


# TPU v5e geometry (targets; the container CPU only validates semantics).
VMEM_BYTES = 64 * 1024 * 1024  # per-core VMEM class budget we plan against
VMEM_BLOCK_BUDGET = VMEM_BYTES // 8  # working set ≤ 1/8 VMEM: in+out+slack
L2_HALF_FLOATS = 128 * 1024  # the paper's best CPU partition: ½ L2 in elems


@dataclasses.dataclass(frozen=True)
class Choice:
    algorithm: str  # 'horizontal' | 'blocked' | 'two_pass' | 'kernel'
    block_size: int
    variant: int  # two-pass organization (1 = scan-first, 2 = reduce-first)
    carry_exchange: str  # distributed sums exchange
    reason: str


def choose(
    n: int,
    itemsize: int = 4,
    n_devices: int = 1,
    bandwidth_abundant: bool = False,
    carry_bytes: int = 4,
    kernel_available: bool = True,
) -> Choice:
    """Pick a scan algorithm for ``n`` elements of ``itemsize`` bytes."""
    bytes_total = n * itemsize
    block = max(1024, min(VMEM_BLOCK_BUDGET // max(itemsize, 1), n))

    if bytes_total <= VMEM_BLOCK_BUDGET:
        # Fits in fast memory: one horizontal pass, no partitioning (Obs 2).
        return Choice(
            "horizontal", n, 2, "all_gather",
            "input fits in VMEM; in-register log-step scan only",
        )

    if bandwidth_abundant:
        # The KNL/HBM finding: when bandwidth is abundant, partitioning's
        # overhead is pure cost (Obs 2) — plain two-pass, reduce-first.
        return Choice(
            "two_pass", block, 2, "all_gather",
            "bandwidth abundant: skip partitioning (paper Fig 13)",
        )

    algo = "kernel" if kernel_available else "blocked"
    # Large carries (e.g. SSM matrix states) across many devices favor the
    # log-step permute exchange over all-gather.
    exchange = "all_gather"
    if n_devices > 1 and carry_bytes * n_devices > 1 << 20:
        exchange = "hillis_permute"
    return Choice(
        algo, block, 2, exchange,
        "bandwidth-bound: cache/VMEM partitioning, reduce-first (SIMD2-P)",
    )
