"""Algorithm selection policy — the paper's §5 observations, codified.

The paper closes with five observations about which algorithm to use when.
This module turns them into an executable policy so `repro.core.scan.api`
can pick a sensible default, and so the choice is documented in one place:

  Obs 1  Dilation factors are fragile → we never auto-pick dilated variants;
         equal partitions + partitioning (whose one tunable, the block size,
         follows from cache/VMEM geometry) are the default.
  Obs 2  Partition only when bandwidth-bound → tiny inputs that fit in
         VMEM/cache skip the blocked machinery.
  Obs 3  SIMD2-P (accumulate-first + partitioning) is the most robust
         multithreaded organization → variant=2 is the distributed default.
  Obs 4  In/out-of-place interacts with structure → exposed as buffer
         donation in the jitted wrappers, not an algorithm change.
  Obs 5  Tree/vertical lose on memory access → never auto-picked; they
         remain available for study and as oracles.

Kernel SCHEDULE rule (Obs 2/3 applied to the Pallas grid): the kernel-
backed scans run one of FOUR grid organizations, picked by
``choose_schedule`` (also surfaced as ``Choice.schedule``) and executed
by the monoid-generic engine in ``repro.kernels.scan_engine``:

  'carry'      grid-carried total: ("parallel", "arbitrary") — one fused
               HBM pass (read n + write n), but the sequence axis is a
               sequential carry chain, so parallelism == batch rows. The
               winner whenever ``batch >= cores`` keeps every core busy
               (the paper's SIMD-P single-pass organization).
  'decoupled'  reduce-then-scan in two launches: a fully parallel pass 1b
               emits per-chunk totals only, a tiny exclusive scan combines
               them, and a fully parallel pass 2 redoes the in-chunk scan
               with the chunk offset fused into the writeback — both grids
               are ("parallel", "parallel"), so a LONG row spreads across
               cores at the price of reading the data twice
               (read 2n + write n; the paper's SIMD2-P, Observation 3).
  'fused'      the same reduce-then-scan organization in ONE launch: each
               chunk scans once and chains its prefix to its successor
               through cross-chunk semaphores — decoupled's parallelism
               at the carry chain's traffic (read n + write n). Where the
               native single-launch path cannot run (interpret mode, no
               semaphore API) the engine degrades to the two-launch
               decoupled schedule, bit-identically.
  'tree'       carry's grid with the work-efficient Blelloch sweep as the
               in-tile network (the paper's §3.3 balanced tree): O(b)
               combines per b-element tile instead of the log network's
               O(b log b), at the cost of strided deinterleave/interleave
               passes inside VMEM (Observation 5's memory-access penalty,
               which partitioning confines to fast memory). Same HBM
               traffic as carry (read n + write n).

  The flip: carry-chain when ``batch >= cores`` (enough rows to fill the
  machine; cheapest traffic) — upgraded to the tree network when the tile
  is long (``block_elems >= TREE_BLOCK_ELEMS``), where the in-tile
  combine count dominates and work-efficiency pays; a parallel-sequence
  schedule when a long row would otherwise serialize — ``batch < cores``
  AND the row spans multiple blocks AND there are at least
  ``cores // batch`` chunks to spread. Of the two parallel
  organizations, fused is preferred (it erases decoupled's second read);
  ``prefer_fused=False`` forces the two-launch form. Serve-engine decode
  and SSM prefill (B=1, N ≥ 2^22) land on fused/decoupled; training
  shapes (B ≥ 8) keep the carry chain at default blocks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.obs import trace


# TPU v5e geometry (targets; the container CPU only validates semantics).
VMEM_BYTES = 64 * 1024 * 1024  # per-core VMEM class budget we plan against
VMEM_BLOCK_BUDGET = VMEM_BYTES // 8  # working set ≤ 1/8 VMEM: in+out+slack
L2_HALF_FLOATS = 128 * 1024  # the paper's best CPU partition: ½ L2 in elems

# Cores one kernel launch can spread over (the paper's thread count): the
# v5e chip exposes a handful of Mosaic-parallelizable cores per launch
# class; 8 also matches the paper's CPU thread sweet spot (Fig. 7).
NUM_CORES = 8

# In-tile element count above which the work-efficient tree network pays
# for its strided deinterleave/interleave passes (the paper's Observation
# 5 tradeoff): the Hillis–Steele network does O(b log b) combines per
# tile vs the tree's O(b), so the tree's advantage grows with the block
# length, while its reshuffle overhead is roughly flat per level. Below
# this the lane-parallel log network wins; at the default 2048-element
# blocks the carry schedule keeps the job.
TREE_BLOCK_ELEMS = 8192


@dataclasses.dataclass(frozen=True)
class Choice:
    algorithm: str  # 'horizontal' | 'blocked' | 'two_pass' | 'kernel'
    block_size: int
    variant: int  # two-pass organization (1 = scan-first, 2 = reduce-first)
    carry_exchange: str  # distributed sums exchange
    reason: str
    schedule: str = "carry"  # grid org: 'carry'|'decoupled'|'fused'|'tree'
    # The inputs the choice was made from (the explain surface) — filled
    # by ``choose``; excluded from equality so cached/reconstructed
    # Choices with the same outcome still compare equal.
    inputs: Dict = dataclasses.field(default_factory=dict, compare=False)


@dataclasses.dataclass(frozen=True)
class Decision:
    """A policy decision plus why: the answer to "why did this run
    split-KV?". ``inputs`` echoes every argument the rule consumed."""

    what: str        # which rule decided ('schedule' | 'attention_schedule')
    value: str       # the decision itself
    reason: str      # human-readable rationale
    inputs: Dict = dataclasses.field(default_factory=dict, compare=False)

    def emit(self) -> "Decision":
        """Record the decision as a trace instant event (no-op when
        tracing is disabled) and return self."""
        trace.instant(f"policy.{self.what}", value=self.value,
                      reason=self.reason, **self.inputs)
        return self


def explain_schedule(
    batch: int,
    n: int,
    cores: int = NUM_CORES,
    block_elems: int = 2048,
    prefer_fused: bool = True,
) -> Decision:
    """``choose_schedule`` with its working shown: the decision, the
    branch of the four-way rule that fired, and the inputs — emitted as
    a ``policy.schedule`` trace event."""
    batch = max(int(batch), 1)
    chunks = -(-n // max(block_elems, 1))
    spare = cores // batch  # cores idle under the carry chain
    inputs = dict(batch=batch, n=n, cores=cores, block_elems=block_elems,
                  chunks=chunks, spare=spare, prefer_fused=prefer_fused)
    if batch >= cores:
        if block_elems >= TREE_BLOCK_ELEMS:
            return Decision(
                "schedule", "tree",
                f"batch {batch} >= cores {cores} and block_elems "
                f"{block_elems} >= {TREE_BLOCK_ELEMS}: rows fill every "
                f"core and the tile is long enough that the "
                f"work-efficient tree sweep beats the log network",
                inputs).emit()
        return Decision(
            "schedule", "carry",
            f"batch {batch} >= cores {cores}: rows alone fill every core; "
            f"carry chain has the cheapest HBM traffic", inputs).emit()
    # A parallel-sequence schedule costs extra machinery (a second read,
    # or the semaphore chain); only worth it when the idle cores can
    # actually be fed — at least ``spare`` chunks per row (a row inside
    # one block has nothing to parallelize).
    if spare >= 2 and chunks >= spare:
        value = "fused" if prefer_fused else "decoupled"
        return Decision(
            "schedule", value,
            f"batch {batch} < cores {cores} with {chunks} chunks >= "
            f"{spare} spare cores: spread the row "
            f"({'single-launch fused' if prefer_fused else 'two-launch decoupled'})",
            inputs).emit()
    return Decision(
        "schedule", "carry",
        f"batch {batch} < cores {cores} but only {chunks} chunk(s) for "
        f"{spare} spare core(s): nothing to spread, keep the carry chain",
        inputs).emit()


def choose_schedule(
    batch: int,
    n: int,
    cores: int = NUM_CORES,
    block_elems: int = 2048,
    prefer_fused: bool = True,
) -> str:
    """Kernel grid organization for a (batch, n) scan — see module doc.

    ``block_elems`` must be the chunk length the kernel will actually
    tile with — the chunks-per-spare-core test is meaningless against
    any other block size. ``prefer_fused=False`` picks the two-launch
    decoupled form over the single-launch fused one for parallel-sequence
    shapes (e.g. to sidestep the semaphore path on an unvalidated
    platform; off-TPU the engine falls back by itself).
    ``explain_schedule`` returns the same decision with its rationale.
    """
    return explain_schedule(batch, n, cores, block_elems, prefer_fused).value


# Attention (carried-payload fold) thresholds. SPLIT_KV_CHUNKS is the KV
# chain length past which the fold's serial latency dominates a row's
# cost and the split-KV form pays for its chain traffic — 256 chunks is
# 32k context at the default 128-wide KV block, the serve long-context
# class. SPLIT_KV_ROW_CAP bounds it to decode/scoring shapes (few query
# rows): when (head, q-block) rows already oversubscribe every core by
# this factor, splitting KV buys no throughput and only adds traffic.
SPLIT_KV_CHUNKS = 256
SPLIT_KV_ROW_CAP = 8


def explain_attention_schedule(
    batch_rows: int,
    kv_len: int,
    cores: int = NUM_CORES,
    block_elems: int = 128,
    split_kv_chunks: int = SPLIT_KV_CHUNKS,
    split_kv_row_cap: int = SPLIT_KV_ROW_CAP,
) -> Decision:
    """``choose_attention_schedule`` with its working shown — emitted as
    a ``policy.attention_schedule`` trace event."""
    batch_rows = max(int(batch_rows), 1)
    chunks = -(-kv_len // max(block_elems, 1))
    spare = cores // batch_rows
    inputs = dict(batch_rows=batch_rows, kv_len=kv_len, cores=cores,
                  block_elems=block_elems, chunks=chunks, spare=spare,
                  split_kv_chunks=split_kv_chunks,
                  split_kv_row_cap=split_kv_row_cap)
    if batch_rows < cores and spare >= 2 and chunks >= spare:
        return Decision(
            "attention_schedule", "decoupled",
            f"{batch_rows} fold row(s) leave {spare} cores idle and the "
            f"KV chain has {chunks} chunks to spread: split-KV "
            f"(flash-decoding)", inputs).emit()
    if chunks >= split_kv_chunks and batch_rows < cores * split_kv_row_cap:
        return Decision(
            "attention_schedule", "decoupled",
            f"KV chain of {chunks} chunks >= {split_kv_chunks} dominates "
            f"a row's latency and {batch_rows} rows < "
            f"{cores * split_kv_row_cap} saturation cap: split-KV",
            inputs).emit()
    return Decision(
        "attention_schedule", "carry",
        f"{batch_rows} rows fill the machine (or the {chunks}-chunk KV "
        f"chain is short): classic flash carry accumulate", inputs).emit()


def choose_attention_schedule(
    batch_rows: int,
    kv_len: int,
    cores: int = NUM_CORES,
    block_elems: int = 128,
    split_kv_chunks: int = SPLIT_KV_CHUNKS,
    split_kv_row_cap: int = SPLIT_KV_ROW_CAP,
) -> str:
    """Grid organization for the attention fold (softmax pair + payload).

    Two-way (attention has no fused form — the output is the fold, so
    there is no per-element writeback to chain a prefix into):

      carry      the flash forward: (head, q-block) rows parallel, KV
                 blocks a sequential accumulate. Right whenever the rows
                 fill the machine and the KV chain is short — training
                 and ordinary prefill shapes.
      decoupled  split-KV / flash-decoding: KV chunks parallel, partial
                 (m, l, acc) payloads combined in a tiny second step.
                 Chosen when rows leave cores idle (decode: one q block,
                 ``batch_rows == B·H``), or when the KV chain is long
                 (the 32k/500k-context prefill and padded-cache scoring
                 class) while rows stay within ``SPLIT_KV_ROW_CAP·cores``
                 — fully saturated rows keep the carry form, where
                 splitting adds chain traffic and returns nothing.

    ``batch_rows`` is the number of independent fold chains the carry
    grid already parallelizes (B·H_q·q_blocks); ``block_elems`` the KV
    chunk length actually tiled. ``explain_attention_schedule`` returns
    the same decision with its rationale.
    """
    return explain_attention_schedule(
        batch_rows, kv_len, cores, block_elems, split_kv_chunks,
        split_kv_row_cap).value


def explain_cache_layout(
    max_slots: int,
    max_len: int,
    page_size: int,
    num_pages: "int | None" = None,
    expected_len: "int | None" = None,
) -> Decision:
    """Serve KV-cache layout rule (``contiguous`` | ``paged``) with its
    working shown — emitted as a ``policy.cache_layout`` trace event.

    The contiguous layout reserves ``max_slots · max_len`` K/V slots up
    front; the paged layout (serve/paging.py) reserves ``num_pages ·
    page_size`` and assigns pages on demand, so memory follows ACTUAL
    sequence length. Decide paged when the page budget is below the
    worst case (contiguous could not even allocate the pool) or when the
    expected length leaves most of a contiguous slot dead; otherwise
    the indirection buys nothing and contiguous keeps the simpler
    (gather-free) addressing.
    """
    worst = max_slots * max_len
    budget = worst if num_pages is None else num_pages * page_size
    inputs = dict(max_slots=max_slots, max_len=max_len,
                  page_size=page_size, num_pages=num_pages,
                  expected_len=expected_len, worst_tokens=worst,
                  budget_tokens=budget)
    if budget < worst:
        return Decision(
            "cache_layout", "paged",
            f"page budget {budget} tokens < worst case {worst}: only "
            f"on-demand pages can host {max_slots} slots; admission "
            f"backpressure replaces up-front reservation", inputs).emit()
    if expected_len is not None and 2 * expected_len <= max_len:
        return Decision(
            "cache_layout", "paged",
            f"expected length {expected_len} <= max_len {max_len}/2: a "
            f"contiguous slot would be mostly dead reservation",
            inputs).emit()
    return Decision(
        "cache_layout", "contiguous",
        f"budget {budget} covers the worst case {worst} and lengths run "
        f"near max_len: page indirection buys nothing", inputs).emit()


def choose_cache_layout(
    max_slots: int,
    max_len: int,
    page_size: int,
    num_pages: "int | None" = None,
    expected_len: "int | None" = None,
) -> str:
    """Serve cache layout for ``EngineConfig.cache_layout="auto"`` —
    see ``explain_cache_layout`` for the rule and rationale."""
    return explain_cache_layout(
        max_slots, max_len, page_size, num_pages, expected_len).value


def explain_defrag(
    fragmentation: float,
    free_pages: int,
    longest_free_run: int,
    *,
    threshold: float = 0.5,
) -> Decision:
    """Auto-defrag rule (``defrag`` | ``skip``) with its working shown —
    emitted as a ``policy.defrag`` trace event.

    Driven by the ``serve.pages.fragmentation`` gauge (1 - largest free
    run / free pages) and the free-run length. Page-granular allocation
    never NEEDS contiguity, so this is a locality/observability policy:
    compacting live pages to the front keeps pool writes clustered and
    the gauge honest, and it is free of correctness risk (the gathered
    view is invariant under page renaming). Skip when the pool is full
    (fragmentation pins to 1.0 but compaction cannot create space —
    only request completion can) and when the free space is already one
    healthy extent.
    """
    inputs = dict(fragmentation=round(float(fragmentation), 4),
                  free_pages=int(free_pages),
                  longest_free_run=int(longest_free_run),
                  threshold=threshold)
    if free_pages == 0:
        return Decision(
            "defrag", "skip",
            "no free pages: compaction cannot create space, only "
            "request completion can", inputs).emit()
    if fragmentation < threshold:
        return Decision(
            "defrag", "skip",
            f"fragmentation {fragmentation:.2f} < threshold {threshold}: "
            f"largest free run {longest_free_run}/{free_pages} pages is "
            f"healthy", inputs).emit()
    return Decision(
        "defrag", "defrag",
        f"fragmentation {fragmentation:.2f} >= threshold {threshold}: "
        f"free space shattered into runs <= {longest_free_run} of "
        f"{free_pages} pages — compact live pages to the front",
        inputs).emit()


def choose_defrag(
    fragmentation: float,
    free_pages: int,
    longest_free_run: int,
    *,
    threshold: float = 0.5,
) -> bool:
    """True when the engine tick should run ``Engine.defrag()`` — see
    ``explain_defrag`` for the rule and rationale."""
    return explain_defrag(fragmentation, free_pages, longest_free_run,
                          threshold=threshold).value == "defrag"


def choose(
    n: int,
    itemsize: int = 4,
    n_devices: int = 1,
    bandwidth_abundant: bool = False,
    carry_bytes: int = 4,
    kernel_available: bool = True,
    batch: int = NUM_CORES,
    cores: int = NUM_CORES,
) -> Choice:
    """Pick a scan algorithm for ``n`` elements of ``itemsize`` bytes.

    ``batch`` is the number of independent rows scanned together (defaults
    to "plenty" so shape-oblivious callers keep the carry-chain default);
    it only affects ``Choice.schedule``. Every call emits a
    ``policy.choose`` trace event carrying the inputs and reason.
    """
    bytes_total = n * itemsize
    block = max(1024, min(VMEM_BLOCK_BUDGET // max(itemsize, 1), n))
    schedule = choose_schedule(batch, n, cores)
    inputs = dict(n=n, itemsize=itemsize, n_devices=n_devices,
                  bandwidth_abundant=bandwidth_abundant,
                  carry_bytes=carry_bytes,
                  kernel_available=kernel_available, batch=batch,
                  cores=cores, bytes_total=bytes_total)

    def _emit(choice: Choice) -> Choice:
        Decision("choose", choice.algorithm, choice.reason,
                 dict(inputs, schedule=choice.schedule,
                      block_size=choice.block_size)).emit()
        return choice

    if bytes_total <= VMEM_BLOCK_BUDGET:
        # Fits in fast memory: one horizontal pass, no partitioning (Obs 2).
        return _emit(Choice(
            "horizontal", n, 2, "all_gather",
            "input fits in VMEM; in-register log-step scan only",
            inputs=inputs,
        ))

    if bandwidth_abundant:
        # The KNL/HBM finding: when bandwidth is abundant, partitioning's
        # overhead is pure cost (Obs 2) — plain two-pass, reduce-first.
        return _emit(Choice(
            "two_pass", block, 2, "all_gather",
            "bandwidth abundant: skip partitioning (paper Fig 13)",
            schedule, inputs=inputs,
        ))

    algo = "kernel" if kernel_available else "blocked"
    # Large carries (e.g. SSM matrix states) across many devices favor the
    # log-step permute exchange over all-gather.
    exchange = "all_gather"
    if n_devices > 1 and carry_bytes * n_devices > 1 << 20:
        exchange = "hillis_permute"
    reason = "bandwidth-bound: cache/VMEM partitioning, reduce-first (SIMD2-P)"
    if schedule in ("decoupled", "fused"):
        reason += f"; {schedule} grid (batch < cores, long row)"
    return _emit(Choice(algo, block, 2, exchange, reason, schedule,
                        inputs=inputs))
