"""Horizontal scan — the paper's §3.1, adapted from AVX-512 to TPU vectors.

The CPU version computes an in-register prefix sum of a 16-lane vector with
``log2(16) = 4`` shift+add steps (Listing 1: ``_mm512_alignr_epi32`` +
``_mm512_add_epi32``), then broadcasts the last lane into the running total
for the next vector.

On TPU the analogue of "in register" is "in VREG/VMEM": the Hillis–Steele
log-step network over the scanned axis, where each step combines the array
with a copy of itself shifted by ``2^k``. XLA lowers the shifts to cheap
lane/sublane slices. Work is ``O(n log n)`` combines — *not* work-efficient —
but, exactly as the paper observes (§3.2 end), the extra combines happen in
fast memory and beat "work-efficient" variants that pay memory traffic.

This module is also the building block for in-block scans inside the Pallas
kernels (``repro.kernels.scan_blocked``) where the axis length is the VMEM
tile extent, so ``log`` steps are ~8 cheap vector ops.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scan import assoc

Pytree = Any


def _shift_down(elems: Pytree, ident_full: Pytree, k: int, axis: int) -> Pytree:
    """Shift toward higher indices by ``k``; fill ``[0, k)`` with identity.

    The TPU analogue of the paper's ``_mm512_slli_si512`` (which shifts in
    zeros — the identity of ``+``; we shift in the monoid's identity).
    """

    def f(x, ident):
        head = jax.lax.slice_in_dim(ident, 0, k, axis=axis)
        tail = jax.lax.slice_in_dim(x, 0, x.shape[axis] - k, axis=axis)
        return jnp.concatenate([head, tail], axis=axis)

    return jax.tree.map(f, elems, ident_full)


def scan_horizontal(
    elems: Pytree,
    op: "str | assoc.Monoid" = "sum",
    axis: int = -1,
    exclusive: bool = False,
) -> Pytree:
    """Hillis–Steele log-step inclusive scan along ``axis``.

    ``ceil(log2(n))`` combine steps, each a full-width vector op. For the
    ``sum`` monoid over 16 lanes this is exactly the paper's Listing 1.
    """
    monoid = assoc.get(op)
    leaves = jax.tree.leaves(elems)
    axis = axis % leaves[0].ndim
    n = leaves[0].shape[axis]
    if n == 0:
        # Nothing to combine — and the exclusive shift below would slice
        # [0, 1) out of a length-0 identity.
        return elems

    ident_full = monoid.identity_like(elems)

    out = elems
    k = 1
    while k < n:
        shifted = _shift_down(out, ident_full, k, axis)
        out = monoid.combine(shifted, out)  # shifted = earlier prefix
        k *= 2

    if exclusive:
        out = _shift_down(out, ident_full, 1, axis)
    return out
