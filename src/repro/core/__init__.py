"""The paper's primary contribution: the prefix-scan substrate.

``repro.core.scan`` implements every algorithm in the paper (horizontal /
vertical / tree SIMD, the four two-pass multithreaded organizations, and
cache-friendly partitioning) plus their distributed shard_map forms, over
arbitrary associative monoids. Higher layers (MoE dispatch, SSM blocks,
flash attention, data pipeline) consume this substrate.
"""

from repro.core import scan

__all__ = ["scan"]
