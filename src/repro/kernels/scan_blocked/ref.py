"""Pure-jnp oracle for the blocked-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _accum_dtype(dtype) -> jnp.dtype:
    if dtype in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    if dtype in (jnp.int8, jnp.int16):
        return jnp.int32
    return dtype


def cumsum_ref(
    x: jax.Array, axis: int = -1, exclusive: bool = False
) -> jax.Array:
    """Sequential-semantics prefix sum with widened accumulation."""
    acc = _accum_dtype(x.dtype)
    y = jnp.cumsum(x.astype(acc), axis=axis)
    if exclusive:
        y = y - x.astype(acc)
    return y.astype(x.dtype)
