"""Pure-jnp oracle for the blocked-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import assoc


def _accum_dtype(dtype) -> jnp.dtype:
    # The one shared accumulation policy (see assoc.accum_dtype).
    return assoc.accum_dtype(dtype)


def cumsum_ref(
    x: jax.Array, axis: int = -1, exclusive: bool = False
) -> jax.Array:
    """Sequential-semantics prefix sum with widened accumulation."""
    acc = _accum_dtype(x.dtype)
    y = jnp.cumsum(x.astype(acc), axis=axis)
    if exclusive:
        y = y - x.astype(acc)
    return y.astype(x.dtype)
