"""Pallas TPU kernel: VMEM-blocked prefix sum with a grid-carried total.

This is the paper's §2.2 cache-friendly partitioning, restated for the TPU
memory hierarchy:

  CPU (paper)                          TPU (this kernel)
  ---------------------------------    ------------------------------------
  partition = ½ L2 cache               block = VMEM tile (block_b × block_n)
  pass 1: local prefix sum in cache    in-block two-level scan in VREGs
  pass 2: add carried offset (cache)   fused `+ carry` before the writeback
  barrier + sums[] exchange            sequential grid on one core: the
                                       carry lives in VMEM scratch, so the
                                       "barrier" is structural and free
  2 passes over RAM  →  1 pass         HBM traffic: read n + write n only

The in-block scan is the paper's §3.1 *horizontal SIMD* algorithm at TPU
geometry: a log2(128)-step Hillis–Steele pass along the 128-wide lane axis,
then a log-step scan of the per-row totals along the sublane axis, then a
broadcast add — i.e. "scan the vector in register, broadcast the last lane",
scaled from a 16-lane ZMM register to a (sublanes × 128) VMEM tile.

Grid layout: (batch_blocks, seq_blocks); the sequence axis is innermost so
each core walks its row-block left-to-right carrying the running total, and
`dimension_semantics=("parallel", "arbitrary")` lets Mosaic parallelize
row-blocks across cores (the paper's threads) while keeping the carry chain
sequential (the paper's iteration order).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

LANES = 128


def _log_scan(x: jax.Array, axis: int, exclusive: bool = False) -> jax.Array:
    """Hillis–Steele log-step inclusive scan (in-register; paper §3.1)."""
    n = x.shape[axis]
    k = 1
    while k < n:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (k, 0)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, n)
        x = x + jnp.pad(x, pad)[tuple(sl)]
        k *= 2
    if exclusive:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (1, 0)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, n)
        x = jnp.pad(x, pad)[tuple(sl)]
    return x


def _inblock_scan(x: jax.Array) -> jax.Array:
    """Two-level tile scan: lanes, then sublane row-offsets (paper Fig. 3)."""
    bb, bn = x.shape
    if bn > LANES and bn % LANES == 0:
        r = bn // LANES
        t = x.reshape(bb, r, LANES)
        t = _log_scan(t, axis=2)               # scan within each lane row
        row_tot = t[:, :, LANES - 1]           # (bb, r) row totals
        row_off = _log_scan(row_tot, axis=1, exclusive=True)
        t = t + row_off[:, :, None]            # broadcast add (paper's
        return t.reshape(bb, bn)               # "broadcast last element")
    return _log_scan(x, axis=1)


def _kernel(x_ref, o_ref, carry_ref, *, acc_dtype, exclusive):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        # New row-block: zero the running total (a fresh scan starts).
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(acc_dtype)
    inc = _inblock_scan(x)                     # "pass 1", VMEM-resident
    carry = carry_ref[...]                     # (bb, 1)
    if exclusive:
        shifted = jnp.pad(inc, ((0, 0), (1, 0)))[:, :-1]
        o_ref[...] = (shifted + carry).astype(o_ref.dtype)
    else:
        o_ref[...] = (inc + carry).astype(o_ref.dtype)  # "pass 2", fused
    carry_ref[...] = carry + inc[:, -1:]       # the paper's `sums` update


def _accum_dtype(dtype) -> jnp.dtype:
    if dtype in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    if dtype in (jnp.int8, jnp.int16):
        return jnp.int32
    return dtype


def scan_blocked_kernel(
    x: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    exclusive: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Prefix sum along the last axis of a 2D array (batch, n).

    Caller contract: ``x.shape == (B, N)`` with ``B % block_b == 0`` and
    ``N % block_n == 0`` (the jitted wrapper in ``ops.py`` pads).
    """
    if x.ndim != 2:
        raise ValueError(f"kernel expects 2D input, got {x.shape}")
    B, N = x.shape
    if B % block_b or N % block_n:
        raise ValueError(
            f"shape {x.shape} not divisible by block ({block_b}, {block_n})"
        )
    acc_dtype = _accum_dtype(x.dtype)
    grid = (B // block_b, N // block_n)
    kernel = functools.partial(
        _kernel, acc_dtype=acc_dtype, exclusive=exclusive
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, 1), acc_dtype)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="scan_blocked",
    )(x)
