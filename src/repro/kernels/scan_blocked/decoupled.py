"""Decoupled reduce-then-scan prefix sum: the sequence axis across cores.

The carry-chain kernel (``scan_blocked.py``) makes the sequence grid axis
``"arbitrary"`` — one core walks each row left-to-right. Great when
``B >= cores`` (training shapes), but a single long row (serve decode,
SSM prefill: small B, huge N) runs on ONE core. This module is the
paper's multithreaded SIMD2-P organization (Observation 3) on the Mosaic
grid instead of threads:

  pass 1b  fully parallel grid over (row-block, chunk): each instance
           reads its chunk and emits the chunk TOTAL only (reduce-first —
           read n, write n/block).
  combine  a tiny exclusive scan over the (B, chunks) totals — the
           paper's serial `sums` scan, microscopic next to n. Runs as a
           sequential ``lax.scan`` so the float addition order is
           EXACTLY the carry chain's (bit-identical outputs).
  pass 2   fully parallel grid: redo the in-chunk scan and fuse the
           chunk offset into the writeback (read n, write n).

HBM traffic is read 2n + write n versus the carry chain's read n +
write n — the price of decoupling; ``core/scan/policy.choose_schedule``
only picks this schedule when idle cores repay it.

Both grids are ``("parallel", "parallel")``: no cross-instance state, no
revisiting — Mosaic may run chunks of one row concurrently on every core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params
from repro.kernels.scan_blocked.scan_blocked import (_accum_dtype,
                                                     _inblock_scan)


def _totals_kernel(x_ref, tot_ref, *, acc_dtype):
    """Pass 1b: per-chunk totals via the same in-block scan network.

    Using ``_inblock_scan(...)[:, -1:]`` (not a plain sum) keeps the
    reduction tree identical to the carry kernel's running total, which
    is what makes the two schedules bit-identical in floating point.
    """
    x = x_ref[...].astype(acc_dtype)
    tot_ref[...] = _inblock_scan(x)[:, -1:]


def _scan_kernel(x_ref, off_ref, o_ref, *, acc_dtype, exclusive):
    """Pass 2: in-chunk scan + fused chunk-offset writeback."""
    x = x_ref[...].astype(acc_dtype)
    inc = _inblock_scan(x)
    carry = off_ref[...]  # (bb, 1) exclusive chunk offset
    if exclusive:
        shifted = jnp.pad(inc, ((0, 0), (1, 0)))[:, :-1]
        o_ref[...] = (shifted + carry).astype(o_ref.dtype)
    else:
        o_ref[...] = (inc + carry).astype(o_ref.dtype)


def _exclusive_chain(totals: jax.Array) -> jax.Array:
    """Sequential exclusive scan of (B, chunks) totals along axis 1.

    Left-to-right ``lax.scan`` — the same association order as the
    carry kernel's ``carry += total`` update.
    """

    def step(carry, t):
        return carry + t, carry

    zero = jnp.zeros_like(totals[:, 0])
    _, offs = jax.lax.scan(step, zero, jnp.moveaxis(totals, 1, 0))
    return jnp.moveaxis(offs, 0, 1)


def scan_blocked_decoupled(
    x: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    exclusive: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Decoupled prefix sum along the last axis of a 2D (B, N) array.

    Same caller contract as ``scan_blocked_kernel``: shape divisible by
    the block; results are bit-identical to the carry schedule.
    """
    if x.ndim != 2:
        raise ValueError(f"kernel expects 2D input, got {x.shape}")
    B, N = x.shape
    if B % block_b or N % block_n:
        raise ValueError(
            f"shape {x.shape} not divisible by block ({block_b}, {block_n})"
        )
    acc_dtype = _accum_dtype(x.dtype)
    chunks = N // block_n
    grid = (B // block_b, chunks)
    xspec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))
    tspec = pl.BlockSpec((block_b, 1), lambda i, j: (i, j))

    totals = pl.pallas_call(
        functools.partial(_totals_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[xspec],
        out_specs=tspec,
        out_shape=jax.ShapeDtypeStruct((B, chunks), acc_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="scan_blocked_totals",
    )(x)

    offsets = _exclusive_chain(totals)

    return pl.pallas_call(
        functools.partial(
            _scan_kernel, acc_dtype=acc_dtype, exclusive=exclusive
        ),
        grid=grid,
        in_specs=[xspec, tspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="scan_blocked_apply",
    )(x, offsets)
