"""Jitted public wrapper for the blocked-scan Pallas kernel.

Handles arbitrary ranks/axes, padding to block multiples, dtype policy and
interpret-mode fallback on CPU. ``in_place=True`` donates the input buffer —
the paper's in-place variant (§4.2.3) expressed as XLA buffer donation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scan_blocked.scan_blocked import scan_blocked_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("axis", "exclusive", "block_b", "block_n", "interpret"),
)
def _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret):
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    n = x.shape[-1]
    b = 1
    for d in lead:
        b *= d
    x2 = x.reshape(b, n)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    pad_b = (-b) % bb
    bn = min(block_n, _round_up(n, 128))
    pad_n = (-n) % bn
    x2 = jnp.pad(x2, ((0, pad_b), (0, pad_n)))

    out = scan_blocked_kernel(
        x2, block_b=bb, block_n=bn, exclusive=exclusive, interpret=interpret
    )
    out = out[:b, :n].reshape(lead + (n,))
    return jnp.moveaxis(out, -1, axis)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def cumsum(
    x: jax.Array,
    axis: int = -1,
    exclusive: bool = False,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Kernel-backed prefix sum along ``axis`` (any rank).

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = not _on_tpu()
    return _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret)
