"""Blocked prefix sum: the SUM registration of the Pallas scan engine.

This family is nothing but the sum monoid run through the monoid-generic
engine (``repro.kernels.scan_engine``) on the Rows layout — the hand
rolled carry/decoupled kernel bodies that used to live here are the
engine's schedules now, written once for every monoid.

The public wrapper handles arbitrary ranks/axes, padding to block
multiples, dtype policy and interpret-mode fallback on CPU.
``in_place=True`` donates the input buffer — the paper's in-place variant
(§4.2.3) expressed as XLA buffer donation.

Four grid schedules (see ``core/scan/policy`` module doc):
  * ``schedule="carry"``     — grid-carried total, sequence sequential;
  * ``schedule="decoupled"`` — reduce-then-scan, two launches;
  * ``schedule="fused"``     — reduce-then-scan, single launch chained
    through cross-chunk semaphores (two-launch fallback off-TPU);
  * ``schedule="tree"``      — carry's grid, work-efficient Blelloch
    sweep inside each tile (§3.3);
  * ``schedule="auto"``      — the policy's batch-vs-cores rule decides.

``cumsum`` is differentiable via a ``jax.custom_vjp`` whose backward is
ITSELF an engine scan — the adjoint of a prefix sum is a suffix sum, so
the gradient runs the same kernel on the flipped cotangent (one more
``kernel.launch`` with the same schedule), never falling back to
differentiate-through-the-network.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import scan_engine
from repro.kernels.scan_engine import monoids
from repro.kernels.scan_engine import resolve_schedule  # back-compat export

SCHEDULES = scan_engine.RESOLVABLE


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("axis", "exclusive", "block_b", "block_n", "interpret",
                     "schedule"),
)
def _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret, schedule):
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    n = x.shape[-1]
    b = 1
    for d in lead:
        b *= d
    x2 = x.reshape(b, n)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    pad_b = (-b) % bb
    bn = min(block_n, _round_up(n, 128))
    pad_n = (-n) % bn
    x2 = jnp.pad(x2, ((0, pad_b), (0, pad_n)))

    layout = scan_engine.Rows(x2.shape[0], x2.shape[1], bb, bn)
    out, = scan_engine.scan(
        (x2,), monoids.SUM, layout, schedule=schedule, exclusive=exclusive,
        interpret=interpret)
    out = out[:b, :n].reshape(lead + (n,))
    return jnp.moveaxis(out, -1, axis)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


# Gradient-as-a-scan: d(prefix sum)/dx is a SUFFIX sum of the cotangent
# with the same exclusivity — flip, run the identical engine kernel,
# flip back. All the static knobs ride as nondiff args so the backward
# reuses the forward's jitted ``_cumsum_impl`` (and therefore emits its
# own ``kernel.launch`` trace event when compiled).
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _cumsum_vjp(x, axis, exclusive, block_b, block_n, interpret, schedule):
    return _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret,
                        schedule)


def _cumsum_fwd(x, axis, exclusive, block_b, block_n, interpret, schedule):
    out = _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret,
                       schedule)
    return out, None


def _cumsum_bwd(axis, exclusive, block_b, block_n, interpret, schedule,
                _residual, g):
    # Inclusive: dx_j = Σ_{i>=j} g_i; exclusive: dx_j = Σ_{i>j} g_i —
    # both are the same-flavor prefix sum of the reversed cotangent.
    rev = _cumsum_impl(jnp.flip(g, axis), axis, exclusive, block_b,
                       block_n, interpret, schedule)
    return (jnp.flip(rev, axis),)


_cumsum_vjp.defvjp(_cumsum_fwd, _cumsum_bwd)


def cumsum(
    x: jax.Array,
    axis: int = -1,
    exclusive: bool = False,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> jax.Array:
    """Kernel-backed prefix sum along ``axis`` (any rank).

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere.
    ``schedule`` picks the grid organization
    (carry|decoupled|fused|tree|auto). Differentiable: the custom VJP
    runs the backward as another engine scan (see module doc).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if x.size == 0:
        # Zero-length scan axis (or an empty batch): the scan of nothing
        # is nothing — and the padding arithmetic below would divide by
        # a zero block.
        return x
    n = x.shape[axis]
    batch = max(x.size // max(n, 1), 1)
    bn = min(block_n, _round_up(n, 128))  # the block _cumsum_impl uses
    schedule = resolve_schedule(schedule, batch, n, bn)
    return _cumsum_vjp(x, axis, exclusive, block_b, block_n, interpret,
                       schedule)


# ---------------------------------------------------------------------------
# Back-compat kernel entry points (PR-1 signatures; 2D, pre-padded)
# ---------------------------------------------------------------------------


def _scan_2d(x, block_b, block_n, exclusive, interpret, schedule):
    if x.ndim != 2:
        raise ValueError(f"kernel expects 2D input, got {x.shape}")
    layout = scan_engine.Rows(x.shape[0], x.shape[1], block_b, block_n)
    out, = scan_engine.scan(
        (x,), monoids.SUM, layout, schedule=schedule, exclusive=exclusive,
        interpret=interpret)
    return out


def scan_blocked_kernel(x, *, block_b=8, block_n=2048, exclusive=False,
                        interpret=False):
    """Carry-schedule prefix sum of a pre-padded 2D (B, N) array."""
    return _scan_2d(x, block_b, block_n, exclusive, interpret, "carry")


def scan_blocked_decoupled(x, *, block_b=8, block_n=2048, exclusive=False,
                           interpret=False):
    """Decoupled-schedule prefix sum of a pre-padded 2D (B, N) array."""
    return _scan_2d(x, block_b, block_n, exclusive, interpret, "decoupled")
