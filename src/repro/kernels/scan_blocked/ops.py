"""Jitted public wrapper for the blocked-scan Pallas kernels.

Handles arbitrary ranks/axes, padding to block multiples, dtype policy and
interpret-mode fallback on CPU. ``in_place=True`` donates the input buffer —
the paper's in-place variant (§4.2.3) expressed as XLA buffer donation.

Two grid schedules (see ``core/scan/policy`` module doc):
  * ``schedule="carry"``     — grid-carried total, sequence sequential;
  * ``schedule="decoupled"`` — reduce-then-scan, sequence parallel;
  * ``schedule="auto"``      — the policy's batch-vs-cores rule decides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scan import policy
from repro.kernels.scan_blocked.decoupled import scan_blocked_decoupled
from repro.kernels.scan_blocked.scan_blocked import scan_blocked_kernel

SCHEDULES = ("carry", "decoupled", "auto")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_schedule(schedule: str, batch: int, n: int,
                     block_elems: int) -> str:
    """'auto' -> the policy's batch-vs-cores rule; else validate.

    ``block_elems`` is the chunk length the kernel will ACTUALLY tile
    the scanned axis with — the policy's chunks-per-core test is only
    meaningful against the real grid.
    """
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; one of {SCHEDULES}")
    if schedule == "auto":
        return policy.choose_schedule(batch, n, block_elems=block_elems)
    return schedule


@functools.partial(
    jax.jit,
    static_argnames=("axis", "exclusive", "block_b", "block_n", "interpret",
                     "schedule"),
)
def _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret, schedule):
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    n = x.shape[-1]
    b = 1
    for d in lead:
        b *= d
    x2 = x.reshape(b, n)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    pad_b = (-b) % bb
    bn = min(block_n, _round_up(n, 128))
    pad_n = (-n) % bn
    x2 = jnp.pad(x2, ((0, pad_b), (0, pad_n)))

    kernel = (scan_blocked_decoupled if schedule == "decoupled"
              else scan_blocked_kernel)
    out = kernel(
        x2, block_b=bb, block_n=bn, exclusive=exclusive, interpret=interpret
    )
    out = out[:b, :n].reshape(lead + (n,))
    return jnp.moveaxis(out, -1, axis)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def cumsum(
    x: jax.Array,
    axis: int = -1,
    exclusive: bool = False,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> jax.Array:
    """Kernel-backed prefix sum along ``axis`` (any rank).

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere.
    ``schedule`` picks the grid organization (carry | decoupled | auto).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n = x.shape[axis]
    batch = max(x.size // max(n, 1), 1)
    bn = min(block_n, _round_up(n, 128))  # the block _cumsum_impl uses
    schedule = resolve_schedule(schedule, batch, n, bn)
    return _cumsum_impl(x, axis, exclusive, block_b, block_n, interpret,
                        schedule)
