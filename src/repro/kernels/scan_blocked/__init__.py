from repro.kernels.scan_blocked.decoupled import scan_blocked_decoupled
from repro.kernels.scan_blocked.ops import cumsum
from repro.kernels.scan_blocked.ref import cumsum_ref
from repro.kernels.scan_blocked.scan_blocked import scan_blocked_kernel

__all__ = ["cumsum", "cumsum_ref", "scan_blocked_decoupled",
           "scan_blocked_kernel"]
