from repro.kernels.scan_blocked.ops import (cumsum, scan_blocked_decoupled,
                                            scan_blocked_kernel)
from repro.kernels.scan_blocked.ref import cumsum_ref

__all__ = ["cumsum", "cumsum_ref", "scan_blocked_decoupled",
           "scan_blocked_kernel"]
