"""Grid/tile layouts the monoid-generic schedules are written against.

A layout answers every SHAPE question a schedule has — grid geometry,
block specs, carry/chunk-total shapes, how to read a tile out of a ref —
so the schedule bodies in ``schedules.py`` contain no per-family
geometry. Three layouts cover the five kernel families:

  Rows      (R, N) leaves scanned along the last axis in (bb, bn) VMEM
            tiles; rows are the paper's threads. Used by the sum,
            segmented and compact-mask registrations.
  Channels  (B, T, D) leaves scanned along the TIME axis in (1, bt, bd)
            tiles; channels ride the 128-lane axis as independent lanes
            (the paper's §3.2 vertical SIMD — natural on TPU, not a
            gather penalty). Used by the affine/SSM registration.
  KVBlocks  attention geometry for carried-payload (transform) monoids:
            q (BH, Tq, d) against k/v (BHkv, Tk, d), folded along KV
            blocks. Operands have DIFFERENT index maps (GQA maps q head
            ``h`` to kv head ``h // group`` — free addressing, paper
            Obs. 5), monoid leaves are per-q-block payload carries with
            per-leaf trailing dims (``leaf_dims``), and outputs are the
            fold. Used by the flash-attention registration (forward and
            the backward dq fold).
  QBlocks   the TRANSPOSED attention fold for the backward dk/dv: one
            grid row per (kv head, KV block), folded along the
            (group × q-block) axis — every q head addressing this KV
            head is part of the fold, so GQA head summation is the fold
            itself.

Attention layouts optionally carry ``kv_bounds`` — the per-q-block KV
extent (causal, window, kv_len): fold schedules skip grid cells whose
mask is provably all-dead. With the zeroed-probability convention
(``assoc.softmax_pair_kernel_spec``) a skipped cell's element is the
monoid identity, so the bound is bitwise-invisible while causal prefill
runs ~half the cells.

All layouts put the scanned axis LAST in the grid, expose ``chunk``
axis 1 in their chunk-total arrays, and keep the scan axis at size 1 in
carry slices so monoid ``combine`` broadcasts carries against tiles.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _check_divisible(shape, block, what):
    for s, b in zip(shape, block):
        if s % b:
            raise ValueError(
                f"{what} shape {shape} not divisible by block {block}")


class _UniformLeaves:
    """Shared per-leaf plumbing for layouts whose monoid leaves all share
    the data tile geometry (Rows, Channels). The schedules only speak the
    per-leaf/per-operand dialect so carried-payload layouts (KVBlocks)
    can differ; uniform layouts delegate to their single spec."""

    def op_specs(self, n_ops):
        return [self.data_spec()] * n_ops

    def out_spec(self):
        return self.data_spec()

    def out_spec_for(self, i):
        return self.out_spec()

    def out_shape_for(self, i):
        return self.shape

    def chain_spec_for(self, leaf):
        return self.chain_spec()

    def chain_shape_for(self, leaf):
        return self.chain_shape


@dataclasses.dataclass(frozen=True)
class Rows(_UniformLeaves):
    """2D (rows, n) leaves, scan along axis 1, blocks (bb, bn)."""

    rows: int
    n: int
    bb: int
    bn: int

    def __post_init__(self):
        _check_divisible((self.rows, self.n), (self.bb, self.bn), "Rows")

    # -- grid geometry --------------------------------------------------
    @property
    def shape(self):
        return (self.rows, self.n)

    @property
    def grid(self):
        return (self.rows // self.bb, self.n // self.bn)

    @property
    def num_seq_blocks(self):
        return self.n // self.bn

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    scan_axis = 1  # within the (bb, bn) tile

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    # -- block specs ----------------------------------------------------
    def data_spec(self):
        return pl.BlockSpec((self.bb, self.bn), lambda i, j: (i, j))

    def chain_spec(self):
        return pl.BlockSpec((self.bb, 1), lambda i, j: (i, j))

    @property
    def chain_shape(self):
        return (self.rows, self.num_seq_blocks)

    @property
    def chain_block(self):
        return (self.bb, 1)

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((self.bb, 1), dtype)

    # -- in-kernel views ------------------------------------------------
    def read(self, ref):
        return ref[...]

    def write(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_carry(self, ref):
        return ref[...]

    def write_carry(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_chain(self, ref):
        return ref[...]

    def write_chain(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def take_last(self, x):
        return x[:, -1:]

    # -- fused-schedule addressing (whole-array HBM refs) ---------------
    def chain_at(self, ref, seq_index):
        """Slice one chunk column of the (rows, chunks) chain buffer for
        this instance's row block."""
        i = pl.program_id(0)
        return ref.at[pl.ds(i * self.bb, self.bb), pl.ds(seq_index, 1)]

    def sem_at(self, sem, seq_index):
        return sem.at[pl.program_id(0), seq_index]


@dataclasses.dataclass(frozen=True)
class Channels(_UniformLeaves):
    """3D (B, T, D) leaves, scan along axis 1 (time), blocks (1, bt, bd).

    In-kernel tiles are (bt, bd) with time on the SUBLANE axis and
    channels on lanes; carries are (1, bd) — one state per channel lane.
    """

    b: int
    t: int
    d: int
    bt: int
    bd: int

    def __post_init__(self):
        _check_divisible((self.t, self.d), (self.bt, self.bd), "Channels")

    @property
    def shape(self):
        return (self.b, self.t, self.d)

    @property
    def grid(self):
        return (self.b, self.d // self.bd, self.t // self.bt)

    @property
    def num_seq_blocks(self):
        return self.t // self.bt

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    scan_axis = 0  # within the (bt, bd) tile

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    def data_spec(self):
        return pl.BlockSpec((1, self.bt, self.bd), lambda i, d, t: (i, t, d))

    def chain_spec(self):
        return pl.BlockSpec((1, 1, self.bd), lambda i, d, t: (i, t, d))

    @property
    def chain_shape(self):
        return (self.b, self.num_seq_blocks, self.d)

    @property
    def chain_block(self):
        return (1, 1, self.bd)

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((1, self.bd), dtype)

    def read(self, ref):
        return ref[0]  # (bt, bd)

    def write(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def read_carry(self, ref):
        return ref[...]  # (1, bd): broadcasts over the (bt, bd) tile

    def write_carry(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_chain(self, ref):
        return ref[0]  # (1, bd)

    def write_chain(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def take_last(self, x):
        return x[-1:, :]

    def chain_at(self, ref, seq_index):
        i, d = pl.program_id(0), pl.program_id(1)
        return ref.at[pl.ds(i, 1), pl.ds(seq_index, 1),
                      pl.ds(d * self.bd, self.bd)]

    def sem_at(self, sem, seq_index):
        return sem.at[pl.program_id(0), pl.program_id(1), seq_index]


def _block_map_lookup(table):
    """Scalar lookup ``j -> table[j]`` usable inside a Pallas index map.

    Index maps may not capture ARRAY constants, so the table is encoded
    arithmetically over python-int literals (a one-hot dot product on
    the traced block id). O(len(table)) scalar ops per grid cell — cheap
    at block granularity; a scalar-prefetch table
    (``PrefetchScalarGridSpec``) is the TPU-native upgrade path.
    """
    table = tuple(int(x) for x in table)

    def look(j):
        out = jnp.int32(0)
        for idx, phys in enumerate(table):
            out = out + jnp.int32(phys) * (j == idx).astype(jnp.int32)
        return out

    return look


def block_live(qi, kj, *, bq, bk, causal, window, kv_len):
    """Whether the (q-block ``qi``, kv-block ``kj``) mask has ANY live
    entry — the per-q-block KV extent in predicate form.

    Conservative in the safe direction: a False is a proof that every
    (row, col) pair in the cell is masked (each conjunct is a necessary
    condition for liveness over the block's row/col ranges), so skipping
    the cell is exact; a rare True on a fully-masked cell merely folds
    in the monoid identity. Works on python ints (analytic cell counts)
    and traced program ids (in-kernel skip) alike.
    """
    live = True
    if kv_len is not None:
        live = kj * bk < kv_len
    if causal:
        live = live & (kj * bk <= (qi + 1) * bq - 1)
    if window is not None:
        live = live & ((kj + 1) * bk - 1 > qi * bq - window)
    return live


def _active_cell_count(nq, nk, *, bq, bk, bounds):
    causal, window, kv_len = bounds
    return sum(
        bool(block_live(qi, kj, bq=bq, bk=bk, causal=causal,
                        window=window, kv_len=kv_len))
        for qi in range(nq) for kj in range(nk))


@dataclasses.dataclass(frozen=True)
class _AttnFold:
    """Shared plumbing for the attention fold layouts (KVBlocks/QBlocks).

    Both transposes share the field set, operand addressing kinds,
    split-grid derivation, and the KV-extent liveness wiring; concrete
    classes supply only the grid orientation — which axis is the fold,
    the per-operand/output index maps, and the chain/carry geometry.

    ``op_kinds`` names each operand's addressing — ``"q"`` (q-major
    (bh, tq, d) tiles), ``"kv"`` (kv-major (bh_kv, tk, d) tiles with the
    GQA ``h // group`` association), ``"qstat"`` (q-major per-row
    statistics, trailing dim 1) — so the backward folds can feed
    ``(q, k, v, do, m, l, delta)`` through the same layouts.
    ``out_dims`` gives per-output trailing dims (stats outputs are
    dim-1); ``kv_bounds = (causal, window, kv_len)`` enables the
    per-q-block KV extent (``fold_active``).
    """

    bh: int              # flattened B·H_q query rows
    bh_kv: int           # flattened B·H_kv rows; bh == bh_kv * group
    tq: int
    tk: int
    d: int
    bq: int
    bk: int
    group: int = 1
    splits: int = 1      # fold-axis chunks for the decoupled schedule
    leaf_dims: "tuple | None" = None   # per-leaf trailing dims
    op_kinds: tuple = ("q", "kv", "kv")
    out_dims: "tuple | None" = None    # per-output trailing dims; all d
    kv_bounds: "tuple | None" = None   # (causal, window, kv_len) extent
    # Page indirection (serve/paging.py): logical KV block j reads
    # physical block kv_block_map[j] — block-granular gather folded into
    # the operand INDEX MAPS, so a paged pool feeds the fold with no
    # materialized contiguous copy. None = identity addressing.
    kv_block_map: "tuple | None" = None

    def __post_init__(self):
        name = type(self).__name__
        _check_divisible((self.tq, self.tk), (self.bq, self.bk), name)
        if self.bh != self.bh_kv * self.group:
            raise ValueError(
                f"bh={self.bh} != bh_kv={self.bh_kv} * group={self.group}")
        if self.kv_block_map is not None and len(self.kv_block_map) != self.nk:
            raise ValueError(
                f"kv_block_map has {len(self.kv_block_map)} entries for "
                f"{self.nk} logical KV blocks")
        if self.num_seq_blocks % self.splits:
            raise ValueError(
                f"splits={self.splits} must divide {self.num_seq_blocks} "
                f"{name} fold blocks")
        bad = set(self.op_kinds) - {"q", "kv", "qstat"}
        if bad:
            raise ValueError(f"unknown op kinds {sorted(bad)}")

    # -- geometry --------------------------------------------------------
    @property
    def nq(self):
        return self.tq // self.bq

    @property
    def nk(self):
        return self.tk // self.bk

    @property
    def blocks_per_chunk(self):
        return self.num_seq_blocks // self.splits

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    @property
    def split_grid(self):
        return self.grid[:-1] + (self.splits, self.blocks_per_chunk)

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    def split_semantics(self):
        # chunks parallel, sub-blocks within a chunk sequential
        return ("parallel",) * 3 + ("arbitrary",)

    def out_dim(self, i: int) -> int:
        return self.d if self.out_dims is None else self.out_dims[i]

    # -- block specs -----------------------------------------------------
    def _check_ops(self, n_ops):
        if n_ops != len(self.op_kinds):
            raise ValueError(
                f"{type(self).__name__} expects {len(self.op_kinds)} "
                f"operands ({self.op_kinds}), got {n_ops}")

    def op_specs(self, n_ops):
        self._check_ops(n_ops)
        return [self._op_spec(kind, split=False) for kind in self.op_kinds]

    def split_op_specs(self, n_ops):
        self._check_ops(n_ops)
        return [self._op_spec(kind, split=True) for kind in self.op_kinds]

    # -- causal-aware KV extent ------------------------------------------
    def fold_active(self, ids):
        """Liveness of the grid cell at semantic ids ``(h, qi, kj)`` —
        ``None`` when no bounds are configured (always run)."""
        if self.kv_bounds is None:
            return None
        causal, window, kv_len = self.kv_bounds
        if not causal and window is None and kv_len is None:
            # No live constraint: block_live would fold to the python
            # constant True, which the schedules' pl.when/counter can't
            # consume — report "no bound" instead.
            return None
        _, qi, kj = ids
        return block_live(qi, kj, bq=self.bq, bk=self.bk, causal=causal,
                          window=window, kv_len=kv_len)

    def _live_plane_cells(self) -> int:
        """Live cells of the (q-block, kv-block) plane under bounds."""
        if self.kv_bounds is None:
            return self.nq * self.nk
        return _active_cell_count(self.nq, self.nk, bq=self.bq,
                                  bk=self.bk, bounds=self.kv_bounds)

    # -- in-kernel views -------------------------------------------------
    def read_op(self, ref):
        return ref[0]

    def write(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def write_chain(self, ref, val):
        ref[0, 0] = val.astype(ref.dtype)


@dataclasses.dataclass(frozen=True)
class KVBlocks(_AttnFold):
    """Attention fold geometry for carried-payload (transform) monoids.

    q ``(bh, tq, d)`` attends k/v ``(bh_kv, tk, d)``; the scanned axis is
    the KV-block axis and the monoid leaves are per-q-block PAYLOAD
    carries — ``(bq, leaf_dims[i])`` tiles (flash attention: the
    ``(m, l)`` pair at dim 1 plus the weighted-value accumulator at dim
    ``d``) — so carries, chain buffers and scratch are per-leaf shaped,
    unlike the uniform-leaf layouts above.

    Two grids serve the two fold schedules:

      carry      ``(bh, nq, nk)``, KV axis sequential ("arbitrary"):
                 the single-pass accumulate — q·kᵀ folded into the VMEM
                 payload carry block by block, output written once at
                 the last KV block.
      decoupled  ``(bh, nq, splits, nk/splits)``: the split-KV /
                 flash-decoding organization. KV chunks are fully
                 parallel; WITHIN a chunk the sub-block axis is the same
                 sequential accumulate, publishing one payload triple
                 per chunk to the chain buffers; a tiny jnp combine
                 chain + finalize stitches chunks back together.

    ``group`` maps q head ``h`` to kv head ``h // group`` in the k/v
    index maps (GQA as free addressing, paper Obs. 5). Used by the
    flash forward AND the backward dq fold (see ``_AttnFold`` for the
    operand-kind / out-dims / KV-bounds machinery).
    """

    @property
    def shape(self):
        return (self.bh, self.tq, self.d)

    @property
    def num_seq_blocks(self):
        return self.nk          # the fold walks KV blocks

    @property
    def grid(self):
        return (self.bh, self.nq, self.nk)

    def leaf_dim(self, leaf: int) -> int:
        dims = self.leaf_dims if self.leaf_dims is not None \
            else (1, 1, self.d)
        return dims[leaf]

    def _op_spec(self, kind, split: bool):
        g, bpc = self.group, self.blocks_per_chunk
        if kind == "q" or kind == "qstat":
            dim = self.d if kind == "q" else 1
            if split:
                return pl.BlockSpec((1, self.bq, dim),
                                    lambda h, i, c, s: (h, i, 0))
            return pl.BlockSpec((1, self.bq, dim),
                                lambda h, i, j: (h, i, 0))
        if self.kv_block_map is not None:
            # Paged addressing: the logical fold position routes through
            # the block map; the grid walk (and with it kv_bounds /
            # fold_active, keyed on LOGICAL ids) is unchanged.
            m = _block_map_lookup(self.kv_block_map)
            if split:
                return pl.BlockSpec((1, self.bk, self.d),
                                    lambda h, i, c, s, g=g, bpc=bpc, m=m:
                                    (h // g, m(c * bpc + s), 0))
            return pl.BlockSpec((1, self.bk, self.d),
                                lambda h, i, j, g=g, m=m: (h // g, m(j), 0))
        if split:
            return pl.BlockSpec((1, self.bk, self.d),
                                lambda h, i, c, s, g=g, bpc=bpc:
                                (h // g, c * bpc + s, 0))
        return pl.BlockSpec((1, self.bk, self.d),
                            lambda h, i, j, g=g: (h // g, j, 0))

    def out_spec_for(self, i: int):
        # independent of the KV axis: the block persists in VMEM across
        # the sequential axis and is written once, at the last KV block
        dim = self.out_dim(i)
        return pl.BlockSpec((1, self.bq, dim), lambda h, qi, j: (h, qi, 0))

    def out_shape_for(self, i: int):
        return (self.bh, self.tq, self.out_dim(i))

    def chain_shape_for(self, leaf: int):
        return (self.bh * self.nq, self.splits, self.bq,
                self.leaf_dim(leaf))

    def split_chain_spec_for(self, leaf: int):
        nq = self.nq
        return pl.BlockSpec(
            (1, 1, self.bq, self.leaf_dim(leaf)),
            lambda h, i, c, s, nq=nq: (h * nq + i, c, 0, 0))

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((self.bq, self.leaf_dim(leaf)), dtype)

    def active_cells(self) -> int:
        """Analytic count of live grid cells under ``kv_bounds`` (full
        grid when bounds are off) — per flattened head row."""
        return self._live_plane_cells()

    # -- cell-count instrumentation (carry fold) -------------------------
    @property
    def count_shape(self):
        return (self.bh, self.nq)

    def count_spec(self):
        return pl.BlockSpec((1, 1), lambda h, qi, j: (h, qi))

    # -- in-kernel views -------------------------------------------------
    def block_ids(self):
        return (pl.program_id(0), pl.program_id(1), pl.program_id(2))

    def split_block_ids(self):
        bpc = self.blocks_per_chunk
        return (pl.program_id(0), pl.program_id(1),
                pl.program_id(2) * bpc + pl.program_id(3))

    def unchain_out(self, x):
        """(bh·nq, bq, dim) fold/finalize result -> (bh, tq, dim)."""
        return x.reshape(self.bh, self.tq, x.shape[-1])


@dataclasses.dataclass(frozen=True)
class QBlocks(_AttnFold):
    """Transposed attention fold geometry: the backward dk/dv layout.

    One grid row per (kv head, KV block); the scanned axis walks the
    (group × q-block) product — every q head that addresses this KV head
    under GQA plus every q block, so the head summation IS the fold.
    Monoid leaves are per-KV-block accumulators of shape
    ``(bk, leaf_dims[i])`` (flash backward: the dk and dv tiles), and
    outputs land kv-major at ``(bh_kv, tk, out_dim)``.

    Operand addressing mirrors :class:`KVBlocks` with the roles
    transposed: kv-kind operands ride the grid row, q-kind operands are
    indexed from the fold position ``f`` as
    ``(h_kv·group + f // nq, f % nq)``. ``kv_bounds`` applies the same
    per-(q-block, kv-block) liveness predicate — for a causal grid the
    fold skips the q blocks above the diagonal.
    """

    op_kinds: tuple = ("q", "kv", "kv", "q", "qstat", "qstat", "qstat")

    @property
    def num_seq_blocks(self):
        return self.group * self.nq    # the fold walks (group, q) blocks

    @property
    def grid(self):
        return (self.bh_kv, self.nk, self.num_seq_blocks)

    def leaf_dim(self, leaf: int) -> int:
        return self.d if self.leaf_dims is None else self.leaf_dims[leaf]

    def _op_spec(self, kind, split: bool):
        g, nq, bpc = self.group, self.nq, self.blocks_per_chunk
        if kind == "q" or kind == "qstat":
            dim = self.d if kind == "q" else 1
            if split:
                return pl.BlockSpec(
                    (1, self.bq, dim),
                    lambda h, j, c, s, g=g, nq=nq, bpc=bpc:
                    (h * g + (c * bpc + s) // nq, (c * bpc + s) % nq, 0))
            return pl.BlockSpec(
                (1, self.bq, dim),
                lambda h, j, f, g=g, nq=nq: (h * g + f // nq, f % nq, 0))
        if split:
            return pl.BlockSpec((1, self.bk, self.d),
                                lambda h, j, c, s: (h, j, 0))
        return pl.BlockSpec((1, self.bk, self.d),
                            lambda h, j, f: (h, j, 0))

    def out_spec_for(self, i: int):
        # independent of the fold axis: persists in VMEM, written once
        dim = self.out_dim(i)
        return pl.BlockSpec((1, self.bk, dim), lambda h, j, f: (h, j, 0))

    def out_shape_for(self, i: int):
        return (self.bh_kv, self.tk, self.out_dim(i))

    def chain_shape_for(self, leaf: int):
        return (self.bh_kv * self.nk, self.splits, self.bk,
                self.leaf_dim(leaf))

    def split_chain_spec_for(self, leaf: int):
        nk = self.nk
        return pl.BlockSpec(
            (1, 1, self.bk, self.leaf_dim(leaf)),
            lambda h, j, c, s, nk=nk: (h * nk + j, c, 0, 0))

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((self.bk, self.leaf_dim(leaf)), dtype)

    def active_cells(self) -> int:
        """Live fold cells per flattened kv-head row (every q head of
        the group walks the same (qi, kj) liveness plane)."""
        return self.group * self._live_plane_cells()

    # -- cell-count instrumentation (carry fold) -------------------------
    @property
    def count_shape(self):
        return (self.bh_kv, self.nk)

    def count_spec(self):
        return pl.BlockSpec((1, 1), lambda h, j, f: (h, j))

    # -- in-kernel views -------------------------------------------------
    def block_ids(self):
        h, j, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        return (h * self.group + f // self.nq, f % self.nq, j)

    def split_block_ids(self):
        h, j = pl.program_id(0), pl.program_id(1)
        f = pl.program_id(2) * self.blocks_per_chunk + pl.program_id(3)
        return (h * self.group + f // self.nq, f % self.nq, j)

    def unchain_out(self, x):
        """(bh_kv·nk, bk, dim) fold/finalize result -> (bh_kv, tk, dim)."""
        return x.reshape(self.bh_kv, self.tk, x.shape[-1])
