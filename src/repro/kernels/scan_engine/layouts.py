"""Grid/tile layouts the monoid-generic schedules are written against.

A layout answers every SHAPE question a schedule has — grid geometry,
block specs, carry/chunk-total shapes, how to read a tile out of a ref —
so the schedule bodies in ``schedules.py`` contain no per-family
geometry. Three layouts cover the five kernel families:

  Rows      (R, N) leaves scanned along the last axis in (bb, bn) VMEM
            tiles; rows are the paper's threads. Used by the sum,
            segmented and compact-mask registrations.
  Channels  (B, T, D) leaves scanned along the TIME axis in (1, bt, bd)
            tiles; channels ride the 128-lane axis as independent lanes
            (the paper's §3.2 vertical SIMD — natural on TPU, not a
            gather penalty). Used by the affine/SSM registration.
  KVBlocks  attention geometry for carried-payload (transform) monoids:
            q (BH, Tq, d) against k/v (BHkv, Tk, d), folded along KV
            blocks. Operands have DIFFERENT index maps (GQA maps q head
            ``h`` to kv head ``h // group`` — free addressing, paper
            Obs. 5), monoid leaves are per-q-block payload carries with
            per-leaf trailing dims (``leaf_dims``), and outputs are the
            fold. Used by the flash-attention registration.

All layouts put the scanned axis LAST in the grid, expose ``chunk``
axis 1 in their chunk-total arrays, and keep the scan axis at size 1 in
carry slices so monoid ``combine`` broadcasts carries against tiles.
"""

from __future__ import annotations

import dataclasses

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _check_divisible(shape, block, what):
    for s, b in zip(shape, block):
        if s % b:
            raise ValueError(
                f"{what} shape {shape} not divisible by block {block}")


class _UniformLeaves:
    """Shared per-leaf plumbing for layouts whose monoid leaves all share
    the data tile geometry (Rows, Channels). The schedules only speak the
    per-leaf/per-operand dialect so carried-payload layouts (KVBlocks)
    can differ; uniform layouts delegate to their single spec."""

    def op_specs(self, n_ops):
        return [self.data_spec()] * n_ops

    def out_spec(self):
        return self.data_spec()

    def chain_spec_for(self, leaf):
        return self.chain_spec()

    def chain_shape_for(self, leaf):
        return self.chain_shape


@dataclasses.dataclass(frozen=True)
class Rows(_UniformLeaves):
    """2D (rows, n) leaves, scan along axis 1, blocks (bb, bn)."""

    rows: int
    n: int
    bb: int
    bn: int

    def __post_init__(self):
        _check_divisible((self.rows, self.n), (self.bb, self.bn), "Rows")

    # -- grid geometry --------------------------------------------------
    @property
    def shape(self):
        return (self.rows, self.n)

    @property
    def grid(self):
        return (self.rows // self.bb, self.n // self.bn)

    @property
    def num_seq_blocks(self):
        return self.n // self.bn

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    scan_axis = 1  # within the (bb, bn) tile

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    # -- block specs ----------------------------------------------------
    def data_spec(self):
        return pl.BlockSpec((self.bb, self.bn), lambda i, j: (i, j))

    def chain_spec(self):
        return pl.BlockSpec((self.bb, 1), lambda i, j: (i, j))

    @property
    def chain_shape(self):
        return (self.rows, self.num_seq_blocks)

    @property
    def chain_block(self):
        return (self.bb, 1)

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((self.bb, 1), dtype)

    # -- in-kernel views ------------------------------------------------
    def read(self, ref):
        return ref[...]

    def write(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_carry(self, ref):
        return ref[...]

    def write_carry(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_chain(self, ref):
        return ref[...]

    def write_chain(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def take_last(self, x):
        return x[:, -1:]

    # -- fused-schedule addressing (whole-array HBM refs) ---------------
    def chain_at(self, ref, seq_index):
        """Slice one chunk column of the (rows, chunks) chain buffer for
        this instance's row block."""
        i = pl.program_id(0)
        return ref.at[pl.ds(i * self.bb, self.bb), pl.ds(seq_index, 1)]

    def sem_at(self, sem, seq_index):
        return sem.at[pl.program_id(0), seq_index]


@dataclasses.dataclass(frozen=True)
class Channels(_UniformLeaves):
    """3D (B, T, D) leaves, scan along axis 1 (time), blocks (1, bt, bd).

    In-kernel tiles are (bt, bd) with time on the SUBLANE axis and
    channels on lanes; carries are (1, bd) — one state per channel lane.
    """

    b: int
    t: int
    d: int
    bt: int
    bd: int

    def __post_init__(self):
        _check_divisible((self.t, self.d), (self.bt, self.bd), "Channels")

    @property
    def shape(self):
        return (self.b, self.t, self.d)

    @property
    def grid(self):
        return (self.b, self.d // self.bd, self.t // self.bt)

    @property
    def num_seq_blocks(self):
        return self.t // self.bt

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    scan_axis = 0  # within the (bt, bd) tile

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    def data_spec(self):
        return pl.BlockSpec((1, self.bt, self.bd), lambda i, d, t: (i, t, d))

    def chain_spec(self):
        return pl.BlockSpec((1, 1, self.bd), lambda i, d, t: (i, t, d))

    @property
    def chain_shape(self):
        return (self.b, self.num_seq_blocks, self.d)

    @property
    def chain_block(self):
        return (1, 1, self.bd)

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((1, self.bd), dtype)

    def read(self, ref):
        return ref[0]  # (bt, bd)

    def write(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def read_carry(self, ref):
        return ref[...]  # (1, bd): broadcasts over the (bt, bd) tile

    def write_carry(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_chain(self, ref):
        return ref[0]  # (1, bd)

    def write_chain(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def take_last(self, x):
        return x[-1:, :]

    def chain_at(self, ref, seq_index):
        i, d = pl.program_id(0), pl.program_id(1)
        return ref.at[pl.ds(i, 1), pl.ds(seq_index, 1),
                      pl.ds(d * self.bd, self.bd)]

    def sem_at(self, sem, seq_index):
        return sem.at[pl.program_id(0), pl.program_id(1), seq_index]


@dataclasses.dataclass(frozen=True)
class KVBlocks:
    """Attention fold geometry for carried-payload (transform) monoids.

    q ``(bh, tq, d)`` attends k/v ``(bh_kv, tk, d)``; the scanned axis is
    the KV-block axis and the monoid leaves are per-q-block PAYLOAD
    carries — ``(bq, leaf_dims[i])`` tiles (flash attention: the
    ``(m, l)`` pair at dim 1 plus the weighted-value accumulator at dim
    ``d``) — so carries, chain buffers and scratch are per-leaf shaped,
    unlike the uniform-leaf layouts above.

    Two grids serve the two fold schedules:

      carry      ``(bh, nq, nk)``, KV axis sequential ("arbitrary"):
                 the single-pass accumulate — q·kᵀ folded into the VMEM
                 payload carry block by block, output written once at
                 the last KV block.
      decoupled  ``(bh, nq, splits, nk/splits)``: the split-KV /
                 flash-decoding organization. KV chunks are fully
                 parallel; WITHIN a chunk the sub-block axis is the same
                 sequential accumulate, publishing one payload triple
                 per chunk to the chain buffers; a tiny jnp combine
                 chain + finalize stitches chunks back together.

    ``group`` maps q head ``h`` to kv head ``h // group`` in the k/v
    index maps (GQA as free addressing, paper Obs. 5).
    """

    bh: int              # flattened B·H_q query rows
    bh_kv: int           # flattened B·H_kv rows; bh == bh_kv * group
    tq: int
    tk: int
    d: int
    bq: int
    bk: int
    group: int = 1
    splits: int = 1      # KV chunks for the decoupled fold
    leaf_dims: "tuple | None" = None   # per-leaf trailing dims; (1,1,d)

    def __post_init__(self):
        _check_divisible((self.tq, self.tk), (self.bq, self.bk), "KVBlocks")
        if self.bh != self.bh_kv * self.group:
            raise ValueError(
                f"bh={self.bh} != bh_kv={self.bh_kv} * group={self.group}")
        if self.num_seq_blocks % self.splits:
            raise ValueError(
                f"splits={self.splits} must divide {self.num_seq_blocks} "
                "KV blocks")

    # -- geometry --------------------------------------------------------
    @property
    def shape(self):
        return (self.bh, self.tq, self.d)

    @property
    def nq(self):
        return self.tq // self.bq

    @property
    def num_seq_blocks(self):
        return self.tk // self.bk

    @property
    def blocks_per_chunk(self):
        return self.num_seq_blocks // self.splits

    @property
    def grid(self):
        return (self.bh, self.nq, self.num_seq_blocks)

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    @property
    def split_grid(self):
        return (self.bh, self.nq, self.splits, self.blocks_per_chunk)

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    def split_semantics(self):
        # chunks parallel, sub-blocks within a chunk sequential
        return ("parallel",) * 3 + ("arbitrary",)

    def leaf_dim(self, leaf: int) -> int:
        dims = self.leaf_dims if self.leaf_dims is not None \
            else (1, 1, self.d)
        return dims[leaf]

    # -- block specs -----------------------------------------------------
    def op_specs(self, n_ops):
        if n_ops != 3:
            raise ValueError(f"KVBlocks expects (q, k, v) operands, "
                             f"got {n_ops}")
        g = self.group
        return [
            pl.BlockSpec((1, self.bq, self.d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, self.bk, self.d),
                         lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, self.bk, self.d),
                         lambda h, i, j, g=g: (h // g, j, 0)),
        ]

    def split_op_specs(self, n_ops):
        if n_ops != 3:
            raise ValueError(f"KVBlocks expects (q, k, v) operands, "
                             f"got {n_ops}")
        g, bpc = self.group, self.blocks_per_chunk
        return [
            pl.BlockSpec((1, self.bq, self.d),
                         lambda h, i, c, s: (h, i, 0)),
            pl.BlockSpec((1, self.bk, self.d),
                         lambda h, i, c, s, g=g, bpc=bpc:
                         (h // g, c * bpc + s, 0)),
            pl.BlockSpec((1, self.bk, self.d),
                         lambda h, i, c, s, g=g, bpc=bpc:
                         (h // g, c * bpc + s, 0)),
        ]

    def out_spec(self):
        # independent of the KV axis: the block persists in VMEM across
        # the sequential axis and is written once, at the last KV block
        return pl.BlockSpec((1, self.bq, self.d), lambda h, i, j: (h, i, 0))

    def chain_shape_for(self, leaf: int):
        return (self.bh * self.nq, self.splits, self.bq,
                self.leaf_dim(leaf))

    def split_chain_spec_for(self, leaf: int):
        nq = self.nq
        return pl.BlockSpec(
            (1, 1, self.bq, self.leaf_dim(leaf)),
            lambda h, i, c, s, nq=nq: (h * nq + i, c, 0, 0))

    def carry_scratch(self, dtype, leaf=0):
        return pltpu.VMEM((self.bq, self.leaf_dim(leaf)), dtype)

    # -- in-kernel views -------------------------------------------------
    def block_ids(self):
        return (pl.program_id(0), pl.program_id(1), pl.program_id(2))

    def split_block_ids(self):
        bpc = self.blocks_per_chunk
        return (pl.program_id(0), pl.program_id(1),
                pl.program_id(2) * bpc + pl.program_id(3))

    def read_op(self, ref):
        return ref[0]

    def write(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def write_chain(self, ref, val):
        ref[0, 0] = val.astype(ref.dtype)

    def unchain_out(self, x):
        """(bh·nq, bq, dim) fold/finalize result -> (bh, tq, dim)."""
        return x.reshape(self.bh, self.tq, x.shape[-1])
