"""Grid/tile layouts the monoid-generic schedules are written against.

A layout answers every SHAPE question a schedule has — grid geometry,
block specs, carry/chunk-total shapes, how to read a tile out of a ref —
so the schedule bodies in ``schedules.py`` contain no per-family
geometry. Two layouts cover the four kernel families:

  Rows      (R, N) leaves scanned along the last axis in (bb, bn) VMEM
            tiles; rows are the paper's threads. Used by the sum,
            segmented and compact-mask registrations.
  Channels  (B, T, D) leaves scanned along the TIME axis in (1, bt, bd)
            tiles; channels ride the 128-lane axis as independent lanes
            (the paper's §3.2 vertical SIMD — natural on TPU, not a
            gather penalty). Used by the affine/SSM registration.

Both layouts put the scanned axis LAST in the grid, expose ``chunk``
axis 1 in their chunk-total arrays, and keep the scan axis at size 1 in
carry slices so monoid ``combine`` broadcasts carries against tiles.
"""

from __future__ import annotations

import dataclasses

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _check_divisible(shape, block, what):
    for s, b in zip(shape, block):
        if s % b:
            raise ValueError(
                f"{what} shape {shape} not divisible by block {block}")


@dataclasses.dataclass(frozen=True)
class Rows:
    """2D (rows, n) leaves, scan along axis 1, blocks (bb, bn)."""

    rows: int
    n: int
    bb: int
    bn: int

    def __post_init__(self):
        _check_divisible((self.rows, self.n), (self.bb, self.bn), "Rows")

    # -- grid geometry --------------------------------------------------
    @property
    def shape(self):
        return (self.rows, self.n)

    @property
    def grid(self):
        return (self.rows // self.bb, self.n // self.bn)

    @property
    def num_seq_blocks(self):
        return self.n // self.bn

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    scan_axis = 1  # within the (bb, bn) tile

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    # -- block specs ----------------------------------------------------
    def data_spec(self):
        return pl.BlockSpec((self.bb, self.bn), lambda i, j: (i, j))

    def chain_spec(self):
        return pl.BlockSpec((self.bb, 1), lambda i, j: (i, j))

    @property
    def chain_shape(self):
        return (self.rows, self.num_seq_blocks)

    @property
    def chain_block(self):
        return (self.bb, 1)

    def carry_scratch(self, dtype):
        return pltpu.VMEM((self.bb, 1), dtype)

    # -- in-kernel views ------------------------------------------------
    def read(self, ref):
        return ref[...]

    def write(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_carry(self, ref):
        return ref[...]

    def write_carry(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_chain(self, ref):
        return ref[...]

    def write_chain(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def take_last(self, x):
        return x[:, -1:]

    # -- fused-schedule addressing (whole-array HBM refs) ---------------
    def chain_at(self, ref, seq_index):
        """Slice one chunk column of the (rows, chunks) chain buffer for
        this instance's row block."""
        i = pl.program_id(0)
        return ref.at[pl.ds(i * self.bb, self.bb), pl.ds(seq_index, 1)]

    def sem_at(self, sem, seq_index):
        return sem.at[pl.program_id(0), seq_index]


@dataclasses.dataclass(frozen=True)
class Channels:
    """3D (B, T, D) leaves, scan along axis 1 (time), blocks (1, bt, bd).

    In-kernel tiles are (bt, bd) with time on the SUBLANE axis and
    channels on lanes; carries are (1, bd) — one state per channel lane.
    """

    b: int
    t: int
    d: int
    bt: int
    bd: int

    def __post_init__(self):
        _check_divisible((self.t, self.d), (self.bt, self.bd), "Channels")

    @property
    def shape(self):
        return (self.b, self.t, self.d)

    @property
    def grid(self):
        return (self.b, self.d // self.bd, self.t // self.bt)

    @property
    def num_seq_blocks(self):
        return self.t // self.bt

    @property
    def seq_grid_axis(self):
        return len(self.grid) - 1

    scan_axis = 0  # within the (bt, bd) tile

    def semantics(self, seq_kind: str):
        return ("parallel",) * (len(self.grid) - 1) + (seq_kind,)

    def data_spec(self):
        return pl.BlockSpec((1, self.bt, self.bd), lambda i, d, t: (i, t, d))

    def chain_spec(self):
        return pl.BlockSpec((1, 1, self.bd), lambda i, d, t: (i, t, d))

    @property
    def chain_shape(self):
        return (self.b, self.num_seq_blocks, self.d)

    @property
    def chain_block(self):
        return (1, 1, self.bd)

    def carry_scratch(self, dtype):
        return pltpu.VMEM((1, self.bd), dtype)

    def read(self, ref):
        return ref[0]  # (bt, bd)

    def write(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def read_carry(self, ref):
        return ref[...]  # (1, bd): broadcasts over the (bt, bd) tile

    def write_carry(self, ref, val):
        ref[...] = val.astype(ref.dtype)

    def read_chain(self, ref):
        return ref[0]  # (1, bd)

    def write_chain(self, ref, val):
        ref[0] = val.astype(ref.dtype)

    def take_last(self, x):
        return x[-1:, :]

    def chain_at(self, ref, seq_index):
        i, d = pl.program_id(0), pl.program_id(1)
        return ref.at[pl.ds(i, 1), pl.ds(seq_index, 1),
                      pl.ds(d * self.bd, self.bd)]

    def sem_at(self, sem, seq_index):
        return sem.at[pl.program_id(0), pl.program_id(1), seq_index]
