"""Monoid-generic Pallas scan engine: each schedule written once.

The paper's finding is that prefix-sum performance is decided by how the
computation's sub-procedures are ORGANIZED — single-pass accumulate,
reduce-then-scan, scan-then-propagate, and their partitioned variants —
not by the binary operator being scanned. This package is that split as
architecture:

  organization (written ONCE)             operator (a registration)
  --------------------------------------  --------------------------------
  schedules.scan_carry      — the paper's  assoc.SUM_KERNEL        (cumsum)
    single-pass accumulate (SIMD-P) over   assoc.SEGMENTED_SUM_KERNEL
    VMEM partitions                          (segmented scans / MoE ranks)
  schedules.scan_decoupled  — reduce-then- assoc.AFFINE_KERNEL
    scan (SIMD2-P, Observation 3), two       (SSM/xLSTM recurrences)
    launches                               assoc.mask_kernel_spec
  schedules.scan_fused      — reduce-then-   (stream compaction, fused
    scan in ONE launch, chunk prefixes       predicate select)
    chained through cross-chunk            assoc.softmax_pair_kernel_spec
    semaphores (Merrill-style); falls        (flash attention: carried
    back to two-launch under interpret       payload + input transform)
  schedules.scan_tree       — work-efficient
    balanced tree (§3.3, Observation 5):
    Blelloch up-sweep/down-sweep inside
    each VMEM tile, carry's grid between
    tiles
  schedules.fold_carry /    — the same two
    schedules.fold_decoupled organizations
    as a FOLD for carried-payload monoids
    (spec.transform builds each block's
    element from raw operand tiles;
    decoupled == split-KV flash-decoding)

(The paper's remaining organization, scan-then-propagate / SIMD1-P, is
the same dataflow as reduce-then-scan with the pass-1 scans kept; its
extra intermediate traffic loses under Observation 3, so the engine does
not ship it as a schedule — ``core.scan.blocked.scan_two_pass`` keeps it
available as a library oracle.)

Geometry lives in ``layouts`` (Rows for 2D batch×sequence, Channels for
SSM batch×time×channel tiles, KVBlocks for the attention fold);
``core/scan/policy.choose_schedule`` arbitrates the three-way schedule
choice (``choose_attention_schedule`` the two-way fold variant). The
five kernel families under
``repro.kernels.{scan_blocked,segscan,ssm_scan,compact,flash_attention}``
are thin back-compat wrappers over this engine — adding a new schedule
(or a new monoid) is a one-file change.
"""

from repro.kernels.scan_engine import monoids
from repro.kernels.scan_engine.layouts import (Channels, KVBlocks, QBlocks,
                                               Rows, block_live)
from repro.kernels.scan_engine.schedules import (RESOLVABLE, SCHEDULES,
                                                 exclusive_chain, fold_carry,
                                                 fold_chain, fold_decoupled,
                                                 fused_native_available,
                                                 resolve_schedule, scan,
                                                 scan_carry, scan_decoupled,
                                                 scan_fused, scan_tree,
                                                 tile_scan, tree_scan)

__all__ = [
    "Channels", "KVBlocks", "QBlocks", "RESOLVABLE", "Rows", "SCHEDULES",
    "block_live", "exclusive_chain", "fold_carry", "fold_chain",
    "fold_decoupled", "fused_native_available", "monoids",
    "resolve_schedule", "scan", "scan_carry", "scan_decoupled",
    "scan_fused", "scan_tree", "tile_scan", "tree_scan",
]
