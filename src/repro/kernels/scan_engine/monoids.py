"""Monoid registrations for the scan engine.

Each of the five kernel families is nothing but one of these entries —
the kernel specs themselves live next to their library monoids in
``repro.core.scan.assoc`` (element leaves, identity fills, in-kernel
combine/select emitters; for flash attention the carried-payload
transform/finalize pair); this module is the kernel-side registry that
the family ``ops`` wrappers, the parity tests and the benchmark sweep
iterate over.
"""

from __future__ import annotations

from repro.core.scan import assoc

SUM = assoc.SUM_KERNEL
SEGMENTED_SUM = assoc.SEGMENTED_SUM_KERNEL
AFFINE = assoc.AFFINE_KERNEL


def mask(sentinel: int) -> assoc.KernelSpec:
    """Compact-mask spec: integer mask scan + fused predicate select.

    ``sentinel`` is the destination emitted for dropped lanes (the padded
    row length, so a size-(n+1) scatter buffer parks them harmlessly).
    """
    return assoc.mask_kernel_spec(sentinel)


def softmax_pair(**config) -> assoc.KernelSpec:
    """Flash-attention spec: online softmax + carried value payload.

    Config (scale, masking geometry, block sizes) is baked into the
    per-block input transform — see ``assoc.softmax_pair_kernel_spec``.
    """
    config.setdefault("scale", 1.0)
    return assoc.softmax_pair_kernel_spec(**config)


# name -> spec factory taking no arguments (mask gets a default sentinel,
# softmax_pair a default geometry, only meaningful for sweeps/tests; real
# callers pass their padded N / attention config).
REGISTRY = {
    "sum": lambda: SUM,
    "segmented_sum": lambda: SEGMENTED_SUM,
    "affine": lambda: AFFINE,
    "mask": lambda: mask(0x7FFFFFFF),
    "softmax_pair": lambda: softmax_pair(),
}
