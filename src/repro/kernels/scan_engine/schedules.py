"""The four grid organizations, each written ONCE over a KernelSpec.

This is the paper's central claim — prefix-scan performance is decided by
how the sub-procedures are ORGANIZED, not by the binary operator — turned
into code structure. Every schedule below is monoid-generic: it calls
only the ``KernelSpec`` interface (``combine`` / ``fills`` / ``emit``,
see ``repro.core.scan.assoc``) plus a ``Layout`` for geometry, so sum,
segmented, affine-SSM and compact-mask all run the SAME bodies:

  carry      the paper's single-pass accumulate (SIMD-P) partitioned over
             VMEM tiles: sequential grid along the scanned axis, the
             inter-block state in VMEM scratch. HBM: read n + write n.
  decoupled  the paper's reduce-then-scan (SIMD2-P, Observation 3): a
             fully parallel totals pass, a tiny sequential combine chain
             over chunk totals, a fully parallel apply pass. HBM: read 2n
             + write n — the price of spreading ONE row across cores.
  fused      decoupled in a single launch: every chunk computes its local
             scan once, then chains its prefix to its successor through
             cross-chunk semaphores (Merrill-style chained scan). HBM:
             read n + write n with decoupled's parallelism. Requires the
             TPU semaphore API; under interpret mode (or when the API is
             missing) it degrades to the two-launch decoupled schedule —
             same organization, same bits.
  tree       the paper's work-efficient balanced tree (§3.3, Observation
             5; Blelloch's up-sweep/down-sweep): the carry schedule's
             grid and inter-block carry, but the IN-TILE network replaced
             by a recursive pairwise up-sweep (combine evens with odds,
             halving the problem) and down-sweep (parent prefixes fan
             back out, ``combine(parent, old_left)``). O(n) combines per
             tile instead of Hillis–Steele's O(n log n), at the price of
             the strided deinterleave/interleave traffic the paper's
             Observation 5 charges it with — all inside VMEM, where those
             extra passes are cheap. HBM: read n + write n.

Bit-identity across schedules holds by construction for every monoid:
carry/decoupled/fused run the identical in-tile scan network, and the
decoupled/fused combine chains apply ``combine`` in exactly the carry
chain's order (``combine`` is pointwise along the scan axis, so
combining a carry into a block and then taking the last column equals
combining it into the last column directly). The tree schedule computes
the same monoid products through a DIFFERENT association (the balanced
tree), so it is bitwise identical to the others exactly when ``combine``
is associative in machine arithmetic — integer monoids, logical monoids,
floats on exactly-representable data — and agrees to rounding error
otherwise. The parity wall in ``tests/test_scan_engine.py`` pins both
regimes.

CARRIED-PAYLOAD monoids (``spec.transform`` set — flash attention's
softmax pair with its weighted-value accumulator) run the same two
organizations as a FOLD over blocks: each grid block along the scanned
axis contributes ONE macro element built by the spec's input transform
from raw operand tiles, and outputs are emitted once from the final
carried state via ``spec.finalize``:

  carry      ``_fold_carry_body`` — the single-pass accumulate again:
             sequential KV grid, payload carry in VMEM scratch, finalize
             fused into the last block's writeback. This IS the classic
             flash-attention forward, recovered from the generic engine.
  decoupled  ``_fold_totals_body`` — split-KV / flash-decoding: KV chunks
             fully parallel, each running the same accumulate over its
             sub-blocks and publishing one payload element to the chain
             buffers; a tiny jnp combine chain + finalize stitches the
             chunks. (No fused form: a fold has no per-element writeback
             to chain a prefix into, so "fused" maps to decoupled.)

Folds are not bitwise-invariant across schedules — the chunk chain
re-associates the payload rescaling — but agree to float tolerance, and
each matches the reference oracles to the usual kernel tolerances.

Both fold forms honor the layout's optional KV-extent bounds
(``layout.fold_active``): grid cells whose mask is provably all-dead
skip the transform-and-combine entirely, leaving the carry untouched —
bitwise identical to folding in the identity element the masked
transform would have produced, at none of the cost. Causal prefill
runs ~half its cells this way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.scan import policy
from repro.core.scan.assoc import KernelSpec
from repro.kernels import pallas_compat
from repro.obs import trace

LANES = 128

SCHEDULES = ("carry", "decoupled", "fused", "tree")
RESOLVABLE = SCHEDULES + ("auto",)


def resolve_schedule(schedule: str, batch: int, n: int,
                     block_elems: int) -> str:
    """'auto' -> the policy's four-way rule; else validate.

    Shared by every family's ops wrapper. ``block_elems`` is the chunk
    length the kernel will ACTUALLY tile the scanned axis with — the
    policy's chunks-per-core test is only meaningful against the real
    grid.
    """
    if schedule not in RESOLVABLE:
        raise ValueError(
            f"unknown schedule {schedule!r}; one of {RESOLVABLE}")
    if schedule == "auto":
        return policy.choose_schedule(batch, n, block_elems=block_elems)
    return schedule


# ---------------------------------------------------------------------------
# Monoid-generic in-tile scan network
# ---------------------------------------------------------------------------


def _shift(x, k, axis, fill):
    """Shift ``x`` right by ``k`` along ``axis``, filling with identity."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (k, 0)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, x.shape[axis])
    return jnp.pad(x, pad, constant_values=fill)[tuple(sl)]


def shift_one(spec: KernelSpec, leaves, axis):
    """Exclusive shift: one step right, identity-filled (all leaves)."""
    return tuple(
        _shift(x, 1, axis, f) for x, f in zip(leaves, spec.fills))


def log_scan(spec: KernelSpec, leaves, axis):
    """Hillis–Steele log-step inclusive scan of monoid leaves (§3.1)."""
    n = leaves[0].shape[axis]
    k = 1
    while k < n:
        shifted = tuple(
            _shift(x, k, axis, f) for x, f in zip(leaves, spec.fills))
        leaves = spec.combine(shifted, leaves)
        k *= 2
    return leaves


def tile_scan(spec: KernelSpec, leaves, axis):
    """In-tile inclusive scan; two-level lane/sublane split on lane axes.

    When the scan axis is the (128-wide) lane axis and divisible, run the
    paper's Fig. 3 scheme lifted to the monoid: scan within each lane row,
    exclusive-scan the row totals along sublanes, broadcast-combine —
    "scan the vector in register, broadcast the last element".
    """
    x0 = leaves[0]
    n = x0.shape[axis]
    last = x0.ndim - 1
    if axis == last and n > LANES and n % LANES == 0:
        r = n // LANES
        ts = tuple(
            x.reshape(x.shape[:-1] + (r, LANES)) for x in leaves)
        ts = log_scan(spec, ts, axis=ts[0].ndim - 1)
        tot = tuple(t[..., LANES - 1] for t in ts)      # per-row totals
        off = log_scan(spec, tot, axis=tot[0].ndim - 1)
        off = shift_one(spec, off, axis=off[0].ndim - 1)  # exclusive
        ts = spec.combine(tuple(o[..., None] for o in off), ts)
        return tuple(t.reshape(x.shape) for t, x in zip(ts, leaves))
    return log_scan(spec, leaves, axis)


def _pad_to(x, m, axis, fill):
    """Pad ``x`` up to length ``m`` along ``axis`` with the identity."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - x.shape[axis])
    return jnp.pad(x, pad, constant_values=fill)


def _blelloch(spec: KernelSpec, leaves, axis):
    """Recursive pairwise Blelloch sweep; power-of-two length required.

    Up-sweep: deinterleave the tile into even/odd positions and combine
    (``combine(evens, odds)`` — left argument earlier, preserving
    non-commutative order), recursing on the half-length pair totals.
    Down-sweep: the recursion returns the parents' EXCLUSIVE prefixes;
    each even slot takes its parent's prefix unchanged and each odd slot
    takes ``combine(parent, old_left)`` — the same left-argument
    convention the ``core.scan.tree`` oracle pins. Returns
    ``(exclusive_scan, root_total)`` where the total keeps a size-1 scan
    axis (the shape ``layout.take_last`` produces, so the inter-block
    carry chain is shared with the carry schedule verbatim).

    The deinterleave/interleave is reshape-based (no gather): at each of
    the log2(n) levels a ``(..., m/2, 2, ...)`` view splits and a stack +
    reshape merges — the strided access pattern of the paper's
    Observation 5, confined to VMEM.
    """
    m = leaves[0].shape[axis]
    if m == 1:
        ident = tuple(
            jnp.full_like(x, f) for x, f in zip(leaves, spec.fills))
        return ident, leaves

    def split(x):
        shape = x.shape
        xs = x.reshape(shape[:axis] + (m // 2, 2) + shape[axis + 1:])
        ev = jax.lax.index_in_dim(xs, 0, axis + 1, keepdims=False)
        od = jax.lax.index_in_dim(xs, 1, axis + 1, keepdims=False)
        return ev, od

    pairs = tuple(split(x) for x in leaves)
    evens = tuple(p[0] for p in pairs)
    odds = tuple(p[1] for p in pairs)
    parent_excl, total = _blelloch(spec, spec.combine(evens, odds), axis)
    right = spec.combine(parent_excl, evens)   # combine(parent, old_left)

    def merge(left, rt):
        st = jnp.stack([left, rt], axis=axis + 1)
        return st.reshape(left.shape[:axis] + (m,) + left.shape[axis + 1:])

    excl = tuple(merge(l, r) for l, r in zip(parent_excl, right))
    return excl, total


def tree_scan(spec: KernelSpec, leaves, axis):
    """Work-efficient in-tile EXCLUSIVE scan (§3.3 balanced tree).

    Pads the scan axis to a power of two with the monoid identity (the
    padded tail contributes identity to every prefix and to the root
    total, so the slice-back is exact), runs the Blelloch sweep, and
    returns ``(exclusive_scan, total)`` — the inclusive form is one
    ``combine(exclusive, elems)`` away, which the tree body fuses into
    its carry application.
    """
    n = leaves[0].shape[axis]
    m = 1
    while m < n:
        m *= 2
    if m != n:
        leaves = tuple(
            _pad_to(x, m, axis, f) for x, f in zip(leaves, spec.fills))
    excl, total = _blelloch(spec, leaves, axis)
    if m != n:
        excl = tuple(
            jax.lax.slice_in_dim(x, 0, n, axis=axis) for x in excl)
    return excl, total


def exclusive_chain(spec: KernelSpec, totals, axis: int = 1):
    """Sequential exclusive monoid scan of chunk totals along ``axis``.

    Left-to-right ``lax.scan`` applying ``combine`` in EXACTLY the carry
    schedule's association order — this is what makes the decoupled and
    fused organizations bit-identical to the carry chain.
    """
    init = tuple(
        jnp.full_like(jax.lax.index_in_dim(t, 0, axis, keepdims=False), f)
        for t, f in zip(totals, spec.fills))

    def step(carry, t):
        return spec.combine(carry, t), carry

    moved = tuple(jnp.moveaxis(t, axis, 0) for t in totals)
    _, offs = jax.lax.scan(step, init, moved)
    return tuple(jnp.moveaxis(o, 0, axis) for o in offs)


# ---------------------------------------------------------------------------
# Shared kernel-body pieces
# ---------------------------------------------------------------------------


def _scan_block(spec, layout, data_refs, elem_dts):
    raw = tuple(layout.read(r) for r in data_refs)
    elems = tuple(r.astype(dt) for r, dt in zip(raw, elem_dts))
    scanned = tile_scan(spec, elems, layout.scan_axis)
    return elems, scanned


def _emit(spec, layout, out_refs, elems, combined):
    if spec.emit is not None:
        outs = spec.emit(elems, combined)
    else:
        outs = tuple(combined[i] for i in spec.out_leaves)
    for r, o in zip(out_refs, outs):
        layout.write(r, o)


def _dtypes(spec, operands):
    in_dts = tuple(jnp.dtype(o.dtype) for o in operands)
    return spec.elem_dtypes(in_dts), spec.out_dtypes(in_dts)


# ---------------------------------------------------------------------------
# Schedule 1: carry (single-pass accumulate, grid-carried total)
# ---------------------------------------------------------------------------


def _carry_body(*refs, spec, layout, elem_dts, n_out, exclusive, n_tot):
    n_elem = spec.n_leaves
    n_ops = len(refs) - n_out - n_tot - n_elem
    data_refs = refs[:n_ops]
    out_refs = refs[n_ops:n_ops + n_out]
    tot_refs = refs[n_ops + n_out:n_ops + n_out + n_tot]
    carry_refs = refs[n_ops + n_out + n_tot:]
    j = pl.program_id(layout.seq_grid_axis)

    @pl.when(j == 0)
    def _reset():
        # New row/stripe: reset the running state to the monoid identity.
        for r, f in zip(carry_refs, spec.fills):
            r[...] = jnp.full(r.shape, f, r.dtype)

    elems, scanned = _scan_block(spec, layout, data_refs, elem_dts)
    carry = tuple(layout.read_carry(r) for r in carry_refs)
    sel = shift_one(spec, scanned, layout.scan_axis) if exclusive else scanned
    combined = spec.combine(carry, sel)       # carry is the EARLIER operand
    _emit(spec, layout, out_refs, elems, combined)
    new_carry = spec.combine(
        carry, tuple(layout.take_last(s) for s in scanned))
    for r, c in zip(carry_refs, new_carry):
        layout.write_carry(r, c)
    # Optional running chunk-totals chain (combined through chunk j) —
    # bit-identical to the decoupled chain by the argument above.
    for r, c in zip(tot_refs, new_carry):
        layout.write_chain(r, c)


def scan_carry(operands, spec, layout, *, exclusive=False, interpret=False,
               return_totals=False):
    elem_dts, out_dts = _dtypes(spec, operands)
    n_tot = spec.n_leaves if return_totals else 0
    body = functools.partial(
        _carry_body, spec=spec, layout=layout, elem_dts=elem_dts,
        n_out=len(out_dts), exclusive=exclusive, n_tot=n_tot)
    outs = pl.pallas_call(
        body,
        grid=layout.grid,
        in_specs=layout.op_specs(len(operands)),
        out_specs=[layout.out_spec()] * len(out_dts)
        + [layout.chain_spec_for(i) for i in range(n_tot)],
        out_shape=[jax.ShapeDtypeStruct(layout.shape, dt) for dt in out_dts]
        + [jax.ShapeDtypeStruct(layout.chain_shape_for(i), dt)
           for i, dt in enumerate(elem_dts[:n_tot])],
        scratch_shapes=[layout.carry_scratch(dt, i)
                        for i, dt in enumerate(elem_dts)],
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=layout.semantics("arbitrary")),
        interpret=interpret,
        name=f"scan_{spec.name}_carry",
    )(*operands)
    if return_totals:
        return tuple(outs[:len(out_dts)]), tuple(outs[len(out_dts):])
    return tuple(outs)


# ---------------------------------------------------------------------------
# Schedule 2: decoupled (reduce-then-scan, two launches)
# ---------------------------------------------------------------------------


def _totals_body(*refs, spec, layout, elem_dts):
    n_elem = spec.n_leaves
    n_ops = len(refs) - n_elem
    data_refs = refs[:n_ops]
    tot_refs = refs[n_ops:]
    _, scanned = _scan_block(spec, layout, data_refs, elem_dts)
    for r, s in zip(tot_refs, scanned):
        layout.write_chain(r, layout.take_last(s))


def _apply_body(*refs, spec, layout, elem_dts, n_out, exclusive):
    n_elem = spec.n_leaves
    n_ops = len(refs) - n_out - n_elem
    data_refs = refs[:n_ops]
    off_refs = refs[n_ops:n_ops + n_elem]
    out_refs = refs[n_ops + n_elem:]
    elems, scanned = _scan_block(spec, layout, data_refs, elem_dts)
    carry = tuple(layout.read_chain(r) for r in off_refs)
    sel = shift_one(spec, scanned, layout.scan_axis) if exclusive else scanned
    combined = spec.combine(carry, sel)
    _emit(spec, layout, out_refs, elems, combined)


def scan_decoupled(operands, spec, layout, *, exclusive=False,
                   interpret=False, return_totals=False):
    elem_dts, out_dts = _dtypes(spec, operands)
    par = pallas_compat.compiler_params(
        dimension_semantics=layout.semantics("parallel"))

    totals = pl.pallas_call(
        functools.partial(
            _totals_body, spec=spec, layout=layout, elem_dts=elem_dts),
        grid=layout.grid,
        in_specs=layout.op_specs(len(operands)),
        out_specs=[layout.chain_spec_for(i) for i in range(spec.n_leaves)],
        out_shape=[jax.ShapeDtypeStruct(layout.chain_shape_for(i), dt)
                   for i, dt in enumerate(elem_dts)],
        compiler_params=par,
        interpret=interpret,
        name=f"scan_{spec.name}_totals",
    )(*operands)

    offsets = exclusive_chain(spec, tuple(totals))

    outs = tuple(pl.pallas_call(
        functools.partial(
            _apply_body, spec=spec, layout=layout, elem_dts=elem_dts,
            n_out=len(out_dts), exclusive=exclusive),
        grid=layout.grid,
        in_specs=layout.op_specs(len(operands))
        + [layout.chain_spec_for(i) for i in range(spec.n_leaves)],
        out_specs=[layout.out_spec()] * len(out_dts),
        out_shape=[jax.ShapeDtypeStruct(layout.shape, dt) for dt in out_dts],
        compiler_params=par,
        interpret=interpret,
        name=f"scan_{spec.name}_apply",
    )(*operands, *offsets))
    if return_totals:
        # Running (inclusive) chunk totals — exactly the carry schedule's
        # per-chunk carries: exclusive offset ⊕ local total, O(B·chunks).
        running = spec.combine(offsets, tuple(totals))
        return outs, running
    return outs


# ---------------------------------------------------------------------------
# Schedule 3: fused (single-launch decoupled, cross-chunk semaphores)
# ---------------------------------------------------------------------------


# Safety gate for the native single-launch path: it has never executed on
# real hardware (this container is CPU-only), and its liveness rests on an
# unverified assumption about Mosaic's parallel sub-grid traversal order.
# Until someone validates it on a TPU (ROADMAP), EVERY "fused" request —
# including policy-auto production routes — runs the two-launch decoupled
# organization, which is bit-identical. Flip to True (or monkeypatch) for
# the on-TPU validation run.
FUSED_NATIVE_ENABLED = False


def fused_native_available() -> bool:
    """Whether the single-launch chained scan can actually run here.

    Needs the validation gate open, a real TPU backend (the
    chained-semaphore protocol has no interpreter support), and a jax
    that exposes the semaphore API.
    """
    return (FUSED_NATIVE_ENABLED
            and jax.default_backend() == "tpu"
            and pallas_compat.has_semaphores())


def _fused_body(*refs, spec, layout, elem_dts, n_out, exclusive):
    # refs: data ops | outs | HBM chain bufs | 2×staging | 3 semaphores
    n_elem = spec.n_leaves
    n_ops = len(refs) - n_out - 3 * n_elem - 3
    data_refs = refs[:n_ops]
    out_refs = refs[n_ops:n_ops + n_out]
    pref_refs = refs[n_ops + n_out:n_ops + n_out + n_elem]  # HBM chain bufs
    scratch = refs[n_ops + n_out + n_elem:]
    stage_in = scratch[:n_elem]           # VMEM landing for pred prefix
    stage_out = scratch[n_elem:2 * n_elem]  # VMEM staging for own prefix
    sems, dsem_in, dsem_out = scratch[2 * n_elem:2 * n_elem + 3]

    j = pl.program_id(layout.seq_grid_axis)
    nseq = layout.num_seq_blocks
    elems, scanned = _scan_block(spec, layout, data_refs, elem_dts)
    total = tuple(layout.take_last(s) for s in scanned)

    @pl.when(j > 0)
    def _await_predecessor():
        # Predecessor signals only after its prefix DMA has landed in HBM.
        pallas_compat.semaphore_wait(layout.sem_at(sems, j - 1), 1)
        for p, s in zip(pref_refs, stage_in):
            cp = pallas_compat.async_copy(layout.chain_at(p, j - 1), s,
                                          dsem_in)
            cp.start()
            cp.wait()

    prefix = tuple(
        jnp.where(j > 0, layout.read_chain(s),
                  jnp.full_like(layout.read_chain(s), f))
        for s, f in zip(stage_in, spec.fills))

    @pl.when(j < nseq - 1)
    def _publish():
        # Publish combine(prefix_in, total) for the successor, then signal.
        new_prefix = spec.combine(prefix, total)
        for s, p, v in zip(stage_out, pref_refs, new_prefix):
            layout.write_chain(s, v)
            cp = pallas_compat.async_copy(s, layout.chain_at(p, j), dsem_out)
            cp.start()
            cp.wait()
        pallas_compat.semaphore_signal(layout.sem_at(sems, j), 1)

    sel = shift_one(spec, scanned, layout.scan_axis) if exclusive else scanned
    combined = spec.combine(prefix, sel)
    _emit(spec, layout, out_refs, elems, combined)


def scan_fused(operands, spec, layout, *, exclusive=False, interpret=False,
               return_totals=False):
    """Single-launch decoupled: chunk prefixes chained through semaphores.

    EXPERIMENTAL on-device path (pending real-TPU validation — see
    ROADMAP): each grid instance scans its chunk once, waits for its
    predecessor's published prefix, combines, republishes, and fuses the
    prefix into its own writeback — read n + write n total, with the
    scanned axis spread across cores. Correct under Mosaic's ascending
    per-core traversal of parallel grid dimensions (contiguous slabs or
    round-robin both chain forward). Until ``FUSED_NATIVE_ENABLED`` is
    flipped after on-TPU validation — and always off-TPU / under
    interpret mode — callers get the two-launch decoupled schedule: the
    same organization split into two ``pallas_call``s, bit-identical
    results.
    """
    if interpret or not fused_native_available() or return_totals:
        # return_totals also routes here: the native chain buffers hold
        # per-chunk PREFIXES except the last chunk (which never
        # publishes), so the two-launch form is the totals-bearing one.
        return scan_decoupled(operands, spec, layout, exclusive=exclusive,
                              interpret=interpret,
                              return_totals=return_totals)
    elem_dts, out_dts = _dtypes(spec, operands)
    n_elem = spec.n_leaves
    grid = layout.grid
    outs = pl.pallas_call(
        functools.partial(
            _fused_body, spec=spec, layout=layout, elem_dts=elem_dts,
            n_out=len(out_dts), exclusive=exclusive),
        grid=grid,
        in_specs=layout.op_specs(len(operands)),
        out_specs=[layout.out_spec()] * len(out_dts)
        + [pl.BlockSpec(memory_space=pallas_compat.any_memory_space())]
        * n_elem,
        out_shape=[jax.ShapeDtypeStruct(layout.shape, dt) for dt in out_dts]
        + [jax.ShapeDtypeStruct(layout.chain_shape, dt) for dt in elem_dts],
        scratch_shapes=(
            [pltpu.VMEM(layout.chain_block, dt) for dt in elem_dts]
            + [pltpu.VMEM(layout.chain_block, dt) for dt in elem_dts]
            + [pallas_compat.regular_semaphores(grid),
               pallas_compat.dma_semaphore(),
               pallas_compat.dma_semaphore()]),
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=layout.semantics("parallel")),
        interpret=interpret,
        name=f"scan_{spec.name}_fused",
    )(*operands)
    return tuple(outs[:len(out_dts)])  # drop the HBM chain buffers


# ---------------------------------------------------------------------------
# Schedule 4: tree (work-efficient Blelloch sweep inside each tile)
# ---------------------------------------------------------------------------


def _tree_body(*refs, spec, layout, elem_dts, n_out, exclusive, n_tot):
    n_elem = spec.n_leaves
    n_ops = len(refs) - n_out - n_tot - n_elem
    data_refs = refs[:n_ops]
    out_refs = refs[n_ops:n_ops + n_out]
    tot_refs = refs[n_ops + n_out:n_ops + n_out + n_tot]
    carry_refs = refs[n_ops + n_out + n_tot:]
    j = pl.program_id(layout.seq_grid_axis)

    @pl.when(j == 0)
    def _reset():
        for r, f in zip(carry_refs, spec.fills):
            r[...] = jnp.full(r.shape, f, r.dtype)

    raw = tuple(layout.read(r) for r in data_refs)
    elems = tuple(r.astype(dt) for r, dt in zip(raw, elem_dts))
    excl, total = tree_scan(spec, elems, layout.scan_axis)
    carry = tuple(layout.read_carry(r) for r in carry_refs)
    # The down-sweep hands us the exclusive scan for free; inclusive is
    # one extra pointwise combine with the raw elements.
    sel = excl if exclusive else spec.combine(excl, elems)
    combined = spec.combine(carry, sel)       # carry is the EARLIER operand
    _emit(spec, layout, out_refs, elems, combined)
    # ``total`` already carries a size-1 scan axis — the same shape
    # ``layout.take_last`` yields — so the carry chain is carry's verbatim.
    new_carry = spec.combine(carry, total)
    for r, c in zip(carry_refs, new_carry):
        layout.write_carry(r, c)
    for r, c in zip(tot_refs, new_carry):
        layout.write_chain(r, c)


def scan_tree(operands, spec, layout, *, exclusive=False, interpret=False,
              return_totals=False):
    """Carry's grid with the Blelloch tree as the in-tile network.

    Work-efficient (O(n) combines per tile vs Hillis–Steele's
    O(n log n)) at the cost of log2(n) strided deinterleave/interleave
    passes inside VMEM — the §3.3 organization. The inter-block carry
    chain, exclusive handling, and optional chunk-totals chain all match
    ``scan_carry`` exactly, so the schedules differ only in how each
    tile internally associates ``combine``.
    """
    elem_dts, out_dts = _dtypes(spec, operands)
    n_tot = spec.n_leaves if return_totals else 0
    body = functools.partial(
        _tree_body, spec=spec, layout=layout, elem_dts=elem_dts,
        n_out=len(out_dts), exclusive=exclusive, n_tot=n_tot)
    outs = pl.pallas_call(
        body,
        grid=layout.grid,
        in_specs=layout.op_specs(len(operands)),
        out_specs=[layout.out_spec()] * len(out_dts)
        + [layout.chain_spec_for(i) for i in range(n_tot)],
        out_shape=[jax.ShapeDtypeStruct(layout.shape, dt) for dt in out_dts]
        + [jax.ShapeDtypeStruct(layout.chain_shape_for(i), dt)
           for i, dt in enumerate(elem_dts[:n_tot])],
        scratch_shapes=[layout.carry_scratch(dt, i)
                        for i, dt in enumerate(elem_dts)],
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=layout.semantics("arbitrary")),
        interpret=interpret,
        name=f"scan_{spec.name}_tree",
    )(*operands)
    if return_totals:
        return tuple(outs[:len(out_dts)]), tuple(outs[len(out_dts):])
    return tuple(outs)


# ---------------------------------------------------------------------------
# Carried-payload fold schedules (spec.transform monoids)
# ---------------------------------------------------------------------------


def fold_chain(spec: KernelSpec, totals, axis: int = 1):
    """Sequential INCLUSIVE fold of chunk elements along ``axis``.

    Left-to-right ``lax.scan`` seeded with the monoid identity — the
    same association order as the fold-carry chain, so the decoupled
    fold re-associates only at chunk boundaries.
    """
    init = tuple(
        jnp.full_like(jax.lax.index_in_dim(t, 0, axis, keepdims=False), f)
        for t, f in zip(totals, spec.fills))

    def step(carry, t):
        return spec.combine(carry, t), None

    moved = tuple(jnp.moveaxis(t, axis, 0) for t in totals)
    final, _ = jax.lax.scan(step, init, moved)
    return final


def _fold_step(spec, layout, data_refs, carry_refs, elem_dts, ids):
    """One fold accumulate — transform, combine, carry writeback —
    gated on the layout's KV-extent liveness when bounds are on.

    Returns the traced ``active`` predicate (``None`` without bounds):
    a skipped cell leaves the carry untouched, which is bitwise equal to
    folding in the monoid identity its fully-masked transform would have
    produced.
    """
    active = layout.fold_active(ids)

    def step():
        ops = tuple(layout.read_op(r) for r in data_refs)
        elem = spec.transform(ops, ids)
        elem = tuple(e.astype(dt) for e, dt in zip(elem, elem_dts))
        carry = tuple(r[...] for r in carry_refs)
        new_carry = spec.combine(carry, elem)  # carry is EARLIER operand
        for r, c in zip(carry_refs, new_carry):
            r[...] = c.astype(r.dtype)

    if active is None:
        step()
    else:
        pl.when(active)(step)
    return active


def _fold_carry_body(*refs, spec, layout, elem_dts, n_ops, n_out, count):
    data_refs = refs[:n_ops]
    out_refs = refs[n_ops:n_ops + n_out]
    cnt_refs = refs[n_ops + n_out:n_ops + n_out + count]
    scratch = refs[n_ops + n_out + count:]
    carry_refs = scratch[:spec.n_leaves]
    cnt_scratch = scratch[spec.n_leaves:]
    j = pl.program_id(layout.seq_grid_axis)

    @pl.when(j == 0)
    def _reset():
        for r, f in zip(carry_refs, spec.fills):
            r[...] = jnp.full(r.shape, f, r.dtype)
        for r in cnt_scratch:
            r[...] = jnp.zeros(r.shape, r.dtype)

    active = _fold_step(spec, layout, data_refs, carry_refs, elem_dts,
                        layout.block_ids())
    for r in cnt_scratch:
        r[0, 0] += (1 if active is None
                    else active.astype(jnp.int32))

    @pl.when(j == layout.num_seq_blocks - 1)
    def _finalize():
        cur = tuple(r[...] for r in carry_refs)
        for r, o in zip(out_refs, spec.finalize(cur)):
            layout.write(r, o)
        for r, c in zip(cnt_refs, cnt_scratch):
            r[0, 0] = c[0, 0]


def fold_carry(operands, spec, layout, *, interpret=False,
               count_cells=False):
    """Single-pass accumulate of a carried-payload monoid (flash fwd).

    ``count_cells=True`` appends an int32 ``layout.count_shape`` output
    counting the fold cells that actually executed per grid row — the
    instrumentation behind the causal-bound "launches ~half the cells"
    assertion.
    """
    elem_dts, out_dts = _dtypes(spec, operands)
    count = 1 if count_cells else 0
    body = functools.partial(
        _fold_carry_body, spec=spec, layout=layout, elem_dts=elem_dts,
        n_ops=len(operands), n_out=len(out_dts), count=count)
    outs = pl.pallas_call(
        body,
        grid=layout.grid,
        in_specs=layout.op_specs(len(operands)),
        out_specs=[layout.out_spec_for(i) for i in range(len(out_dts))]
        + [layout.count_spec()] * count,
        out_shape=[jax.ShapeDtypeStruct(layout.out_shape_for(i), dt)
                   for i, dt in enumerate(out_dts)]
        + [jax.ShapeDtypeStruct(layout.count_shape, jnp.int32)] * count,
        scratch_shapes=[layout.carry_scratch(dt, i)
                        for i, dt in enumerate(elem_dts)]
        + [pltpu.VMEM((1, 1), jnp.int32)] * count,
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=layout.semantics("arbitrary")),
        interpret=interpret,
        name=f"scan_{spec.name}_fold_carry",
    )(*operands)
    if count_cells:
        return tuple(outs[:-1]), outs[-1]
    return tuple(outs)


def _fold_totals_body(*refs, spec, layout, elem_dts, n_ops):
    n_elem = spec.n_leaves
    data_refs = refs[:n_ops]
    chain_refs = refs[n_ops:n_ops + n_elem]
    carry_refs = refs[n_ops + n_elem:]
    s = pl.program_id(len(layout.split_grid) - 1)

    @pl.when(s == 0)
    def _reset():
        for r, f in zip(carry_refs, spec.fills):
            r[...] = jnp.full(r.shape, f, r.dtype)

    _fold_step(spec, layout, data_refs, carry_refs, elem_dts,
               layout.split_block_ids())

    @pl.when(s == layout.blocks_per_chunk - 1)
    def _publish():
        cur = tuple(r[...] for r in carry_refs)
        for r, c in zip(chain_refs, cur):
            layout.write_chain(r, c)


def fold_decoupled(operands, spec, layout, *, interpret=False):
    """Split-KV fold: parallel chunk accumulates + tiny combine chain.

    The flash-decoding organization: launch 1 runs the fold-carry body
    over each of ``layout.splits`` KV chunks in parallel, publishing one
    payload element per chunk; the chunks are then stitched by a
    sequential jnp combine (same association as the carry chain at chunk
    granularity) and finalized — read ``n`` once plus
    O(rows · splits · payload) chain traffic, with the scanned axis
    spread across cores.
    """
    elem_dts, out_dts = _dtypes(spec, operands)
    totals = pl.pallas_call(
        functools.partial(
            _fold_totals_body, spec=spec, layout=layout, elem_dts=elem_dts,
            n_ops=len(operands)),
        grid=layout.split_grid,
        in_specs=layout.split_op_specs(len(operands)),
        out_specs=[layout.split_chain_spec_for(i)
                   for i in range(spec.n_leaves)],
        out_shape=[jax.ShapeDtypeStruct(layout.chain_shape_for(i), dt)
                   for i, dt in enumerate(elem_dts)],
        scratch_shapes=[layout.carry_scratch(dt, i)
                        for i, dt in enumerate(elem_dts)],
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=layout.split_semantics()),
        interpret=interpret,
        name=f"scan_{spec.name}_fold_totals",
    )(*operands)

    final = fold_chain(spec, tuple(totals))
    outs = spec.finalize(final)
    return tuple(
        layout.unchain_out(o).astype(dt) for o, dt in zip(outs, out_dts))


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def _launch_event(operands, spec: KernelSpec, layout, schedule: str) -> None:
    """Record a ``kernel.launch`` trace event: monoid, schedule, grid
    shape, a VMEM working-set estimate (one grid cell's operand blocks),
    and the schedule's slow-memory traffic estimate (read/write bytes —
    the quantity the roofline memory term and ``benchmarks.common
    .hlo_bytes`` measure, so trace events correlate with bench rows).

    Fires at TRACE time for jitted callers — once per compilation, which
    is exactly when the launch geometry is decided — and costs one
    attribute check when tracing is disabled. Uses only static shape /
    dtype metadata, so it is safe under jax tracing.
    """
    if not trace.enabled():
        return
    is_fold = spec.transform is not None
    fold_split = is_fold and schedule not in ("carry", "tree")
    grid = layout.split_grid if fold_split else layout.grid

    def nbytes(shape, dtype):
        n = 1
        for s in shape:
            n *= int(s)
        return n * jnp.dtype(dtype).itemsize

    in_bytes = sum(nbytes(o.shape, o.dtype) for o in operands)
    try:
        specs = (layout.split_op_specs(len(operands)) if fold_split
                 else layout.op_specs(len(operands)))
        vmem_est = sum(
            nbytes(bs.block_shape, o.dtype)
            for bs, o in zip(specs, operands)
            if getattr(bs, "block_shape", None) is not None)
    except Exception:           # noqa: BLE001 — estimate only, never fatal
        vmem_est = 0
    _, out_dts = _dtypes(spec, operands)
    if is_fold:
        out_bytes = sum(nbytes(layout.out_shape_for(i), dt)
                        for i, dt in enumerate(out_dts))
    else:
        out_bytes = sum(nbytes(layout.shape, dt) for dt in out_dts)
    # The module-doc traffic model: decoupled's totals pass re-reads the
    # data; carry/fused read it once.
    reads = 2 * in_bytes if (schedule == "decoupled" and not is_fold) \
        else in_bytes
    trace.instant(
        "kernel.launch", monoid=spec.name, schedule=schedule,
        fold=is_fold, grid=list(grid),
        vmem_block_bytes_est=vmem_est,
        hbm_read_bytes_est=reads, hbm_write_bytes_est=out_bytes)


def scan(operands, spec: KernelSpec, layout, *, schedule: str = "carry",
         exclusive: bool = False, interpret: bool = False,
         return_totals: bool = False, count_cells: bool = False):
    """Run ``spec``'s monoid scan over ``operands`` under one schedule.

    Returns a tuple of output arrays (most registrations emit one).
    ``return_totals=True`` additionally returns the running chunk-totals
    chain (one ``layout.chain_shape`` array per element leaf, combined
    through chunk ``j``) so callers can derive row aggregates in
    O(B·chunks) instead of re-reducing the data — not supported for
    carried-payload (transform) monoids, whose outputs already ARE the
    fold.

    Carried-payload monoids (``spec.transform``) run the fold forms of
    the schedules; ``fused`` maps to ``decoupled`` there (a fold has no
    per-element writeback to chain a prefix into) and ``tree`` maps to
    the carry fold (a fold consumes one macro element per grid block —
    there is no in-block element axis for the tree sweep to reorganize).
    ``count_cells=True`` (carry fold only) additionally returns the
    executed-cell counts — the causal-bound instrumentation.
    """
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; one of {SCHEDULES}")
    if exclusive and not spec.supports_exclusive:
        raise ValueError(
            f"monoid {spec.name!r} does not support exclusive mode")
    if count_cells and (spec.transform is None or schedule != "carry"):
        raise ValueError(
            "count_cells instruments the carry fold only")
    _launch_event(operands, spec, layout, schedule)
    if spec.transform is not None:
        if return_totals:
            raise ValueError(
                "return_totals is meaningless for carried-payload "
                "monoids: the output IS the fold")
        if schedule in ("carry", "tree"):
            return fold_carry(tuple(operands), spec, layout,
                              interpret=interpret, count_cells=count_cells)
        return fold_decoupled(tuple(operands), spec, layout,
                              interpret=interpret)
    fn = {"carry": scan_carry, "decoupled": scan_decoupled,
          "fused": scan_fused, "tree": scan_tree}[schedule]
    return fn(tuple(operands), spec, layout, exclusive=exclusive,
              interpret=interpret, return_totals=return_totals)
