"""Jitted public wrapper for the segmented-scan kernels.

Pads with identity elements — (value 0, flag 0) extends the final
segment, which the slice-back removes — and handles arbitrary rank.
``schedule`` picks the grid organization (see ``core/scan/policy``):
carry-chain, decoupled reduce-then-scan, or the policy's auto rule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scan_blocked.ops import resolve_schedule
from repro.kernels.segscan.decoupled import segscan_decoupled
from repro.kernels.segscan.segscan import segscan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret", "schedule"))
def _impl(values, flags, block_b, block_n, interpret, schedule):
    lead = values.shape[:-1]
    n = values.shape[-1]
    b = 1
    for d in lead:
        b *= d
    v2 = values.reshape(b, n)
    f2 = flags.reshape(b, n).astype(jnp.int32)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    bn = min(block_n, -(-n // 128) * 128)
    pad_b = (-b) % bb
    pad_n = (-n) % bn
    v2 = jnp.pad(v2, ((0, pad_b), (0, pad_n)))
    f2 = jnp.pad(f2, ((0, pad_b), (0, pad_n)))
    kernel = segscan_decoupled if schedule == "decoupled" else segscan_kernel
    out = kernel(v2, f2, block_b=bb, block_n=bn, interpret=interpret)
    return out[:b, :n].reshape(lead + (n,))


def segmented_cumsum(
    values: jax.Array,
    flags: jax.Array,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> jax.Array:
    """Kernel-backed segmented cumsum along the last axis (any rank)."""
    if interpret is None:
        interpret = not _on_tpu()
    n = values.shape[-1]
    batch = max(values.size // max(n, 1), 1)
    bn = min(block_n, -(-n // 128) * 128)  # the block _impl uses
    schedule = resolve_schedule(schedule, batch, n, bn)
    return _impl(values, flags, block_b, block_n, interpret, schedule)
