"""Segmented prefix sum: the SEGMENTED_SUM registration of the engine.

The segmented ``(value, flag)`` monoid (a flag kills the incoming carry —
Blelloch's lift, see ``core/scan/assoc.SEGMENTED_SUM_KERNEL``) run
through the monoid-generic scan engine on the Rows layout. The wrapper
pads with identity elements — (value 0, flag 0) extends the final
segment, which the slice-back removes — and handles arbitrary rank.
``schedule`` picks the grid organization (see ``core/scan/policy``):
carry chain, two-launch decoupled, single-launch fused, the Blelloch
tree sweep, or the policy's auto rule.

Differentiable (w.r.t. ``values``): the custom VJP runs the backward as
another engine segmented scan — the adjoint sums each cotangent backward
to its segment start, which is a REVERSED segmented scan whose
boundaries are the forward flags shifted one step left (the boundary
AFTER an element is what stops gradient flowing back into it). Flags are
structure, not signal: their cotangent is zero.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import scan_engine
from repro.kernels.scan_engine import monoids, resolve_schedule


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret", "schedule"))
def _impl(values, flags, block_b, block_n, interpret, schedule):
    lead = values.shape[:-1]
    n = values.shape[-1]
    b = 1
    for d in lead:
        b *= d
    v2 = values.reshape(b, n)
    # Normalize BEFORE the int cast: a fractional float flag (0.5) must
    # still mark a boundary; astype alone would truncate it to 0.
    f2 = (flags.reshape(b, n) != 0).astype(jnp.int32)

    bb = min(block_b, b) if b % min(block_b, b) == 0 else 1
    bn = min(block_n, -(-n // 128) * 128)
    pad_b = (-b) % bb
    pad_n = (-n) % bn
    v2 = jnp.pad(v2, ((0, pad_b), (0, pad_n)))
    f2 = jnp.pad(f2, ((0, pad_b), (0, pad_n)))
    layout = scan_engine.Rows(v2.shape[0], v2.shape[1], bb, bn)
    out, = scan_engine.scan(
        (v2, f2), monoids.SEGMENTED_SUM, layout, schedule=schedule,
        interpret=interpret)
    return out[:b, :n].reshape(lead + (n,))


def _zero_flag_cotangent(flags):
    """A cotangent for the (non-differentiable) flags operand: float0
    for integer/bool flags — JAX's tangent dtype for them — and plain
    zeros for float flags."""
    if jnp.issubdtype(flags.dtype, jnp.floating):
        return jnp.zeros_like(flags)
    return np.zeros(flags.shape, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _segscan_vjp(values, flags, block_b, block_n, interpret, schedule):
    return _impl(values, flags, block_b, block_n, interpret, schedule)


def _segscan_fwd(values, flags, block_b, block_n, interpret, schedule):
    out = _impl(values, flags, block_b, block_n, interpret, schedule)
    return out, flags


def _segscan_bwd(block_b, block_n, interpret, schedule, flags, g):
    # dv_i = Σ_{j >= i, no boundary in (i, j]} g_j: a reversed segmented
    # scan of the cotangent whose restart flags are the forward flags
    # shifted one LEFT (flag'_j = flag_{j+1}; zero-fill at the end) —
    # killing the reversed carry at j exactly when a segment boundary
    # sits at j+1. Runs through the same jitted engine ``_impl``.
    shifted = jnp.concatenate(
        [flags[..., 1:], jnp.zeros_like(flags[..., :1])], axis=-1)
    rev = _impl(jnp.flip(g, -1), jnp.flip(shifted, -1), block_b, block_n,
                interpret, schedule)
    return jnp.flip(rev, -1), _zero_flag_cotangent(flags)


_segscan_vjp.defvjp(_segscan_fwd, _segscan_bwd)


def segmented_cumsum(
    values: jax.Array,
    flags: jax.Array,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: "bool | None" = None,
    schedule: str = "auto",
) -> jax.Array:
    """Kernel-backed segmented cumsum along the last axis (any rank).

    Differentiable w.r.t. ``values``; the backward is itself an engine
    segmented scan (see module doc).
    """
    if values.shape != flags.shape:
        raise ValueError(
            f"expect matching shapes, got {values.shape} {flags.shape}")
    if interpret is None:
        interpret = not _on_tpu()
    if values.size == 0:
        # Empty scan axis or batch: identity — the padding arithmetic
        # below would otherwise divide by a zero block.
        return values
    n = values.shape[-1]
    batch = max(values.size // max(n, 1), 1)
    bn = min(block_n, -(-n // 128) * 128)  # the block _impl uses
    schedule = resolve_schedule(schedule, batch, n, bn)
    return _segscan_vjp(values, flags, block_b, block_n, interpret, schedule)


# ---------------------------------------------------------------------------
# Back-compat kernel entry points (PR-1 signatures; 2D, pre-padded)
# ---------------------------------------------------------------------------


def _segscan_2d(values, flags, block_b, block_n, interpret, schedule):
    if values.shape != flags.shape or values.ndim != 2:
        raise ValueError(
            f"expect matching 2D inputs, got {values.shape} {flags.shape}")
    layout = scan_engine.Rows(values.shape[0], values.shape[1], block_b,
                              block_n)
    out, = scan_engine.scan(
        (values, (flags != 0).astype(jnp.int32)), monoids.SEGMENTED_SUM,
        layout, schedule=schedule, interpret=interpret)
    return out


def segscan_kernel(values, flags, *, block_b=8, block_n=2048,
                   interpret=False):
    """Carry-schedule segmented cumsum of pre-padded 2D (B, N) inputs."""
    return _segscan_2d(values, flags, block_b, block_n, interpret, "carry")


def segscan_decoupled(values, flags, *, block_b=8, block_n=2048,
                      interpret=False):
    """Decoupled-schedule segmented cumsum of pre-padded 2D inputs."""
    return _segscan_2d(values, flags, block_b, block_n, interpret,
                       "decoupled")
