"""Oracle for the segmented-scan kernel: sequential lax.scan of the
segmented-sum monoid (restart at every nonzero flag)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_cumsum_ref(values: jax.Array, flags: jax.Array) -> jax.Array:
    """Inclusive segmented cumsum along the LAST axis.

    values: (..., N) numeric; flags: (..., N), nonzero starts a segment.
    """
    v = jnp.moveaxis(values.astype(jnp.float32), -1, 0)
    f = jnp.moveaxis(flags != 0, -1, 0)

    def step(carry, xs):
        fi, vi = xs
        out = jnp.where(fi, vi, carry + vi)
        return out, out

    _, ys = jax.lax.scan(step, jnp.zeros_like(v[0]), (f, v))
    return jnp.moveaxis(ys, 0, -1).astype(values.dtype)
