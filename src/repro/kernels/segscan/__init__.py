from repro.kernels.segscan.ops import (segmented_cumsum, segscan_decoupled,
                                       segscan_kernel)
from repro.kernels.segscan.ref import segmented_cumsum_ref

__all__ = ["segmented_cumsum", "segmented_cumsum_ref", "segscan_decoupled",
           "segscan_kernel"]
