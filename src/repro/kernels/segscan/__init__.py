from repro.kernels.segscan.decoupled import segscan_decoupled
from repro.kernels.segscan.ops import segmented_cumsum
from repro.kernels.segscan.ref import segmented_cumsum_ref
from repro.kernels.segscan.segscan import segscan_kernel

__all__ = ["segmented_cumsum", "segmented_cumsum_ref", "segscan_decoupled",
           "segscan_kernel"]
