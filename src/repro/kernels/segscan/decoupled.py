"""Decoupled reduce-then-scan SEGMENTED prefix sum.

Same two-phase organization as ``kernels/scan_blocked/decoupled.py``
(paper Observation 3: reduce-first + partitioning), lifted to the
segmented ``(flag, value)`` monoid:

  pass 1b  parallel grid emits each chunk's monoid total: the pair
           (any-flag-in-chunk, last element of the in-chunk segmented
           scan).
  combine  sequential exclusive chain with the segmented combine —
           ``c' = f ? v : v + c`` — matching the carry kernel's update
           order exactly (bit-identical).
  pass 2   parallel grid redoes the in-chunk segmented scan and applies
           the incoming carry only to the flag-free prefix.

A flag anywhere in a chunk kills the incoming carry, so the chain is the
only place chunk order matters — and it runs on the tiny totals array.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params
from repro.kernels.segscan.segscan import _seg_log_scan


def _totals_kernel(v_ref, f_ref, tot_v_ref, tot_f_ref, *, acc_dtype):
    v = v_ref[...].astype(acc_dtype)
    f = f_ref[...] != 0
    local_v, local_f = _seg_log_scan(v, f)
    tot_v_ref[...] = local_v[:, -1:]
    tot_f_ref[...] = local_f[:, -1:].astype(jnp.int32)


def _scan_kernel(v_ref, f_ref, off_ref, o_ref, *, acc_dtype):
    v = v_ref[...].astype(acc_dtype)
    f = f_ref[...] != 0
    local_v, local_f = _seg_log_scan(v, f)
    carry = off_ref[...]  # (bb, 1): segment value entering the chunk
    out = jnp.where(local_f, local_v, local_v + carry)
    o_ref[...] = out.astype(o_ref.dtype)


def _exclusive_chain(tot_v: jax.Array, tot_f: jax.Array) -> jax.Array:
    """Exclusive segmented chain over (B, chunks) totals along axis 1."""

    def step(carry, tf):
        t, f = tf
        new = jnp.where(f != 0, t, t + carry)
        return new, carry

    zero = jnp.zeros_like(tot_v[:, 0])
    _, offs = jax.lax.scan(
        step, zero,
        (jnp.moveaxis(tot_v, 1, 0), jnp.moveaxis(tot_f, 1, 0)))
    return jnp.moveaxis(offs, 0, 1)


def segscan_decoupled(
    values: jax.Array,
    flags: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Decoupled segmented cumsum along the last axis of 2D (B, N) inputs."""
    if values.shape != flags.shape or values.ndim != 2:
        raise ValueError(
            f"expect matching 2D inputs, got {values.shape} {flags.shape}")
    B, N = values.shape
    if B % block_b or N % block_n:
        raise ValueError(
            f"shape {values.shape} not divisible by ({block_b}, {block_n})")
    acc_dtype = jnp.float32 if values.dtype in (jnp.bfloat16, jnp.float16) \
        else values.dtype
    chunks = N // block_n
    grid = (B // block_b, chunks)
    spec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))
    tspec = pl.BlockSpec((block_b, 1), lambda i, j: (i, j))
    par = compiler_params(dimension_semantics=("parallel", "parallel"))

    tot_v, tot_f = pl.pallas_call(
        functools.partial(_totals_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[tspec, tspec],
        out_shape=[
            jax.ShapeDtypeStruct((B, chunks), acc_dtype),
            jax.ShapeDtypeStruct((B, chunks), jnp.int32),
        ],
        compiler_params=par,
        interpret=interpret,
        name="segscan_totals",
    )(values, flags)

    offsets = _exclusive_chain(tot_v, tot_f)

    return pl.pallas_call(
        functools.partial(_scan_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[spec, spec, tspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(values.shape, values.dtype),
        compiler_params=par,
        interpret=interpret,
        name="segscan_apply",
    )(values, flags, offsets)
