"""Pallas TPU kernel: VMEM-blocked SEGMENTED prefix sum.

The paper's §1 partitioning primitive on-chip: a segmented cumsum
restarts at every flag — MoE per-expert ranking, packed-sequence
boundaries, and stream compaction are all this operator (DESIGN.md §3).

Same schedule as ``kernels/scan_blocked`` (the paper's §2.2 partitioned
scan): VMEM tiles, fused two passes per block, grid-carried state —
except the carry is the segmented monoid's, a ``(value, flag_seen)``
pair:

    combine((f1, v1), (f2, v2)) = (f1 | f2,  f2 ? v2 : v1 + v2)

The in-block pass is the Hillis–Steele log-step network over the pair
(the paper's §3.1 horizontal scan lifted to a richer monoid). Because a
flag anywhere in a block KILLS the incoming carry, the inter-block carry
only survives flag-free prefixes — handled with one where() per block
against the running flag-OR.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params


def _seg_log_scan(v: jax.Array, f: jax.Array):
    """In-block inclusive segmented scan along axis 1 of (bb, bn) tiles."""
    n = v.shape[1]
    k = 1
    while k < n:
        v_sh = jnp.pad(v, ((0, 0), (k, 0)))[:, :n]
        f_sh = jnp.pad(f, ((0, 0), (k, 0)))[:, :n]
        # combine(left=shifted, right=current)
        v = jnp.where(f, v, v_sh + v)
        f = jnp.logical_or(f, f_sh)
        k *= 2
    return v, f


def _kernel(v_ref, f_ref, o_ref, carry_ref, *, acc_dtype):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    v = v_ref[...].astype(acc_dtype)
    f = f_ref[...] != 0
    local_v, local_f = _seg_log_scan(v, f)          # pass 1 in VMEM
    carry = carry_ref[...]                          # (bb, 1) running value
    # pass 2 fused: the carry only reaches positions with NO flag yet.
    out = jnp.where(local_f, local_v, local_v + carry)
    o_ref[...] = out.astype(o_ref.dtype)
    carry_ref[...] = out[:, -1:]                    # segmented `sums` update


def segscan_kernel(
    values: jax.Array,
    flags: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Segmented cumsum along the last axis of 2D (B, N) inputs."""
    if values.shape != flags.shape or values.ndim != 2:
        raise ValueError(
            f"expect matching 2D inputs, got {values.shape} {flags.shape}")
    B, N = values.shape
    if B % block_b or N % block_n:
        raise ValueError(
            f"shape {values.shape} not divisible by ({block_b}, {block_n})")
    acc_dtype = jnp.float32 if values.dtype in (jnp.bfloat16, jnp.float16) \
        else values.dtype
    grid = (B // block_b, N // block_n)
    spec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(values.shape, values.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, 1), acc_dtype)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="segscan",
    )(values, flags)
