"""Pallas TPU kernels for the performance-critical compute layers.

All four scan families are registrations of ONE monoid-generic engine
(``scan_engine``): each grid organization is written once against a
kernel-side monoid spec (``core/scan/assoc.KernelSpec``), and a family
is just a spec + a layout + a back-compat ``ops`` wrapper. Three grid
schedules per family (`schedule=` knob on each ``ops`` wrapper,
arbitrated by ``core/scan/policy.choose_schedule``):

  carry      — the paper's §2.2 partitioned single pass: sequential grid
               along the scanned axis, VMEM scratch carry, both logical
               passes fused while the block is VMEM-resident. Parallelism
               across rows only.
  decoupled  — the paper's SIMD2-P reduce-then-scan (Observation 3): a
               fully parallel totals pass, a tiny exclusive combine, and
               a fully parallel scan+offset pass — the scanned axis
               itself spreads across cores (B=1, huge-N serve shapes).
  fused      — the same reduce-then-scan in a SINGLE launch: chunk
               prefixes chained through cross-chunk semaphores, erasing
               decoupled's second data read. Two-launch fallback under
               interpret mode / missing semaphore API.

  scan_engine      — the schedules (written once) + layouts + registry
  scan_blocked     — prefix sum            (sum monoid registration)
  segscan          — segmented prefix sum  ((value, flag) registration)
  ssm_scan         — affine-monoid scan    (SSM/xLSTM recurrences)
  compact          — stream compaction     (mask monoid, fused select)
  flash_attention  — online-softmax monoid scan over KV blocks
"""
