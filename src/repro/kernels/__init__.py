"""Pallas TPU kernels for the performance-critical compute layers.

The scan kernels run one of two grid schedules (`schedule=` knob on each
``ops`` wrapper, arbitrated by ``core/scan/policy.choose_schedule``):

  carry      — the paper's §2.2 partitioned single pass: sequential grid
               along the scanned axis, VMEM scratch carry, both logical
               passes fused while the block is VMEM-resident. Parallelism
               across rows only.
  decoupled  — the paper's SIMD2-P reduce-then-scan (Observation 3): a
               fully parallel totals pass, a tiny exclusive combine, and
               a fully parallel scan+offset pass — the scanned axis
               itself spreads across cores (B=1, huge-N serve shapes).

  scan_blocked     — prefix sum (``decoupled.py`` per package holds the
                     second schedule)
  segscan          — segmented prefix sum ((flag, value) monoid)
  ssm_scan         — affine-monoid scan (SSM/xLSTM recurrences)
  flash_attention  — online-softmax monoid scan over KV blocks
"""
