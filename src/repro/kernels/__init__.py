"""Pallas TPU kernels for the performance-critical compute layers.

Every kernel follows the same blocked-scan schedule (the paper's §2.2):
sequential grid along the scanned axis, VMEM scratch carry, both logical
passes fused while the block is VMEM-resident.

  scan_blocked     — prefix sum with a grid-carried running total
  ssm_scan         — affine-monoid scan (SSM/xLSTM recurrences)
  flash_attention  — online-softmax monoid scan over KV blocks
"""
