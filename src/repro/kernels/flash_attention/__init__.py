from repro.kernels.flash_attention.flash_attention import (
    flash_attention_bwd_kernel, flash_attention_kernel)
from repro.kernels.flash_attention.ops import (flash_attention,
                                               resolved_attention_schedule)
from repro.kernels.flash_attention.ref import (banded_ref, blockwise_ref,
                                               masked_softmax, mha_ref)

__all__ = ["flash_attention", "flash_attention_bwd_kernel",
           "flash_attention_kernel", "banded_ref", "blockwise_ref",
           "masked_softmax", "mha_ref", "resolved_attention_schedule"]
