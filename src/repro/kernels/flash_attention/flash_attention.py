"""Flash attention = the SOFTMAX_PAIR registration of the scan engine.

The KV-block loop of flash attention is an inclusive FOLD over KV blocks
of the monoid ``(m, s) ⊕ (m', s') = (max(m,m'), s·e^{m-max} + s'·e^{m'-max})``
(``repro.core.scan.assoc.SOFTMAX_PAIR``) with the weighted-value
accumulator carried alongside. The hand-rolled kernel body that used to
live here is the engine's generic fold-carry schedule now — this module
is nothing but the registration: it states the attention GEOMETRY
(``scan_engine.KVBlocks`` — GQA head grouping via index maps, per-leaf
payload dims) and the OPERATOR (``assoc.softmax_pair_kernel_spec`` — the
q·kᵀ input transform with causal/window/softcap/length masking, the
payload combine, the ``acc/l`` finalize), exactly like the other four
kernel families.

Features: causal masking, sliding windows (gemma-style local layers),
logit soft-capping (gemma2), GQA via index-map head grouping, KV-length
masking for padded caches, and two grid schedules:

  ``schedule="carry"``      the classic flash forward — KV sequential,
                            payload carry in VMEM (read n + write out).
  ``schedule="decoupled"``  split-KV / flash-decoding — KV chunks
                            parallel, partial payloads combined by a
                            tiny jnp chain (long-KV decode/scoring).

Forward only: training paths use the autodiff-able jnp blockwise
reference (ref.py) under remat; this kernel serves inference.
"""

from __future__ import annotations

import jax

from repro.core.scan import policy
from repro.core.scan.assoc import NEG_INF, softmax_pair_kernel_spec
from repro.kernels import scan_engine

__all__ = ["NEG_INF", "default_kv_split_target", "flash_attention_kernel",
           "pick_kv_splits"]


def default_kv_split_target() -> int:
    """Default split-KV chunk-count target: oversubscribe every core 2x
    (more chunks only add chain traffic). Single source of truth for
    ``pick_kv_splits`` and the ops wrapper's KV padding, so ROADMAP's
    on-hardware tuning touches one place."""
    return 2 * policy.NUM_CORES


def pick_kv_splits(num_k_blocks: int, target: "int | None" = None) -> int:
    """KV chunk count for the decoupled fold: the largest divisor of the
    block count not exceeding ``target`` (default: enough chunks to
    oversubscribe every core 2x — more chunks only add chain traffic).

    Degenerates toward 1 when the block count has no small divisor
    (prime counts) — the public ``ops`` wrapper avoids that by padding
    the KV axis to a multiple of the target chunk count before calling
    here (the masked tail makes the padding free), so direct kernel
    callers are the only ones exposed to awkward block counts."""
    if target is None:
        target = default_kv_split_target()
    target = max(1, min(int(target), num_k_blocks))
    for splits in range(target, 0, -1):
        if num_k_blocks % splits == 0:
            return splits
    return 1


def flash_attention_kernel(
    q: jax.Array,  # (BH, Tq, d)
    k: jax.Array,  # (BHkv, Tk, d)
    v: jax.Array,  # (BHkv, Tk, d)
    *,
    group: int = 1,       # heads per kv head (GQA)
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    schedule: str = "carry",
    kv_splits: "int | None" = None,
    interpret: bool = False,
) -> jax.Array:
    """Attention over flattened (batch·heads) leading axes.

    ``q`` has BH = B·H_q rows; ``k``/``v`` have B·H_kv; ``group`` maps
    each q head to its kv head via the BlockSpec index map (no
    materialized repeat — the GQA "gather" is free addressing, cf. paper
    Obs. 5). ``schedule`` picks the fold organization; ``kv_splits``
    overrides the decoupled chunk count (default: policy-sized divisor
    of the KV block count).
    """
    BH, Tq, d = q.shape
    BHkv, Tk, dk = k.shape
    assert d == dk and v.shape == k.shape and BH == BHkv * group
    if Tq % block_q or Tk % block_k:
        raise ValueError(f"({Tq},{Tk}) not divisible by ({block_q},{block_k})")
    kv_len = Tk if kv_len is None else kv_len

    splits = 1
    if schedule != "carry":
        splits = pick_kv_splits(Tk // block_k, kv_splits)
    layout = scan_engine.KVBlocks(
        bh=BH, bh_kv=BHkv, tq=Tq, tk=Tk, d=d, bq=block_q, bk=block_k,
        group=group, splits=splits, leaf_dims=(1, 1, d))
    spec = softmax_pair_kernel_spec(
        scale=scale, causal=causal, window=window, softcap=softcap,
        kv_len=kv_len, block_q=block_q, block_k=block_k)
    out, = scan_engine.scan(
        (q, k, v), spec, layout, schedule=schedule, interpret=interpret)
    return out
