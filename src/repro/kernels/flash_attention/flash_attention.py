"""Pallas TPU kernel: flash attention forward — online softmax as a scan.

The KV-block loop of flash attention is an inclusive scan over KV blocks of
the monoid ``(m, s) ⊕ (m', s') = (max(m,m'), s·e^{m-max} + s'·e^{m'-max})``
(``repro.core.scan.assoc.SOFTMAX_PAIR``), with the weighted-value
accumulator carried alongside. Structurally this kernel is the same program
as ``scan_blocked``: grid-sequential blocks over the "scanned" (KV) axis,
carry in VMEM scratch, both "passes" fused while the block is resident —
the paper's §2.2 schedule with a fancier operator. That is why it lives in
this framework: 32k prefill and 500k-context serving lower through the same
blocked-scan machinery as the cumsum.

Features: causal masking, sliding windows (gemma-style local layers),
logit soft-capping (gemma2), GQA via index-map head grouping, and KV-length
masking for padded caches.

Forward only: training paths use the autodiff-able jnp blockwise reference
(ref.py) under remat; this kernel serves inference (prefill/decode scoring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1e30  # finite mask value: keeps the m-carry NaN-free


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, softcap, block_q, block_k, kv_len, num_k_blocks,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]              # (bq, 1)
    l_prev = l_scr[...]              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)  # rescale of the carried sums
    p = jnp.exp(s - m_new)           # (bq, bk); fully-masked rows -> ~0
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (BH, Tq, d)
    k: jax.Array,  # (BHkv, Tk, d)
    v: jax.Array,  # (BHkv, Tk, d)
    *,
    group: int = 1,       # heads per kv head (GQA)
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Attention over flattened (batch·heads) leading axes.

    ``q`` has BH = B·H_q rows; ``k``/``v`` have B·H_kv; ``group`` maps each
    q head to its kv head via the BlockSpec index map (no materialized
    repeat — the GQA "gather" is free addressing, cf. paper Obs. 5).
    """
    BH, Tq, d = q.shape
    BHkv, Tk, dk = k.shape
    assert d == dk and v.shape == k.shape and BH == BHkv * group
    if Tq % block_q or Tk % block_k:
        raise ValueError(f"({Tq},{Tk}) not divisible by ({block_q},{block_k})")
    kv_len = Tk if kv_len is None else kv_len
    nq, nk = Tq // block_q, Tk // block_k

    kernel = functools.partial(
        _kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, kv_len=kv_len, num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec(
                (1, block_k, d),
                lambda h, i, j, g=group: (h // g, j, 0),
            ),
            pl.BlockSpec(
                (1, block_k, d),
                lambda h, i, j, g=group: (h // g, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
