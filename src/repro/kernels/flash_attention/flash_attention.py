"""Flash attention = the SOFTMAX_PAIR registration of the scan engine.

The KV-block loop of flash attention is an inclusive FOLD over KV blocks
of the monoid ``(m, s) ⊕ (m', s') = (max(m,m'), s·e^{m-max} + s'·e^{m'-max})``
(``repro.core.scan.assoc.SOFTMAX_PAIR``) with the weighted-value
accumulator carried alongside. The hand-rolled kernel body that used to
live here is the engine's generic fold-carry schedule now — this module
is nothing but the registration: it states the attention GEOMETRY
(``scan_engine.KVBlocks`` — GQA head grouping via index maps, per-leaf
payload dims) and the OPERATOR (``assoc.softmax_pair_kernel_spec`` — the
q·kᵀ input transform with causal/window/softcap/length masking, the
payload combine, the ``acc/l`` finalize), exactly like the other four
kernel families.

Features: causal masking, sliding windows (gemma-style local layers),
logit soft-capping (gemma2), GQA via index-map head grouping, KV-length
masking for padded caches, and two grid schedules:

  ``schedule="carry"``      the classic flash forward — KV sequential,
                            payload carry in VMEM (read n + write out).
  ``schedule="decoupled"``  split-KV / flash-decoding — KV chunks
                            parallel, partial payloads combined by a
                            tiny jnp chain (long-KV decode/scoring).

Forward and backward: the forward optionally emits the folded ``(m, l)``
row statistics, and ``flash_attention_bwd_kernel`` runs the backward as
two more engine folds over the same KV layout — dq over ``KVBlocks``,
dk/dv over the transposed ``QBlocks`` — against the backward specs in
``assoc`` (recomputed logits, no materialized attention matrix). Both
directions honor the causal-aware KV extent (``use_kv_bounds``): grid
cells that are provably fully masked are skipped, bitwise-free.
"""

from __future__ import annotations

import jax

from repro.core.scan import policy
from repro.core.scan.assoc import (NEG_INF, softmax_pair_bwd_dkv_kernel_spec,
                                   softmax_pair_bwd_dq_kernel_spec,
                                   softmax_pair_kernel_spec)
from repro.kernels import scan_engine

__all__ = ["NEG_INF", "default_kv_split_target", "flash_attention_bwd_kernel",
           "flash_attention_kernel", "pick_kv_splits"]


def default_kv_split_target() -> int:
    """Default split-KV chunk-count target: oversubscribe every core 2x
    (more chunks only add chain traffic). Single source of truth for
    ``pick_kv_splits`` and the ops wrapper's KV padding, so ROADMAP's
    on-hardware tuning touches one place."""
    return 2 * policy.NUM_CORES


def pick_kv_splits(num_k_blocks: int, target: "int | None" = None) -> int:
    """KV chunk count for the decoupled fold: the largest divisor of the
    block count not exceeding ``target`` (default: enough chunks to
    oversubscribe every core 2x — more chunks only add chain traffic).

    Degenerates toward 1 when the block count has no small divisor
    (prime counts) — the public ``ops`` wrapper avoids that by padding
    the KV axis to a multiple of the target chunk count before calling
    here (the masked tail makes the padding free), so direct kernel
    callers are the only ones exposed to awkward block counts."""
    if target is None:
        target = default_kv_split_target()
    target = max(1, min(int(target), num_k_blocks))
    for splits in range(target, 0, -1):
        if num_k_blocks % splits == 0:
            return splits
    return 1


def flash_attention_kernel(
    q: jax.Array,  # (BH, Tq, d)
    k: jax.Array,  # (BHkv, Tk, d)
    v: jax.Array,  # (BHkv, Tk, d)
    *,
    group: int = 1,       # heads per kv head (GQA)
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    schedule: str = "carry",
    kv_splits: "int | None" = None,
    return_stats: bool = False,
    use_kv_bounds: bool = True,
    count_cells: bool = False,
    kv_block_map: "tuple | None" = None,
    interpret: bool = False,
):
    """Attention over flattened (batch·heads) leading axes.

    ``q`` has BH = B·H_q rows; ``k``/``v`` have B·H_kv; ``group`` maps
    each q head to its kv head via the BlockSpec index map (no
    materialized repeat — the GQA "gather" is free addressing, cf. paper
    Obs. 5). ``schedule`` picks the fold organization; ``kv_splits``
    overrides the decoupled chunk count (default: policy-sized divisor
    of the KV block count).

    ``return_stats=True`` returns ``(out, m, l)`` — the folded row max
    and normalizer (each (BH, Tq, 1) f32), the backward's residuals.
    ``use_kv_bounds`` gates the causal-aware KV extent (skip grid cells
    that are provably fully masked — bitwise-identical output);
    ``count_cells=True`` (carry schedule) additionally returns the
    per-(head, q-block) executed-cell counts.

    ``kv_block_map`` routes logical KV block ``j`` to physical block
    ``kv_block_map[j]`` of the k/v arrays through the layout's index
    maps (paged KV pools, ``serve/paging.py``): the fold consumes a
    page-permuted pool without a materialized contiguous gather, and —
    because masks/bounds are keyed on LOGICAL positions — the output is
    bitwise identical to running on the contiguously-laid-out cache.
    """
    BH, Tq, d = q.shape
    BHkv, Tk, dk = k.shape
    assert d == dk and v.shape == k.shape and BH == BHkv * group
    if Tq % block_q or Tk % block_k:
        raise ValueError(f"({Tq},{Tk}) not divisible by ({block_q},{block_k})")
    kv_len = Tk if kv_len is None else kv_len

    splits = 1
    if schedule != "carry":
        splits = pick_kv_splits(Tk // block_k, kv_splits)
    layout = scan_engine.KVBlocks(
        bh=BH, bh_kv=BHkv, tq=Tq, tk=Tk, d=d, bq=block_q, bk=block_k,
        group=group, splits=splits, leaf_dims=(1, 1, d),
        out_dims=(d, 1, 1) if return_stats else (d,),
        kv_bounds=(causal, window, kv_len) if use_kv_bounds else None,
        kv_block_map=(tuple(int(b) for b in kv_block_map)
                      if kv_block_map is not None else None))
    spec = softmax_pair_kernel_spec(
        scale=scale, causal=causal, window=window, softcap=softcap,
        kv_len=kv_len, block_q=block_q, block_k=block_k,
        with_stats=return_stats)
    res = scan_engine.scan(
        (q, k, v), spec, layout, schedule=schedule, interpret=interpret,
        count_cells=count_cells)
    if count_cells:
        res, counts = res
        return (tuple(res) if return_stats else res[0]), counts
    return tuple(res) if return_stats else res[0]


def flash_attention_bwd_kernel(
    q: jax.Array,      # (BH, Tq, d)
    k: jax.Array,      # (BHkv, Tk, d)
    v: jax.Array,      # (BHkv, Tk, d)
    do: jax.Array,     # (BH, Tq, d) — output cotangent
    m: jax.Array,      # (BH, Tq, 1) f32 — forward row max
    l: jax.Array,      # (BH, Tq, 1) f32 — forward row normalizer
    delta: jax.Array,  # (BH, Tq, 1) f32 — rowsum(dO ⊙ O) precompute
    *,
    group: int = 1,
    scale: float,
    causal: bool = True,
    window: "int | None" = None,
    softcap: "float | None" = None,
    kv_len: "int | None" = None,
    block_q: int = 128,
    block_k: int = 128,
    schedule: str = "carry",
    kv_splits: "int | None" = None,
    use_kv_bounds: bool = True,
    interpret: bool = False,
):
    """Flash backward as two engine folds: ``(dq, dk, dv)``.

    dq folds over KV blocks in the forward's ``KVBlocks`` layout; dk/dv
    fold over the transposed ``QBlocks`` (group × q-block) axis so the
    GQA head summation is the fold itself. Both are plain SUM monoids
    whose transforms recompute the logits tile — nothing T×T is ever
    materialized. ``schedule="decoupled"`` runs each fold's axis in
    parallel chunks stitched by the jnp chain (split-KV for dq, split-Q
    for dk/dv).
    """
    BH, Tq, d = q.shape
    BHkv, Tk, dk_ = k.shape
    assert d == dk_ and v.shape == k.shape and BH == BHkv * group
    assert do.shape == q.shape and m.shape == (BH, Tq, 1)
    if Tq % block_q or Tk % block_k:
        raise ValueError(f"({Tq},{Tk}) not divisible by ({block_q},{block_k})")
    kv_len = Tk if kv_len is None else kv_len
    bounds = (causal, window, kv_len) if use_kv_bounds else None
    mask_cfg = dict(scale=scale, causal=causal, window=window,
                    softcap=softcap, kv_len=kv_len, block_q=block_q,
                    block_k=block_k)
    ops = (q, k, v, do, m, l, delta)

    dq_splits = 1
    if schedule != "carry":
        dq_splits = pick_kv_splits(Tk // block_k, kv_splits)
    dq_layout = scan_engine.KVBlocks(
        bh=BH, bh_kv=BHkv, tq=Tq, tk=Tk, d=d, bq=block_q, bk=block_k,
        group=group, splits=dq_splits, leaf_dims=(d,), out_dims=(d,),
        op_kinds=("q", "kv", "kv", "q", "qstat", "qstat", "qstat"),
        kv_bounds=bounds)
    dq, = scan_engine.scan(
        ops, softmax_pair_bwd_dq_kernel_spec(**mask_cfg), dq_layout,
        schedule=schedule, interpret=interpret)

    dkv_splits = 1
    if schedule != "carry":
        dkv_splits = pick_kv_splits(group * (Tq // block_q), kv_splits)
    dkv_layout = scan_engine.QBlocks(
        bh=BH, bh_kv=BHkv, tq=Tq, tk=Tk, d=d, bq=block_q, bk=block_k,
        group=group, splits=dkv_splits, leaf_dims=(d, d), out_dims=(d, d),
        kv_bounds=bounds)
    dk, dv = scan_engine.scan(
        ops, softmax_pair_bwd_dkv_kernel_spec(**mask_cfg), dkv_layout,
        schedule=schedule, interpret=interpret)
    return dq, dk, dv
